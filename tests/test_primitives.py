"""Cluster collective primitives (Alg. 1/2): tree schedules vs XLA
reference on an 8-device host mesh (subprocess) + traffic-model units."""
import numpy as np
import pytest

from repro.core import primitives as prim
from helpers import run_multidevice


def test_traffic_model_exact():
    # paper §3.2 closed forms
    assert prim.traffic_reduce(10, 4) == 10 * 2 * 4
    assert prim.traffic_reduce(7, 8) == 7 * 3 * 8
    assert prim.traffic_gather(10, 4) == 10 * (4 - 1) * 4
    assert prim.traffic_gather(5, 16) == 5 * 15 * 16
    assert prim.traffic_reduce(10, 1) == 0 and prim.traffic_gather(10, 1) == 0


def test_traffic_gather_matches_message_doubling():
    # Gather sends size·(1+2+…+N/2) per rank = size·(N−1)
    for n in (2, 4, 8, 16):
        per_rank = sum(2 ** r for r in range(int(np.log2(n))))
        assert prim.traffic_gather(3, n) == 3 * per_rank * n


@pytest.mark.multidevice
def test_cluster_reduce_and_gather_vs_xla():
    run_multidevice("""
    from repro.core import primitives as prim
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)

    def run(fn):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=P("c", None),
                                 out_specs=P("c", None)))(x)

    r = run(lambda v: prim.cluster_reduce(v, "c", "sum"))
    np.testing.assert_allclose(np.asarray(r)[0], np.asarray(x).sum(0))
    r = run(lambda v: prim.cluster_reduce(v, "c", "max"))
    np.testing.assert_allclose(np.asarray(r)[0], np.asarray(x).max(0))
    gm = jax.jit(shard_map(
        lambda v: jnp.max(jnp.abs(prim.cluster_gather(v, "c")
                                  - jax.lax.all_gather(v, "c"))).reshape(1, 1),
        mesh=mesh, in_specs=P("c", None), out_specs=P("c", None)))(x)
    assert float(jnp.max(gm)) == 0.0
    # sub-axis collectives: model=8 factored as heads 2 × cluster 4
    heads = prim.SubAxis("c", 2, minor_size=4)
    clus = prim.SubAxis("c", 4, minor_size=1)
    def sub(v):
        a = prim.cluster_reduce(v, clus, "sum")     # within groups of 4
        b = prim.cluster_reduce(v, heads, "sum")    # across the two groups
        return jnp.stack([a, b])
    out = jax.jit(shard_map(lambda v: sub(v)[None], mesh=mesh,
                            in_specs=P("c", None),
                            out_specs=P("c", None, None)))(x)
    out = np.asarray(out)
    xs = np.asarray(x)
    for g in range(2):
        expect = xs[g * 4:(g + 1) * 4].sum(0)
        for r_ in range(4):
            np.testing.assert_allclose(out[g * 4 + r_, 0, 0], expect)
    for r_ in range(4):
        expect = xs[r_] + xs[r_ + 4]
        np.testing.assert_allclose(out[r_, 1, 0], expect)
        np.testing.assert_allclose(out[r_ + 4, 1, 0], expect)
    print("PRIM OK")
    """)


@pytest.mark.multidevice
def test_flash_combine_fused_vs_faithful_vs_oracle():
    run_multidevice("""
    from repro.core import primitives as prim
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    key = jax.random.PRNGKey(0)
    m = jax.random.normal(key, (8, 4))
    l = jax.random.uniform(key, (8, 4)) + 0.5
    o = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))

    def combine(fused):
        def f(mm, ll, oo):
            gm, gl, go = prim.cluster_flash_combine(
                mm[0], ll[0], oo[0], "c", fused=fused)
            return (go / gl[:, None])[None]
        return jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=(P("c", None), P("c", None), P("c", None, None)),
            out_specs=P("c", None, None)))(m, l, o)

    mg = np.max(np.asarray(m), axis=0)
    lg = (np.exp(np.asarray(m) - mg) * np.asarray(l)).sum(0)
    og = (np.exp(np.asarray(m) - mg)[..., None] * np.asarray(o)).sum(0) \
        / lg[:, None]
    for fused in (True, False):
        out = np.asarray(combine(fused))
        for r in range(8):
            np.testing.assert_allclose(out[r], og, rtol=1e-5, atol=1e-5)
    print("COMBINE OK")
    """)


@pytest.mark.multidevice
def test_offchip_vs_onchip_reduce_equivalence():
    run_multidevice("""
    from repro.core import primitives as prim
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    on = jax.jit(shard_map(lambda v: prim.cluster_reduce(v, "c", "sum"),
                           mesh=mesh, in_specs=P("c", None),
                           out_specs=P("c", None)))(x)
    off = jax.jit(shard_map(lambda v: prim.offchip_reduce(v[0], "c")[None],
                            mesh=mesh, in_specs=P("c", None),
                            out_specs=P("c", None)))(x)
    np.testing.assert_allclose(np.asarray(on), np.asarray(off))
    print("OFFCHIP OK")
    """)
