"""Fleet router chaos matrix (serving/router.py + serving/faults.py).

Every fault kind × {GQA, MLA} × cluster {1, 2}:

* the router DETECTS the fault within one tick of its firing (the
  probes are per-tick, the faults corrupt observable state the same
  tick they fire),
* the failed replica drains and its in-flight requests recover on the
  survivor, and
* every completed request's token stream is BYTE-IDENTICAL to the
  fault-free oracle run — the zero-corruption invariant: tokens are
  committed to the journal only after the emitting tick's probes pass,
  and recovery re-prefills the prompt then replays the journal through
  the same jitted decode program (DESIGN.md §9).

Cluster 1 runs in-process (tier-1); cluster 2 rides the 8-emulated-
device subprocess (``multidevice``).  All seeds fixed — the chaos tier
is deterministic, a failure reproduces by re-running the test.  The
``_minihyp``-compatible property throws random fault schedules over
random traces at the fleet and asserts the same equality.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # tier-1 container: deterministic shim
    from _minihyp import given, settings, strategies as st

from helpers import run_multidevice

from repro.core import tracecount
from repro.serving.faults import (FAULT_KINDS, FaultInjector, FaultSpec,
                                  ReplicaKilled, corrupt_kv_slot)
from repro.serving.router import Router
from repro.serving.scheduler import Request

pytestmark = pytest.mark.chaos

# the probe each fault kind must trip (serving/faults.py taxonomy)
EXPECTED_SIGNAL = {
    "kill": "detect_heartbeat",
    "blackhole": "detect_journal_stale",
    "corrupt_kv": "detect_nonfinite",
    "corrupt_lens": "detect_lens_bounds",
    "poison_weight": "detect_nonfinite",
    "drop_admit": "detect_journal_stale",
    "dup_admit": "detect_journal_stale",
}


def _build_replicas(arch, **kw):
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import EngineOptions, build_replicas
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:             # dense-MLA arm (deepseek minus MoE)
        cfg = dataclasses.replace(cfg, moe=None)
    mesh = kw.pop("mesh", None) or make_test_mesh(data=1, model=1)
    opts = EngineOptions(backend="xla", check_finite=True,
                         kv_fingerprint=True, shadow_head=True, **kw)
    return cfg, build_replicas(cfg, mesh, n_replicas=2, max_seq=32,
                               batch_global=2, options=opts)


def _mk_trace(cfg, seed, n_req=6):
    rng = np.random.default_rng(seed)
    trace = []
    for rid in range(n_req):
        plen = int(rng.integers(2, 7))
        trace.append((int(rng.integers(0, 4)), Request(
            rid, [int(t) for t in rng.integers(1, cfg.vocab_size, plen)],
            int(rng.integers(3, 7)))))
    return trace


def _run(engines, trace, injectors=None):
    return Router(engines, prompt_cap=8, max_new_cap=8,
                  injectors=injectors).run(
        [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])


@pytest.fixture(scope="module", params=["llama2-7b", "deepseek-v2-lite"],
                ids=["gqa", "mla"])
def fleet(request):
    cfg, engines = _build_replicas(request.param)
    trace = _mk_trace(cfg, seed=0)
    oracle = {rid: list(e.tokens)
              for rid, e in _run(engines, trace).items()}
    return cfg, engines, trace, oracle


# ---------------------------------------------------------------------------
# The chaos matrix (cluster 1, both archs, every fault kind)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", FAULT_KINDS)
def test_chaos_matrix_detect_recover_exact(fleet, kind):
    cfg, engines, trace, oracle = fleet
    tracecount.reset_signals()
    inj = FaultInjector([FaultSpec(kind, step=2, target=0, replica=0)])
    router = Router(engines, prompt_cap=8, max_new_cap=8,
                    injectors={0: inj})
    journal = router.run(
        [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])

    # the fault fired exactly once and was detected within one tick
    assert len(inj.fired) == 1
    lat = router.detection_latency(inj)
    assert lat == [0] or lat == [1], (kind, lat)
    assert len(router.detections) == 1
    assert EXPECTED_SIGNAL[kind] in router.detections[0]["signals"], \
        (kind, router.detections)
    sig = tracecount.signal_totals()
    assert sig[EXPECTED_SIGNAL[kind]] >= 1
    assert sig["replica_failed"] == 1
    # replay never disagreed with the journal (same weights everywhere)
    assert sig["detect_journal_mismatch"] == 0

    # the failed replica drained; the fleet degraded but stayed up
    assert [r.alive for r in router.replicas] == [False, True]
    assert 0.0 < router.availability() < 1.0

    # zero token corruption: every stream byte-equals the oracle's
    got = {rid: list(e.tokens) for rid, e in journal.items()}
    assert got == oracle, (kind, got, oracle)
    assert all(e.done for e in journal.values())
    # the in-flight streams actually recovered (bounded, nonzero)
    requeued = [e for e in journal.values() if e.requeues]
    assert requeued, kind
    assert all(e.replicas[-1] == 1 for e in requeued)   # moved to survivor
    assert 0 < router.recovery_steps() <= 16


def test_fault_free_fleet_full_availability(fleet):
    cfg, engines, trace, oracle = fleet
    router = Router(engines, prompt_cap=8, max_new_cap=8)
    journal = router.run(
        [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])
    assert router.availability() == 1.0
    assert router.recovery_steps() == 0
    assert not router.detections
    assert all(not e.requeues for e in journal.values())
    # queue-depth-aware dispatch actually used both replicas
    used = {r_idx for e in journal.values() for r_idx in e.replicas}
    assert used == {0, 1}


# ---------------------------------------------------------------------------
# Property: ANY fault schedule → survivor streams token-equal to oracle
# ---------------------------------------------------------------------------
@st.composite
def fault_schedules(draw):
    n = draw(st.integers(1, 3))
    specs = [FaultSpec(kind=draw(st.sampled_from(FAULT_KINDS)),
                       step=draw(st.integers(0, 6)),
                       target=draw(st.integers(0, 1)),
                       seed=draw(st.integers(0, 99)),
                       replica=0)          # replica 1 always survives
             for _ in range(n)]
    return specs, draw(st.integers(0, 2 ** 16))


_PROP_FLEET = None


def _prop_fleet():
    """Module-cached GQA replica pair shared by the property test and
    the unit tests below (fixture-free so the ``_minihyp`` shim can
    drive ``@given`` without pytest fixture plumbing)."""
    global _PROP_FLEET
    if _PROP_FLEET is None:
        _PROP_FLEET = _build_replicas("llama2-7b")
    return _PROP_FLEET


@given(fault_schedules())
@settings(max_examples=5, deadline=None)
def test_any_fault_schedule_streams_equal_oracle(sched_spec):
    cfg, engines = _prop_fleet()
    specs, seed = sched_spec
    trace = _mk_trace(cfg, seed=seed, n_req=5)
    oracle = {rid: list(e.tokens)
              for rid, e in _run(engines, trace).items()}
    tracecount.reset_signals()
    inj = FaultInjector(specs)
    journal = _run(engines, trace, injectors={0: inj})
    got = {rid: list(e.tokens) for rid, e in journal.items()}
    assert got == oracle, (specs, seed)
    assert all(e.done for e in journal.values())
    assert tracecount.signal_totals()["detect_journal_mismatch"] == 0


# ---------------------------------------------------------------------------
# Detection plumbing units
# ---------------------------------------------------------------------------
def test_check_finite_sentinel_traces_and_detects():
    """The finite guard is IN the traced step when check_finite is on
    (one ``finite_guard`` bump per admit/decode trace) and the sentinel
    leaf flags a NaN-poisoned slot on the next decode."""
    cfg, engines = _prop_fleet()
    eng = engines[0]
    assert eng.scfg.check_finite          # build_replicas defaults it ON
    B = eng.batch_global
    state = eng.retire_fn(eng.state, np.ones((B,), np.int32))
    toks = np.zeros((B, 8), np.int32)
    toks[0, :4] = [5, 6, 7, 8]
    lens = np.zeros((B,), np.int32)
    lens[0] = 4
    first, state = eng.admit_fn(eng.params["train"], state, toks, lens)
    nf = np.asarray(jax.device_get(state["nonfinite"])).reshape(-1, B)
    assert (nf == 0).all()                # healthy admit: clean sentinel
    state = corrupt_kv_slot(state, 0)
    tok_in = np.asarray(jax.device_get(first)).reshape(-1).astype(np.int32)
    _, state = eng.decode_fn(eng.params["serve"], state, tok_in)
    nf = np.asarray(jax.device_get(state["nonfinite"])).reshape(-1, B)
    assert (nf[:, 0] > 0).all()           # poisoned slot flagged …
    assert (nf[:, 1] == 0).all()          # … its neighbor clean
    # retire clears the sentinel with the slot
    state = eng.retire_fn(state, np.ones((B,), np.int32))
    nf = np.asarray(jax.device_get(state["nonfinite"])).reshape(-1, B)
    assert (nf == 0).all()


def test_check_finite_off_traces_no_guard():
    """The bench path is untouched: check_finite=False builds a decode
    step that traces ZERO finite_guard sites and carries no sentinel
    leaf."""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import EngineOptions, build_engine_full
    cfg = reduced(get_config("llama2-7b"))
    mesh = make_test_mesh(data=1, model=1)
    counts = {}
    for flag in (False, True):
        eng = build_engine_full(
            cfg, mesh, max_seq=16, batch_global=1,
            options=EngineOptions(backend="xla", check_finite=flag))
        assert ("nonfinite" in eng.state) == flag
        with tracecount.counting() as c:
            tok = np.zeros((1,), np.int32)
            eng.decode_fn(eng.params["serve"], eng.state, tok)
            counts[flag] = c.get("finite_guard", 0)
    assert counts[False] == 0
    assert counts[True] == 1


def test_injector_kill_raises_and_specs_validate():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("segfault", step=0)
    inj = FaultInjector([FaultSpec("kill", step=0)])

    class _T:                             # minimal scheduler stand-in
        tick = 0
    with pytest.raises(ReplicaKilled):
        inj.pre_step(_T())
    assert len(inj.fired) == 1


def test_router_capacity_validation():
    cfg, engines = _prop_fleet()
    with pytest.raises(ValueError, match="max_seq"):
        Router(engines, prompt_cap=30, max_new_cap=8)
    r = Router(engines, prompt_cap=8, max_new_cap=4)
    with pytest.raises(ValueError, match="max_new_cap"):
        r.submit(Request(0, [1, 2], 9))
    r.submit(Request(0, [1, 2], 3))
    with pytest.raises(ValueError, match="duplicate"):
        r.submit(Request(0, [1, 2], 3))


# ---------------------------------------------------------------------------
# Cluster 2: the same matrix over a 2-rank cluster sub-axis (both archs)
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
@pytest.mark.parametrize("arch", ["llama2-7b", "deepseek-v2-lite"],
                         ids=["gqa", "mla"])
def test_chaos_matrix_cluster2(arch):
    run_multidevice(f"""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.core import tracecount
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import EngineOptions, build_replicas
    from repro.serving.faults import FAULT_KINDS, FaultInjector, FaultSpec
    from repro.serving.router import Router
    from repro.serving.scheduler import Request

    cfg = reduced(get_config({arch!r}))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=None)
    mesh = make_test_mesh(data=1, model=2)
    engines = build_replicas(
        cfg, mesh, n_replicas=2, max_seq=32, batch_global=2,
        options=EngineOptions(backend="xla", cluster=2, check_finite=True,
                              kv_fingerprint=True, shadow_head=True))
    assert all(e.lay.cluster == 2 for e in engines)
    rng = np.random.default_rng(0)
    trace = []
    for rid in range(4):
        plen = int(rng.integers(2, 6))
        trace.append((int(rng.integers(0, 3)), Request(
            rid, [int(t) for t in rng.integers(1, cfg.vocab_size, plen)],
            int(rng.integers(2, 5)))))

    def run(injectors=None):
        return Router(engines, prompt_cap=8, max_new_cap=8,
                      injectors=injectors).run(
            [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])

    oracle = {{rid: list(e.tokens) for rid, e in run().items()}}
    for kind in FAULT_KINDS:
        tracecount.reset_signals()
        inj = FaultInjector([FaultSpec(kind, step=2, target=0, replica=0)])
        router = Router(engines, prompt_cap=8, max_new_cap=8,
                        injectors={{0: inj}})
        journal = router.run(
            [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])
        got = {{rid: list(e.tokens) for rid, e in journal.items()}}
        assert len(inj.fired) == 1, kind
        lat = router.detection_latency(inj)
        assert lat[0] in (0, 1), (kind, lat)
        assert got == oracle, (kind, got, oracle)
        assert tracecount.signal_totals()["detect_journal_mismatch"] == 0
        print("CLUSTER2 CHAOS OK", kind)
    print("CLUSTER2 MATRIX OK", {arch!r})
    """, timeout=1800)
