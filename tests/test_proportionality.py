"""Cache-length proportionality: decode cost must scale with the LIVE
prefix, not the allocated cache.

* XLA path: :func:`bucketed_flash_attention` executes exactly
  ``ceil(live / block)`` buckets (counter check) and matches the full
  masked reference.
* Pallas path: the scalar-prefetched block index maps stop advancing
  past the live prefix (clamp check on
  :func:`repro.kernels.fused_decode.fused_decode._cache_block_index`).
* Autotune: serving plans (backend + block_s) per seq bucket persist to
  the JSON table and round-trip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import (ServePlan, load_table, pick_block_s,
                                 save_table, seq_bucket, tune_serving)
from repro.core.dataflow import bucketed_flash_attention
from repro.kernels.fused_decode.fused_decode import (_cache_block_index,
                                                     _live_block_bounds)


# ---------------------------------------------------------------------------
# XLA path: bucket counter + equivalence to the masked reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("live_frac", [0.125, 0.5, 1.0])
def test_bucketed_blocks_run_proportional(live_frac):
    S, B, K, Q, hd, ab = 256, 2, 2, 2, 16, 32
    live = int(S * live_frac)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    qf = jax.random.normal(ks[0], (B, K, Q, hd))
    kc = jax.random.normal(ks[1], (S, B, K, hd)) * 0.3
    vc = jax.random.normal(ks[2], (S, B, K, hd)) * 0.3
    valid = jnp.arange(S) < live
    m, l, o, nrun = bucketed_flash_attention(
        qf, kc, vc, valid, scale=0.25, block_s=ab)
    # strictly fewer buckets at partial fill: cost ∝ live tokens
    assert int(nrun) == -(-live // ab)
    if live < S:
        assert int(nrun) < S // ab
    # equivalence to the single masked pass
    s = jnp.einsum("bkqh,sbkh->bkqs", qf, kc) * 0.25
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    m_ref = jnp.max(s, -1)
    p = jnp.exp(s - m_ref[..., None])
    l_ref = jnp.sum(p, -1)
    o_ref = jnp.einsum("bkqs,sbkh->bkqh", p, vc)
    np.testing.assert_allclose(np.asarray(o / l[..., None]),
                               np.asarray(o_ref / l_ref[..., None]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref),
                               rtol=1e-6, atol=1e-6)


def test_bucketed_skips_below_sliding_window():
    # ring-style validity: only a window in the middle is live
    S, ab = 128, 16
    valid = (jnp.arange(S) >= 48) & (jnp.arange(S) < 80)
    qf = jnp.ones((1, 1, 1, 8))
    kc = jnp.ones((S, 1, 1, 8))
    vc = jnp.ones((S, 1, 1, 8))
    *_, nrun = bucketed_flash_attention(qf, kc, vc, valid, scale=1.0,
                                        block_s=ab)
    assert int(nrun) == 2          # buckets [48:64) and [64:80) only


# ---------------------------------------------------------------------------
# Pallas path: index maps provably stop at the live prefix
# ---------------------------------------------------------------------------
def test_pallas_index_map_clamps_to_live_prefix():
    blk_s, n_blocks = 32, 16                      # S = 512 allocated
    cache_len = 64                                # live prefix: 2 blocks
    idx = [int(_cache_block_index(j, cache_len, blk_s=blk_s,
                                  n_blocks=n_blocks, window=0))
           for j in range(n_blocks + 2)]
    # steps 1, 2 fetch blocks 0, 1; every later step re-addresses block 1
    # (already resident ⇒ no new HBM copy), never advancing past the live
    # prefix.
    assert idx[1] == 0 and idx[2] == 1
    assert all(i == 1 for i in idx[3:])
    assert max(idx) == -(-cache_len // blk_s) - 1

    # full cache: maps advance across every block
    idx_full = [int(_cache_block_index(j, blk_s * n_blocks, blk_s=blk_s,
                                       n_blocks=n_blocks, window=0))
                for j in range(1, n_blocks + 1)]
    assert idx_full == list(range(n_blocks))


def test_pallas_index_map_clamps_below_window():
    # linear slot layout (standalone kernel): offsets ARE positions, so
    # the window lower bound culls whole blocks
    blk_s, n_blocks, window = 32, 8, 64           # live = last 64 positions
    cache_len = 200
    lo, hi = _live_block_bounds(cache_len, blk_s, n_blocks, window)
    assert int(lo) == (cache_len - window) // blk_s == 4
    assert int(hi) == -(-cache_len // blk_s) - 1 == 6
    idx = [int(_cache_block_index(j, cache_len, blk_s=blk_s,
                                  n_blocks=n_blocks, window=window))
           for j in range(n_blocks + 2)]
    assert min(idx) == 4 and max(idx) == 6        # dead blocks never fetched


def test_pallas_ring_mode_never_offset_culls():
    """Ring caches (serving dispatch): slot offsets are NOT positions, so
    the window bound must never cull by block offset — once the ring has
    wrapped, every resident block may hold in-window entries."""
    blk_s, n_blocks, window = 2, 4, 32            # local ring shard: 8 slots
    for cache_len in (40, 200, 10_000):           # well past window + shard
        lo, hi = _live_block_bounds(cache_len, blk_s, n_blocks, window,
                                    ring=True)
        assert int(lo) == 0 and int(hi) == n_blocks - 1
    # before the first wrap the fill-order upper bound still applies
    lo, hi = _live_block_bounds(3, blk_s, n_blocks, window, ring=True)
    assert int(lo) == 0 and int(hi) == 1          # slots 0..2 written only


def test_rank_local_bounds_skip_non_owner_shards():
    """Sharded linear cache: a rank whose shard starts past cache_len has
    no live slots — its maps pin to block 0 (one resident fetch, no
    advance) instead of streaming the whole dead shard."""
    blk_s, n_blocks = 32, 4                       # local shard: 128 slots
    cache_len = 128                               # == one full shard
    # rank 0 (pos_base 0): whole shard live
    lo, hi = _live_block_bounds(cache_len, blk_s, n_blocks, 0, pos_base=0)
    assert (int(lo), int(hi)) == (0, 3)
    # rank 1 (pos_base 128): zero live slots ⇒ only block 0 addressed
    lo, hi = _live_block_bounds(cache_len, blk_s, n_blocks, 0,
                                pos_base=128)
    assert (int(lo), int(hi)) == (0, 0)
    # rank 1, half-filled shard
    lo, hi = _live_block_bounds(192, blk_s, n_blocks, 0, pos_base=128)
    assert (int(lo), int(hi)) == (0, 1)


def test_fit_block_s_preserves_bucketing():
    from repro.core.dataflow import _fit_block_s
    assert _fit_block_s(320, 256) == 160      # divisor, not full collapse
    assert _fit_block_s(256, 256) == 256
    assert _fit_block_s(12, 256) == 12
    assert _fit_block_s(4, 2) == 2            # tiny test shards keep blocks
    assert _fit_block_s(331, 256) == 331      # prime: degenerate ⇒ single


def test_pick_block_s_respects_vmem_budget():
    from dataclasses import replace
    from repro.configs import get_config, reduced
    from repro.core.autotune import VMEM_BUDGET
    cfg = reduced(get_config("llama2-7b"))
    wide = replace(cfg, n_kv_heads=8, head_dim=128)
    b = pick_block_s(wide, 65536, 1, batch=8)
    row = 8 * 128 * 2 * 2 * 8
    assert b * row * 2 <= VMEM_BUDGET         # never silently over budget


def test_pallas_interpret_matches_at_partial_fill():
    """Clamped maps change which HBM blocks are addressed, not results:
    interpret-mode kernel at 1/8 fill equals the oracle."""
    from repro.kernels.fused_decode.ops import fused_decode, rope_at
    B, D, S, q_loc, kv_loc, hd = 2, 64, 256, 4, 2, 16
    clen = S // 8
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 6)
    P_ = (q_loc + 2 * kv_loc) * hd
    args = (jax.random.normal(ks[0], (B, D)) * 0.2,
            jax.random.normal(ks[1], (D, P_)) * 0.05, None,
            jax.random.normal(ks[2], (q_loc * hd, D)) * 0.05,
            jax.random.normal(ks[3], (S, kv_loc, hd)) * 0.3,
            jax.random.normal(ks[4], (S, kv_loc, hd)) * 0.3,
            clen, *rope_at(clen, hd))
    kw = dict(q_heads=q_loc, kv_heads=kv_loc)
    o, *_ = fused_decode(*args, **kw, interpret=True, block_s=32)
    o_r, *_ = fused_decode(*args, **kw, use_ref=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Autotune: plan selection + persisted table
# ---------------------------------------------------------------------------
def test_seq_bucket_and_block_pick():
    from repro.configs import get_config, reduced
    assert seq_bucket(1) == 256 and seq_bucket(256) == 256
    assert seq_bucket(257) == 512 and seq_bucket(40_000) == 65536
    cfg = reduced(get_config("llama2-7b"))
    b_short = pick_block_s(cfg, 256, 1)
    b_long = pick_block_s(cfg, 65536, 1)
    assert b_short <= b_long                  # longer span ⇒ ≥ block size
    assert b_long in (128, 256, 512, 1024, 2048)


def test_tune_serving_persists_table(tmp_path):
    from repro.configs import get_config, reduced
    cfg = reduced(get_config("llama2-7b"))
    path = str(tmp_path / "tune.json")
    p1 = tune_serving(cfg, seq_len=1024, batch=4, model_axis=4,
                      backend="auto", table_path=path)
    assert isinstance(p1, ServePlan)
    assert p1.backend == "pallas"             # attention model ⇒ fused path
    table = load_table(path)
    assert len(table) == 1
    # second call is a pure table hit (same plan, no re-tune)
    p2 = tune_serving(cfg, seq_len=900, batch=4, model_axis=4,
                      backend="auto", table_path=path)
    assert p2 == p1                           # same 1024 bucket
    cfg_rec = reduced(get_config("rwkv6-3b"))
    p3 = tune_serving(cfg_rec, seq_len=1024, batch=4, model_axis=4,
                      backend="auto", table_path=path)
    assert p3.backend == "xla"                # attention-free keeps XLA
    assert len(load_table(path)) == 2
    # schema-drifted entry (e.g. older/newer ServePlan) self-heals by
    # re-tuning instead of crashing the launch
    table = load_table(path)
    key = next(k for k in table if k.startswith(cfg.name))
    table[key]["bogus_field"] = 1
    save_table(path, table)
    p4 = tune_serving(cfg, seq_len=1024, batch=4, model_axis=4,
                      backend="auto", table_path=path)
    assert p4 == p1
