"""Backend dispatch parity: ``backend="pallas"`` (interpret mode on CPU)
must match ``backend="xla"`` decode outputs — dataflow-level to ≤1e-2
(bf16 caches), and engine-level greedy tokens exactly — for a GQA config
(bias + softcap + sliding-window ring cache) and an MLA config."""
import pytest

from helpers import run_multidevice

pytestmark = pytest.mark.multidevice


def test_split_token_backend_parity_gqa_window():
    # heads 2 × cluster 4 over an 8-device axis; 6 sequential decode steps
    # through a FULL cache and a sliding-window RING cache, both backends.
    run_multidevice("""
    from repro.core import dataflow as df
    from repro.core import primitives as prim
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    heads = prim.SubAxis("c", 2, minor_size=4)
    clus = prim.SubAxis("c", 4, minor_size=1)
    D, n_heads, kv_heads, hd, B, N, H = 64, 4, 2, 32, 2, 4, 2
    q_loc, kv_loc, hd_n = n_heads // H, kv_heads // H, hd // N
    # T > window + s_blk: the ring wraps AND cache_len passes the point
    # where a local-offset window cull would (wrongly) kill every block
    T, CAP = 14, 20.0
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 9)
    WQ = jax.random.normal(ks[0], (D, n_heads, hd)) * 0.05
    WK = jax.random.normal(ks[1], (D, kv_heads, hd)) * 0.05
    WV = jax.random.normal(ks[2], (D, kv_heads, hd)) * 0.05
    BQ = jax.random.normal(ks[3], (n_heads, hd)) * 0.02
    BK = jax.random.normal(ks[4], (kv_heads, hd)) * 0.02
    BV = jax.random.normal(ks[5], (kv_heads, hd)) * 0.02
    WO = jax.random.normal(ks[6], (n_heads * hd, D)) * 0.05
    XS = jax.random.normal(ks[7], (T, B, D)) * 0.3

    def body(xs, WQ, WK, WV, BQ, BK, BV, WO):
        h = prim.axis_index(heads)
        c = prim.axis_index(clus)
        sl_h = lambda a: jax.lax.dynamic_slice_in_dim(
            a, h * (a.shape[-2] // H), a.shape[-2] // H, axis=-2)
        sl_c = lambda a: jax.lax.dynamic_slice_in_dim(
            a, c * hd_n, hd_n, axis=-1)
        w = df.SplitTokenWeights(
            wq=sl_c(sl_h(WQ)), wk=sl_c(sl_h(WK)), wv=sl_c(sl_h(WV)),
            wo=jax.lax.dynamic_slice_in_dim(
                jax.lax.dynamic_slice_in_dim(
                    WO, h * q_loc * hd, q_loc * hd, axis=0),
                c * (D // N), D // N, axis=1),
            bq=sl_c(sl_h(BQ)), bk=sl_c(sl_h(BK)), bv=sl_c(sl_h(BV)))
        outs = []
        for window, s_blk in ((0, 4), (8, 2)):   # full cache + ring cache
            spec_x = df.ClusterSpec(heads=heads, cluster=clus,
                                    backend="xla", block_s=2)
            spec_p = df.ClusterSpec(heads=heads, cluster=clus,
                                    backend="pallas", interpret=True,
                                    block_s=2)
            caches = [df.KVBlock(
                k=jnp.zeros((s_blk, B * kv_loc, hd), jnp.bfloat16),
                v=jnp.zeros((s_blk, B * kv_loc, hd), jnp.bfloat16),
                pos=jnp.full((s_blk,), -1, jnp.int32)) for _ in range(2)]
            for t in range(T):
                o_x, caches[0] = df.split_token_attention(
                    spec_x, xs[t], w, caches[0], jnp.int32(t),
                    window=window, attn_softcap=CAP)
                o_p, caches[1] = df.split_token_attention(
                    spec_p, xs[t], w, caches[1], jnp.int32(t),
                    window=window, attn_softcap=CAP)
                outs.append(jnp.stack([o_x, o_p]))
        return jnp.stack(outs)[None]          # [1, 2T, 2, B, D/N]

    out = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(), P(), P()),
        out_specs=P("c"), check_vma=False))(
        XS, WQ, WK, WV, BQ, BK, BV, WO)
    out = np.asarray(out, np.float32)         # [8, 2T, 2, B, D/N]
    err = np.abs(out[:, :, 0] - out[:, :, 1]).max()
    assert err <= 1e-2, err
    print("SPLIT-TOKEN PARITY OK", err)
    """)


def test_mla_backend_parity():
    run_multidevice("""
    from repro.core import dataflow as df
    from repro.core import primitives as prim
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    heads = prim.SubAxis("c", 2, minor_size=4)
    clus = prim.SubAxis("c", 4, minor_size=1)
    D, q_heads, nope, rope, l_rank, v_dim = 64, 4, 16, 8, 32, 16
    B, N, H, T = 2, 4, 2, 6
    q_loc = q_heads // H
    nr = nope + rope
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 7)
    WQ = jax.random.normal(ks[0], (D, q_heads, nr)) * 0.05
    WDKV = jax.random.normal(ks[1], (D, l_rank + rope)) * 0.05
    WUK = jax.random.normal(ks[2], (q_heads, nope, l_rank)) * 0.05
    WUV = jax.random.normal(ks[3], (q_heads, l_rank, v_dim)) * 0.05
    WO = jax.random.normal(ks[4], (q_heads * v_dim, D)) * 0.05
    XS = jax.random.normal(ks[5], (T, B, D)) * 0.3
    s_blk = 2                                  # 4 ranks × 2 slots = 8 ≥ T

    def body(xs, WQ, WDKV, WUK, WUV, WO):
        h = prim.axis_index(heads)
        c = prim.axis_index(clus)
        dsl = jax.lax.dynamic_slice_in_dim
        wq_h = dsl(WQ, h * q_loc, q_loc, axis=1)
        wuk_h = dsl(WUK, h * q_loc, q_loc, axis=0)
        wuv_h = dsl(WUV, h * q_loc, q_loc, axis=0)
        wo_h = dsl(WO, h * q_loc * v_dim, q_loc * v_dim, axis=0)
        w = df.MLAWeights(
            wq=dsl(wq_h, c * (nr // N), nr // N, axis=2),
            wdkv=dsl(WDKV, c * ((l_rank + rope) // N),
                     (l_rank + rope) // N, axis=1),
            wuk=dsl(wuk_h, c * (l_rank // N), l_rank // N, axis=2),
            wuv=dsl(wuv_h, c * (l_rank // N), l_rank // N, axis=1),
            wo=dsl(wo_h, c * (D // N), D // N, axis=1))
        spec_x = df.ClusterSpec(heads=heads, cluster=clus,
                                backend="xla", block_s=2)
        spec_p = df.ClusterSpec(heads=heads, cluster=clus,
                                backend="pallas", interpret=True, block_s=2)
        caches = [df.KVBlock(
            k=jnp.zeros((s_blk, B, l_rank + rope), jnp.bfloat16),
            v=jnp.zeros((s_blk, B, 1), jnp.bfloat16),
            pos=jnp.full((s_blk,), -1, jnp.int32)) for _ in range(2)]
        outs = []
        for t in range(T):
            o_x, caches[0] = df.mla_attention(
                spec_x, xs[t], w, caches[0], jnp.int32(t),
                nope_dim=nope, rope_dim=rope)
            o_p, caches[1] = df.mla_attention(
                spec_p, xs[t], w, caches[1], jnp.int32(t),
                nope_dim=nope, rope_dim=rope)
            outs.append(jnp.stack([o_x, o_p]))
        return jnp.stack(outs)[None]

    out = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P(),) * 6,
        out_specs=P("c"), check_vma=False))(XS, WQ, WDKV, WUK, WUV, WO)
    out = np.asarray(out, np.float32)
    err = np.abs(out[:, :, 0] - out[:, :, 1]).max()
    assert err <= 1e-2, err
    print("MLA PARITY OK", err)
    """)


def test_prepacked_vs_adapter_parity_gqa():
    """Prepacked serve layout (fully fused partial_o Pallas path) vs the
    train-layout XLA adapter path: identical outputs over sequential
    decode steps through a FULL cache and a sliding-window RING cache,
    with GQA bias + softcap, at cluster sizes 1, 2 and 4."""
    run_multidevice("""
    from repro.core import dataflow as df
    from repro.core import primitives as prim
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    D, n_heads, kv_heads, hd, B = 64, 4, 2, 32, 2
    H = 2                                        # head-groups
    T, CAP = 14, 20.0
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 9)
    WQ = jax.random.normal(ks[0], (D, n_heads, hd)) * 0.05
    WK = jax.random.normal(ks[1], (D, kv_heads, hd)) * 0.05
    WV = jax.random.normal(ks[2], (D, kv_heads, hd)) * 0.05
    BQ = jax.random.normal(ks[3], (n_heads, hd)) * 0.02
    BK = jax.random.normal(ks[4], (kv_heads, hd)) * 0.02
    BV = jax.random.normal(ks[5], (kv_heads, hd)) * 0.02
    WO = jax.random.normal(ks[6], (n_heads * hd, D)) * 0.05
    XS = jax.random.normal(ks[7], (T, B, D)) * 0.3
    q_loc, kv_loc = n_heads // H, kv_heads // H

    for N in (1, 2, 4):
        heads = prim.SubAxis("c", H, minor_size=N)
        clus = prim.SubAxis("c", N, minor_size=1)
        hd_n = hd // N

        def body(xs, WQ, WK, WV, BQ, BK, BV, WO):
            h = prim.axis_index(heads)
            dsl = jax.lax.dynamic_slice_in_dim
            c = prim.axis_index(clus)
            sl_h = lambda a: dsl(a, h * (a.shape[-2] // H),
                                 a.shape[-2] // H, axis=-2)
            sl_c = lambda a: dsl(a, c * hd_n, hd_n, axis=-1)
            # train-layout adapter weights (per-step slicing, XLA path)
            w_x = df.SplitTokenWeights(
                wq=sl_c(sl_h(WQ)), wk=sl_c(sl_h(WK)), wv=sl_c(sl_h(WV)),
                wo=dsl(dsl(WO, h * q_loc * hd, q_loc * hd, axis=0),
                       c * (D // N), D // N, axis=1),
                bq=sl_c(sl_h(BQ)), bk=sl_c(sl_h(BK)), bv=sl_c(sl_h(BV)))
            # serve-layout prepack: gathered wqkv + fused bias + per-head
            # full-width wo rows (what serving/prepack.py materializes)
            flat = lambda a: sl_h(a).reshape(D, -1)
            wqkv = jnp.concatenate([flat(WQ), flat(WK), flat(WV)], axis=1)
            bflat = lambda a: sl_h(a[None])[0].reshape(-1)
            bqkv = jnp.concatenate([bflat(BQ), bflat(BK), bflat(BV)])
            wo3 = dsl(WO, h * q_loc * hd, q_loc * hd,
                      axis=0).reshape(q_loc, hd, D)
            w_p = df.PackedSplitTokenWeights(wqkv=wqkv, wo=wo3, bqkv=bqkv)

            spec_x = df.ClusterSpec(heads=heads, cluster=clus,
                                    backend="xla", block_s=2)
            spec_p = df.ClusterSpec(heads=heads, cluster=clus,
                                    backend="pallas", interpret=True,
                                    block_s=2)
            outs = []
            for window, s_cap in ((0, 16), (8, 8)):  # full + ring cache
                s_blk = s_cap // N
                caches = [df.KVBlock(
                    k=jnp.zeros((s_blk, B * kv_loc, hd), jnp.bfloat16),
                    v=jnp.zeros((s_blk, B * kv_loc, hd), jnp.bfloat16),
                    pos=jnp.full((s_blk,), -1, jnp.int32))
                    for _ in range(2)]
                for t in range(T):
                    o_x, caches[0] = df.split_token_attention(
                        spec_x, xs[t], w_x, caches[0], jnp.int32(t),
                        window=window, attn_softcap=CAP)
                    o_p, caches[1] = df.split_token_attention(
                        spec_p, xs[t], w_p, caches[1], jnp.int32(t),
                        window=window, attn_softcap=CAP)
                    # adapter output is cluster-tiled; packed is full [B, D]
                    o_xf = prim.cluster_gather_tiled(o_x, clus, axis=1)
                    outs.append(jnp.stack([o_xf, o_p]))
            return jnp.stack(outs)[None]          # [1, 2T, 2, B, D]

        out = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P(),) * 8,
            out_specs=P("c"), check_vma=False))(
            XS, WQ, WK, WV, BQ, BK, BV, WO)
        out = np.asarray(out, np.float32)
        err = np.abs(out[:, :, 0] - out[:, :, 1]).max()
        assert err <= 1e-2, (N, err)
        print("PREPACK PARITY OK N =", N, "err", err)
    """, timeout=1500)


def test_engine_backend_parity_tokens():
    """Full engine: greedy tokens agree between backends (GQA with
    sliding window + softcap, and MLA), pallas in interpret mode with
    the serve-layout prepack auto-enabled."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import build_engine, generate
    for arch in ("gemma2-27b", "deepseek-v2-lite"):
        cfg = reduced(get_config(arch))
        mesh = make_test_mesh()
        outs = {}
        for backend in ("xla", "pallas"):
            params, pf, dec, state, lay, scfg = build_engine(
                cfg, mesh, max_seq=48, batch_global=4, backend=backend,
                interpret=(backend == "pallas"))
            # prepack rides the auto default: on exactly for pallas
            assert scfg.prepack == (backend == "pallas"), scfg
            key = jax.random.PRNGKey(0)
            prompts = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
            toks, _ = generate(cfg, params, pf, dec, state, prompts, 5,
                               None)
            outs[backend] = np.asarray(toks)
        agree = (outs["xla"] == outs["pallas"]).mean()
        assert agree >= 0.95, (arch, agree, outs)
        print("ENGINE PARITY OK", arch, agree)
    """, timeout=1500)


def test_engine_prepack_parity_mla_cluster():
    """MLA engine at forced cluster sizes 2 and 4: prepacked Pallas
    (with the W_UV·W_O fold) matches the XLA adapter path
    token-for-token — and, at cluster 2, the non-prepacked Pallas
    path."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import build_engine, generate
    cfg = reduced(get_config("deepseek-v2-lite"))
    mesh = make_test_mesh()

    def run(cluster, **kw):
        params, pf, dec, state, lay, scfg = build_engine(
            cfg, mesh, max_seq=48, batch_global=4, cluster=cluster, **kw)
        key = jax.random.PRNGKey(0)
        prompts = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
        toks, _ = generate(cfg, params, pf, dec, state, prompts, 5, None)
        return np.asarray(toks), scfg

    for n in (2, 4):
        t_x, _ = run(n, backend="xla")
        t_p, scfg = run(n, backend="pallas", interpret=True)
        assert scfg.prepack, scfg
        agree = (t_x == t_p).mean()
        assert agree >= 0.95, (n, agree)
        print("MLA PREPACK ENGINE PARITY OK N =", n, agree)
    t_np, scfg = run(2, backend="pallas", interpret=True, prepack="off")
    assert not scfg.prepack, scfg
    t_p, _ = run(2, backend="pallas", interpret=True)
    agree = (t_np == t_p).mean()
    assert agree >= 0.95, agree
    print("MLA PREPACK-VS-ADAPTER OK", agree)
    """, timeout=1500)
