"""Property tests on the system's invariants.

Part of tier-1 (no skip): with hypothesis installed (requirements-dev
— the CI env) these shrink and explore; without it they run through
the deterministic fallback shim in ``tests/_minihyp.py`` (fixed seed,
same API subset).  CI pins determinism either way via the registered
"ci" profile (conftest.py, ``HYPOTHESIS_PROFILE=ci``).
"""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # tier-1 container: deterministic shim
    from _minihyp import given, settings, strategies as st

from repro.core.primitives import flash_merge, traffic_gather, traffic_reduce
from repro.core.dataflow import (traffic_split_head, traffic_split_token)


@st.composite
def partials(draw, hd=8):
    n = draw(st.integers(1, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    m = rng.standard_normal(n) * draw(st.floats(0.1, 10.0))
    l = rng.uniform(0.1, 5.0, n)
    o = rng.standard_normal((n, hd))
    return m, l, o


@given(partials())
@settings(max_examples=60, deadline=None)
def test_flash_merge_associative_any_split(p):
    """Online-softmax merge over (m, l, o) is associative: any split of the
    partials gives the same normalized output — THE invariant behind both
    the cluster combine (Alg. 3) and the fused kernel's grid carry."""
    m, l, o = p
    n = len(m)

    def merge_range(lo, hi):
        acc = (jnp.float32(m[lo]), jnp.float32(l[lo]),
               jnp.asarray(o[lo], jnp.float32))
        for i in range(lo + 1, hi):
            acc = flash_merge(acc, (jnp.float32(m[i]), jnp.float32(l[i]),
                                    jnp.asarray(o[i], jnp.float32)))
        return acc

    full = merge_range(0, n)
    ref = np.asarray(full[2]) / np.asarray(full[1])
    for split in range(1, n):
        a = merge_range(0, split)
        b = merge_range(split, n)
        m2, l2, o2 = flash_merge(a, b)
        np.testing.assert_allclose(np.asarray(o2) / np.asarray(l2), ref,
                                   rtol=1e-5, atol=1e-5)


@given(st.integers(1, 20), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_traffic_monotone_in_cluster_size(size_exp, n_exp):
    """Paper §3.2: both traffic formulas grow monotonically in N (the basis
    for its cluster-size trade-off)."""
    size = 2 ** size_exp
    n1, n2 = 2 ** n_exp, 2 ** (n_exp + 1)
    assert traffic_reduce(size, n2) > traffic_reduce(size, n1)
    assert traffic_gather(size, n2) > traffic_gather(size, n1)


@given(st.integers(7, 16), st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_split_token_beats_split_head_at_long_seq(s_exp, n):
    """Paper App. B conclusion: SplitHead traffic ∝ S overtakes SplitToken
    for long sequences (Fig. 20)."""
    S = 2 ** s_exp
    hd, D = 128, 4096
    st_tr = traffic_split_token(hd, D, n)
    sh_tr = traffic_split_head(S, D, n)
    if S >= 1024:
        assert sh_tr > st_tr, (S, n, sh_tr, st_tr)


@given(st.integers(0, 2 ** 31), st.integers(1, 64), st.integers(2, 512))
@settings(max_examples=25, deadline=None)
def test_online_softmax_equals_full_softmax(seed, rows, cols):
    """Chunked online softmax over arbitrary chunkings == full softmax."""
    rng = np.random.default_rng(seed)
    s = rng.standard_normal((rows, cols)).astype(np.float32) * 3
    v = rng.standard_normal((cols, 8)).astype(np.float32)
    ref = (np.exp(s - s.max(-1, keepdims=True))
           / np.exp(s - s.max(-1, keepdims=True)).sum(-1, keepdims=True)) @ v
    # random chunking
    cuts = sorted(set([0, cols] + list(rng.integers(1, cols, 3))))
    m = np.full((rows,), -np.inf, np.float32)
    l = np.zeros((rows,), np.float32)
    o = np.zeros((rows, 8), np.float32)
    for a, b in zip(cuts[:-1], cuts[1:]):
        blk = s[:, a:b]
        m_new = np.maximum(m, blk.max(-1))
        p = np.exp(blk - m_new[:, None])
        corr = np.where(np.isfinite(m), np.exp(m - m_new), 0.0)
        l = l * corr + p.sum(-1)
        o = o * corr[:, None] + p @ v[a:b]
        m = m_new
    np.testing.assert_allclose(o / l[:, None], ref, rtol=2e-4, atol=2e-4)


@given(st.integers(0, 1000), st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_data_pipeline_exact_resume(step, shard):
    """batch_at is a pure function: resume == original stream."""
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = DataConfig(vocab_size=512, seq_len=16, batch_per_shard=2)
    a = SyntheticLM(cfg, shard=shard, num_shards=4).batch_at(step)
    b = SyntheticLM(cfg, shard=shard, num_shards=4).batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards and steps differ
    c = SyntheticLM(cfg, shard=(shard + 1) % 4, num_shards=4).batch_at(step)
    assert not np.array_equal(a["tokens"], c["tokens"])


@given(st.integers(2, 40), st.integers(1, 4), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_moe_capacity_positions_are_unique_and_fifo(T, k, e_exp):
    """GShard dispatch invariant: positions within each expert are unique,
    contiguous from 0, and earlier token-slots win."""
    E = 2 ** e_exp
    rng = np.random.default_rng(T * 1000 + k * 10 + e_exp)
    flat_e = rng.integers(0, E, T * k)
    order = np.argsort(flat_e, kind="stable")
    sorted_e = flat_e[order]
    start = np.searchsorted(sorted_e, np.arange(E))
    pos_sorted = np.arange(T * k) - start[sorted_e]
    pos = np.zeros(T * k, np.int64)
    pos[order] = pos_sorted
    for e in range(E):
        ps = np.sort(pos[flat_e == e])
        np.testing.assert_array_equal(ps, np.arange(len(ps)))
        idxs = np.nonzero(flat_e == e)[0]
        # FIFO: earlier slot ⇒ smaller position
        assert (np.diff(pos[idxs]) > 0).all()


@given(st.lists(st.integers(0, 15), min_size=3, max_size=3))
@settings(max_examples=6, deadline=None)
def test_ragged_cache_lens_lockstep_equivalence(lens):
    """Ragged decode property (shrinkable): a batch of per-slot
    ``cache_lens`` through the vmapped fused kernel equals (a) the
    per-sequence scalar oracle slot by slot, and (b) when all lens are
    equal, ONE lockstep batched kernel call — the ragged path is a
    strict generalization of lockstep decode."""
    from repro.kernels.fused_decode.ops import fused_decode, rope_at
    B, D, S, q_loc, kv_loc, hd = 3, 16, 16, 2, 1, 8
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((B, D)) * 0.2, jnp.float32)
    wqkv = jnp.asarray(rng.standard_normal((D, (q_loc + 2 * kv_loc) * hd))
                       * 0.05, jnp.float32)
    wo = jnp.asarray(rng.standard_normal((q_loc * hd, D)) * 0.05,
                     jnp.float32)
    kc = jnp.asarray(rng.standard_normal((S, kv_loc, hd)) * 0.3,
                     jnp.float32)
    vc = jnp.asarray(rng.standard_normal((S, kv_loc, hd)) * 0.3,
                     jnp.float32)
    kw = dict(q_heads=q_loc, kv_heads=kv_loc, interpret=True, block_s=4)

    def one(xb, cl, cosb, sinb):
        return fused_decode(xb[None], wqkv, None, wo, kc, vc, cl,
                            cosb, sinb, **kw)[0][0]

    def ragged(lens_v):
        cls = jnp.asarray(lens_v, jnp.int32)
        cos, sin = rope_at(cls, hd)
        return jax.vmap(one, in_axes=(0, 0, 0, 0))(x, cls, cos, sin)

    # (a) slot-by-slot per-sequence oracle
    o_rag = ragged(lens)
    for b, L in enumerate(lens):
        cos, sin = rope_at(jnp.int32(L), hd)
        o_b = fused_decode(x[b:b + 1], wqkv, None, wo, kc, vc,
                           jnp.int32(L), cos, sin, **kw)[0]
        np.testing.assert_allclose(np.asarray(o_rag[b]),
                                   np.asarray(o_b[0]),
                                   rtol=2e-5, atol=2e-5)
    # (b) all-equal cache_lens ≡ one lockstep batched call
    L = lens[0]
    cos, sin = rope_at(jnp.int32(L), hd)
    o_lock = fused_decode(x, wqkv, None, wo, kc, vc, jnp.int32(L),
                          cos, sin, **kw)[0]
    np.testing.assert_allclose(np.asarray(ragged([L] * B)),
                               np.asarray(o_lock),
                               rtol=2e-5, atol=2e-5)


def test_elastic_reshard_roundtrip():
    from repro.checkpoint.manager import _reshard_leaf
    a = np.arange(32).reshape(8, 4).astype(np.float32)
    down = _reshard_leaf(a, (4, 4))
    np.testing.assert_array_equal(down, a[:4])
    up = _reshard_leaf(down, (8, 4))
    assert up.shape == (8, 4)
