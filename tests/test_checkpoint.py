"""Checkpoint manager: atomic commit, keep-k, async, resume, elastic,
and loud restore-time validation (shape/dtype per leaf, truncation)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, CheckpointMismatch


def _tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(6.0), "d": jnp.int32(seed)}}


def test_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in (10, 20, 30):
        mgr.save(step, _tree(step))
    assert mgr.latest_step() == 30
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000020", "step_00000030"]      # keep-k GC'd 10
    restored, _ = mgr.restore(_tree(0))
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(_tree(30)["a"]))


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    t = _tree(7)
    mgr.save(1, t)
    mgr.wait()
    restored, extra = mgr.restore(t)
    np.testing.assert_allclose(np.asarray(restored["b"]["c"]),
                               np.asarray(t["b"]["c"]))


def test_crash_during_save_leaves_prior_intact(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree(1), extra={"step": 1})
    # simulate a crashed save: stale .tmp directory
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert mgr.latest_step() == 1                            # tmp ignored
    restored, extra = mgr.restore(_tree(0))
    assert extra["step"] == 1


def test_elastic_restore_dp_change(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    big = {"w": jnp.arange(32.0).reshape(8, 4)}
    mgr.save(5, big)
    small = {"w": jnp.zeros((4, 4))}
    restored, _ = mgr.restore_elastic(small)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(16.0).reshape(4, 4))


def test_restore_shape_mismatch_names_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree(3))
    bad = _tree(0)
    bad["b"]["c"] = jnp.arange(9.0)           # 6 → 9 elements
    with pytest.raises(CheckpointMismatch) as ei:
        mgr.restore(bad)
    # names the PATH of the first mismatched leaf, not just an index
    assert "'c'" in str(ei.value) and "shape" in str(ei.value)


def test_restore_dtype_mismatch_names_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree(3))
    bad = _tree(0)
    bad["a"] = bad["a"].astype(jnp.bfloat16)
    with pytest.raises(CheckpointMismatch) as ei:
        mgr.restore(bad)
    assert "'a'" in str(ei.value) and "dtype" in str(ei.value)
    assert "bfloat16" in str(ei.value)


def test_restore_leaf_count_drift_fails(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree(3))
    bad = _tree(0)
    bad["extra_leaf"] = jnp.zeros((2,))
    with pytest.raises(CheckpointMismatch, match="structure drift"):
        mgr.restore(bad)


def test_truncated_checkpoint_fails_loudly(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree(3))
    os.remove(tmp_path / "step_00000001" / "leaf_00002.npy")
    with pytest.raises(CheckpointMismatch, match="missing"):
        mgr.restore(_tree(0))


def test_corrupted_leaf_fails_loudly(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree(3))
    with open(tmp_path / "step_00000001" / "leaf_00000.npy", "wb") as f:
        f.write(b"\x93NUMPY garbage that is not a valid npy payload")
    with pytest.raises(CheckpointMismatch, match="unreadable"):
        mgr.restore(_tree(0))


@pytest.mark.multidevice
def test_train_driver_resume(tmp_path):
    """End-to-end: train 10 steps w/ checkpoints, kill, resume — the loss
    stream continues from the same data position (exact resume)."""
    from helpers import run_multidevice
    run_multidevice(f"""
    from repro.launch.train import run
    l1 = run("minitron-4b", steps=12, ckpt_dir={str(tmp_path)!r},
             log_every=100)
    # fresh process state: resume and compare overlap determinism
    l2 = run("minitron-4b", steps=4, ckpt_dir={str(tmp_path)!r},
             log_every=100)
    print("RESUME OK", l1[-1], l2[0])
    assert abs(l1[-1] - l2[0]) < 1.0   # continues training (same scale)
    """, timeout=1200)


def test_straggler_monitor():
    from repro.launch.train import StragglerMonitor
    mon = StragglerMonitor()
    flags = [mon.record(0.1) for _ in range(20)]
    assert not any(flags)
    assert mon.record(0.5)              # 5× p50 flagged
    assert not mon.record(0.1)
