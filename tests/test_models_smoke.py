"""Per-architecture smoke tests: reduced config, one forward + loss on CPU,
shape and NaN checks (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, reduced, shapes_for
from repro.models import (forward, init_logical, layout_for, loss_fn,
                          single_device_ctx, to_device_major, unwrap_local)

ARCHS = list_archs()


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, key):
    cfg = reduced(get_config(arch))
    logical = init_logical(cfg, key)
    local = unwrap_local(to_device_major(cfg, layout_for(cfg, 1), logical))
    ctx = single_device_ctx()
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(key, (B, cfg.frontend.num_positions,
                                     cfg.frontend.feature_dim), jnp.float32)
    h = forward(ctx, cfg, local, tokens, fe, remat=False)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(h.astype(jnp.float32))))
    nll, cnt = loss_fn(ctx, cfg, local,
                       {"tokens": tokens, "targets": tokens,
                        "frontend_embeds": fe}, remat=False)
    loss = float(nll / cnt)
    assert 0.0 < loss < 20.0, loss


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_single_device(arch, key):
    """One real optimizer step on one device: loss finite, grads flow."""
    from repro.training.optimizer import OptConfig
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)
    from repro.models import make_train_ctx
    cfg = reduced(get_config(arch))
    lay = layout_for(cfg, 1)
    dm = to_device_major(cfg, lay, init_logical(cfg, key))
    ctx = make_train_ctx(model_size=1, data=())
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3), zero1=False)
    step = make_train_step(ctx, cfg, tcfg, (), 1)
    opt, ef = init_train_state(cfg, tcfg, dm, 1)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.frontend is not None:
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.frontend.num_positions, cfg.frontend.feature_dim))
    new_p, new_opt, _, metrics = jax.jit(
        lambda p, o, b: step(p, o, None, b))(dm, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), dm, new_p)
    assert max(jax.tree.leaves(moved)) > 0


def test_long_500k_applicability():
    """long_500k runs only for sub-quadratic-context archs."""
    for arch in ARCHS:
        cfg = get_config(arch)
        names = [s.name for s in shapes_for(cfg)]
        if arch in ("recurrentgemma-9b", "rwkv6-3b"):
            assert "long_500k" in names, arch
        else:
            assert "long_500k" not in names, arch


def test_param_counts_match_published():
    expect = {
        "kimi-k2-1t-a32b": (1.00e12, 1.10e12),
        "arctic-480b": (4.5e11, 5.0e11),
        "qwen2-72b": (7.1e10, 7.4e10),
        "gemma2-27b": (2.6e10, 2.85e10),
        "granite-8b": (7.7e9, 8.4e9),
        "llama2-7b": (6.5e9, 7.0e9),
        "rwkv6-3b": (2.6e9, 3.2e9),
        "recurrentgemma-9b": (8.8e9, 10.0e9),
        "minitron-4b": (4.0e9, 4.4e9),
        "internvl2-2b": (1.7e9, 2.1e9),
        "deepseek-v2-lite": (1.5e10, 1.65e10),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
