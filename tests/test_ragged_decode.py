"""Ragged decode vs the dense per-sequence oracle.

Every slot of a ragged batch (per-slot ``cache_lens``, staggered
activation, inactive −1 slots) must match a DENSE lockstep run of that
sequence alone through the legacy scalar-``cache_len`` path — for each
kernel (``fused_decode`` / ``fused_mla_decode`` / ``flash_decode``), on
both backends, at cluster sizes {1, 2, 4}, for global caches and
sliding-window ring caches past the wrap threshold (satellite of
ISSUE 3; DESIGN.md §6).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_multidevice


# ---------------------------------------------------------------------------
# Single-device (cluster 1) fast checks — run in the tier-1 `fast` job
# ---------------------------------------------------------------------------
def _staggered_inputs(rng, T, B, D):
    """xs_r[t, b] = the input slot b sees at global tick t (slot b joins
    at tick starts[b]); xs_o[b, i] = its dense per-sequence stream."""
    starts = [0, T // 3, 2 * T // 3]
    xs_o = rng.standard_normal((B, T, D)).astype(np.float32) * 0.3
    xs_r = np.zeros((T, B, D), np.float32)
    for b, s0 in enumerate(starts):
        for t in range(s0, T):
            xs_r[t, b] = xs_o[b, t - s0]
    return starts, jnp.asarray(xs_r), jnp.asarray(xs_o)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("window,s_blk", [(0, 16), (6, 8)])
def test_split_token_ragged_matches_per_sequence(backend, window, s_blk):
    from repro.core import dataflow as df
    D, n_heads, kv_heads, hd, B, T = 32, 2, 1, 16, 3, 12
    rng = np.random.default_rng(0)
    w = df.SplitTokenWeights(
        wq=jnp.asarray(rng.standard_normal((D, n_heads, hd)) * 0.05,
                       jnp.float32),
        wk=jnp.asarray(rng.standard_normal((D, kv_heads, hd)) * 0.05,
                       jnp.float32),
        wv=jnp.asarray(rng.standard_normal((D, kv_heads, hd)) * 0.05,
                       jnp.float32),
        wo=jnp.asarray(rng.standard_normal((n_heads * hd, D)) * 0.05,
                       jnp.float32))
    starts, xs_r, xs_o = _staggered_inputs(rng, T, B, D)
    spec = df.ClusterSpec(heads="model", cluster="model", backend=backend,
                          interpret=True, block_s=2)
    mesh = jax.make_mesh((1,), ("model",))
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    def step(x, cache, cl):
        return df.split_token_attention(spec, x, w, cache, cl,
                                        window=window)

    f = jax.jit(shard_map(step, mesh=mesh, in_specs=(P(), P(), P()),
                          out_specs=(P(), P()), check_vma=False))

    def fresh(b_n, ragged):
        return df.KVBlock(
            k=jnp.zeros((s_blk, b_n * kv_heads, hd), jnp.bfloat16),
            v=jnp.zeros((s_blk, b_n * kv_heads, hd), jnp.bfloat16),
            pos=jnp.full((s_blk, b_n) if ragged else (s_blk,), -1,
                         jnp.int32))

    # ragged run with staggered activation (inactive slots at −1)
    cache = fresh(B, ragged=True)
    cl = jnp.full((B,), -1, jnp.int32)
    outs = []
    for t in range(T):
        act = jnp.asarray([t >= s0 for s0 in starts])
        cl = jnp.where(act & (cl < 0), 0, cl)
        o, cache = f(xs_r[t], cache, cl)
        outs.append(np.asarray(o, np.float32))
        cl = jnp.where(cl >= 0, cl + 1, cl)
    assert int(max(np.asarray(cl))) == T            # longest slot: full T

    # dense per-sequence oracle: scalar cache_len, 1-D pos (legacy path)
    for b, s0 in enumerate(starts):
        cache_b = fresh(1, ragged=False)
        for i in range(T - s0):
            o_b, cache_b = f(xs_o[b, i:i + 1], cache_b, jnp.int32(i))
            np.testing.assert_allclose(
                outs[s0 + i][b], np.asarray(o_b[0], np.float32),
                rtol=2e-2, atol=2e-2,
                err_msg=f"slot {b} step {i} ({backend}, window={window})")


@pytest.mark.parametrize("window", [0, 32])
def test_flash_decode_ragged_vmap_matches_ref(window):
    """Per-slot cache_lens (incl. 0 and full) through a vmapped
    ``flash_decode`` vs the per-sequence reference."""
    from repro.kernels.flash_decode.ops import flash_decode
    B, S, q_loc, kv_loc, hd = 4, 64, 4, 2, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, q_loc, hd)) * 0.3, jnp.float32)
    kc = jnp.asarray(rng.standard_normal((S, B, kv_loc, hd)) * 0.3,
                     jnp.float32)
    vc = jnp.asarray(rng.standard_normal((S, B, kv_loc, hd)) * 0.3,
                     jnp.float32)
    lens = jnp.asarray([0, 17, 40, S], jnp.int32)    # 0 and full included

    def one(qb, kb, vb, cl, use_ref):
        return flash_decode(qb[None], kb, vb, cl, window=window,
                            block_s=16, interpret=True, use_ref=use_ref)[0]

    o_rag = jax.vmap(lambda *a: one(*a, False),
                     in_axes=(0, 1, 1, 0))(q, kc, vc, lens)
    for b in range(B):
        if int(lens[b]) == 0:      # empty slot: kernel emits zeros (the
            assert not np.any(np.asarray(o_rag[b]))   # ref softmax NaNs)
            continue
        o_ref = one(q[b], kc[:, b], vc[:, b], lens[b], True)
        np.testing.assert_allclose(np.asarray(o_rag[b]), np.asarray(o_ref),
                                   rtol=3e-5, atol=3e-5, err_msg=f"slot {b}")


# ---------------------------------------------------------------------------
# Cluster {1, 2, 4} sweeps — 8 emulated devices in a subprocess
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_split_token_ragged_cluster_sweep():
    """GQA ragged decode (bias + softcap, global + RING cache past the
    wrap threshold) vs the dense per-sequence lockstep oracle, at
    cluster sizes 1, 2, 4, backends xla + pallas."""
    run_multidevice("""
    from repro.core import dataflow as df
    from repro.core import primitives as prim
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    D, n_heads, kv_heads, hd, B, H = 64, 4, 2, 32, 3, 2
    T, CAP = 12, 20.0
    rng = np.random.default_rng(0)
    WQ = jnp.asarray(rng.standard_normal((D, n_heads, hd)) * 0.05,
                     jnp.float32)
    WK = jnp.asarray(rng.standard_normal((D, kv_heads, hd)) * 0.05,
                     jnp.float32)
    WV = jnp.asarray(rng.standard_normal((D, kv_heads, hd)) * 0.05,
                     jnp.float32)
    BQ = jnp.asarray(rng.standard_normal((n_heads, hd)) * 0.02, jnp.float32)
    BK = jnp.asarray(rng.standard_normal((kv_heads, hd)) * 0.02, jnp.float32)
    BV = jnp.asarray(rng.standard_normal((kv_heads, hd)) * 0.02, jnp.float32)
    WO = jnp.asarray(rng.standard_normal((n_heads * hd, D)) * 0.05,
                     jnp.float32)
    starts = [0, 4, 8]
    XS_O = rng.standard_normal((B, T, D)).astype(np.float32) * 0.3
    XS_R = np.zeros((T, B, D), np.float32)
    for b, s0 in enumerate(starts):
        XS_R[s0:, b] = XS_O[b, :T - s0]
    XS_R, XS_O = jnp.asarray(XS_R), jnp.asarray(XS_O)
    q_loc, kv_loc = n_heads // H, kv_heads // H

    for N in (1, 2, 4):
        heads = prim.SubAxis("c", H, minor_size=N)
        clus = prim.SubAxis("c", N, minor_size=1)
        hd_n = hd // N

        def body(xs_r, xs_o, WQ, WK, WV, BQ, BK, BV, WO):
            h = prim.axis_index(heads)
            c = prim.axis_index(clus)
            dsl = jax.lax.dynamic_slice_in_dim
            sl_h = lambda a: dsl(a, h * (a.shape[-2] // H),
                                 a.shape[-2] // H, axis=-2)
            sl_c = lambda a: dsl(a, c * hd_n, hd_n, axis=-1)
            w = df.SplitTokenWeights(
                wq=sl_c(sl_h(WQ)), wk=sl_c(sl_h(WK)), wv=sl_c(sl_h(WV)),
                wo=dsl(dsl(WO, h * q_loc * hd, q_loc * hd, axis=0),
                       c * (D // N), D // N, axis=1),
                bq=sl_c(sl_h(BQ)), bk=sl_c(sl_h(BK)), bv=sl_c(sl_h(BV)))
            specs = {
                "xla": df.ClusterSpec(heads=heads, cluster=clus,
                                      backend="xla", block_s=2),
                "pallas": df.ClusterSpec(heads=heads, cluster=clus,
                                         backend="pallas", interpret=True,
                                         block_s=2)}
            rag_all, orc_all = [], []
            # T > window + shard: the ring wraps during the sweep; slot 0
            # reaches the FULL global cache (T == s_cap) by the last step
            for window, s_cap in ((0, 12), (8, 8)):
                s_blk = s_cap // N
                # ragged staggered runs, both backends
                for bk in ("xla", "pallas"):
                    cache = df.KVBlock(
                        k=jnp.zeros((s_blk, B * kv_loc, hd), jnp.bfloat16),
                        v=jnp.zeros((s_blk, B * kv_loc, hd), jnp.bfloat16),
                        pos=jnp.full((s_blk, B), -1, jnp.int32))
                    cl = jnp.full((B,), -1, jnp.int32)
                    o_r = []
                    for t in range(T):
                        act = jnp.asarray([t >= s0 for s0 in starts])
                        cl = jnp.where(act & (cl < 0), 0, cl)
                        o, cache = df.split_token_attention(
                            specs[bk], xs_r[t], w, cache, cl,
                            window=window, attn_softcap=CAP)
                        o_r.append(prim.cluster_gather_tiled(o, clus,
                                                             axis=1))
                        cl = jnp.where(cl >= 0, cl + 1, cl)
                    rag_all.append(jnp.stack(o_r))
                # dense per-sequence lockstep oracle, ONCE (scalar-path
                # xla — backend-independent ground truth)
                o_o = []
                for b in range(B):
                    cache_b = df.KVBlock(
                        k=jnp.zeros((s_blk, kv_loc, hd), jnp.bfloat16),
                        v=jnp.zeros((s_blk, kv_loc, hd), jnp.bfloat16),
                        pos=jnp.full((s_blk,), -1, jnp.int32))
                    per = []
                    for i in range(T):
                        ob, cache_b = df.split_token_attention(
                            specs["xla"], xs_o[b, i:i + 1], w, cache_b,
                            jnp.int32(i), window=window,
                            attn_softcap=CAP)
                        per.append(prim.cluster_gather_tiled(
                            ob, clus, axis=1)[0])
                    o_o.append(jnp.stack(per))
                orc_all.append(jnp.stack(o_o))
            # rag_all: 4 × [T, B, D] (2 cache kinds × 2 backends);
            # orc_all: 2 × [B, T, D] (per cache kind)
            return jnp.stack(rag_all)[None], jnp.stack(orc_all)[None]

        rag, orc = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P(),) * 9,
            out_specs=(P("c"), P("c")), check_vma=False))(
            XS_R, XS_O, WQ, WK, WV, BQ, BK, BV, WO)
        rag = np.asarray(rag, np.float32)   # [8, 4, T, B, D]
        orc = np.asarray(orc, np.float32)   # [8, 2, B, T, D]
        for ci in range(4):                 # (kind, backend) pairs
            for b, s0 in enumerate(starts):
                got = rag[:, ci, s0:, b]
                want = orc[:, ci // 2, b, :T - s0]
                err = np.abs(got - want).max()
                assert err <= 2e-2, (N, ci, b, err)
        print("RAGGED GQA OK N =", N)
    """, timeout=1800)


@pytest.mark.multidevice
def test_mla_ragged_cluster_sweep():
    """MLA ragged decode vs the dense per-sequence oracle at cluster
    sizes 1, 2, 4, backends xla + pallas."""
    run_multidevice("""
    from repro.core import dataflow as df
    from repro.core import primitives as prim
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    D, q_heads, nope, rope, l_rank, v_dim = 64, 4, 16, 8, 32, 16
    B, H, T = 3, 2, 10
    q_loc = q_heads // H
    nr = nope + rope
    rng = np.random.default_rng(2)
    WQ = jnp.asarray(rng.standard_normal((D, q_heads, nr)) * 0.05,
                     jnp.float32)
    WDKV = jnp.asarray(rng.standard_normal((D, l_rank + rope)) * 0.05,
                       jnp.float32)
    WUK = jnp.asarray(rng.standard_normal((q_heads, nope, l_rank)) * 0.05,
                      jnp.float32)
    WUV = jnp.asarray(rng.standard_normal((q_heads, l_rank, v_dim)) * 0.05,
                      jnp.float32)
    WO = jnp.asarray(rng.standard_normal((q_heads * v_dim, D)) * 0.05,
                     jnp.float32)
    starts = [0, 3, 7]
    XS_O = rng.standard_normal((B, T, D)).astype(np.float32) * 0.3
    XS_R = np.zeros((T, B, D), np.float32)
    for b, s0 in enumerate(starts):
        XS_R[s0:, b] = XS_O[b, :T - s0]
    XS_R, XS_O = jnp.asarray(XS_R), jnp.asarray(XS_O)

    for N in (1, 2, 4):
        heads = prim.SubAxis("c", H, minor_size=N)
        clus = prim.SubAxis("c", N, minor_size=1)
        s_blk = 16 // N

        def body(xs_r, xs_o, WQ, WDKV, WUK, WUV, WO):
            h = prim.axis_index(heads)
            c = prim.axis_index(clus)
            dsl = jax.lax.dynamic_slice_in_dim
            wq_h = dsl(WQ, h * q_loc, q_loc, axis=1)
            wuk_h = dsl(WUK, h * q_loc, q_loc, axis=0)
            wuv_h = dsl(WUV, h * q_loc, q_loc, axis=0)
            wo_h = dsl(WO, h * q_loc * v_dim, q_loc * v_dim, axis=0)
            w = df.MLAWeights(
                wq=dsl(wq_h, c * (nr // N), nr // N, axis=2),
                wdkv=dsl(WDKV, c * ((l_rank + rope) // N),
                         (l_rank + rope) // N, axis=1),
                wuk=dsl(wuk_h, c * (l_rank // N), l_rank // N, axis=2),
                wuv=dsl(wuv_h, c * (l_rank // N), l_rank // N, axis=1),
                wo=dsl(wo_h, c * (D // N), D // N, axis=1))
            specs = {
                "xla": df.ClusterSpec(heads=heads, cluster=clus,
                                      backend="xla", block_s=2),
                "pallas": df.ClusterSpec(heads=heads, cluster=clus,
                                         backend="pallas", interpret=True,
                                         block_s=2)}
            outs = []
            for bk in ("xla", "pallas"):
                cache = df.KVBlock(
                    k=jnp.zeros((s_blk, B, l_rank + rope), jnp.bfloat16),
                    v=jnp.zeros((s_blk, B, 1), jnp.bfloat16),
                    pos=jnp.full((s_blk, B), -1, jnp.int32))
                cl = jnp.full((B,), -1, jnp.int32)
                o_r = []
                for t in range(T):
                    act = jnp.asarray([t >= s0 for s0 in starts])
                    cl = jnp.where(act & (cl < 0), 0, cl)
                    o, cache = df.mla_attention(
                        specs[bk], xs_r[t], w, cache, cl,
                        nope_dim=nope, rope_dim=rope)
                    o_r.append(prim.cluster_gather_tiled(o, clus, axis=1))
                    cl = jnp.where(cl >= 0, cl + 1, cl)
                o_o = []
                for b in range(B):
                    cache_b = df.KVBlock(
                        k=jnp.zeros((s_blk, 1, l_rank + rope),
                                    jnp.bfloat16),
                        v=jnp.zeros((s_blk, 1, 1), jnp.bfloat16),
                        pos=jnp.full((s_blk,), -1, jnp.int32))
                    per = []
                    for i in range(T):
                        ob, cache_b = df.mla_attention(
                            specs[bk], xs_o[b, i:i + 1], w, cache_b,
                            jnp.int32(i), nope_dim=nope, rope_dim=rope)
                        per.append(prim.cluster_gather_tiled(
                            ob, clus, axis=1)[0])
                    o_o.append(jnp.stack(per))
                outs.append((jnp.stack(o_r), jnp.stack(o_o)))
            return (jnp.stack([a for a, _ in outs])[None],
                    jnp.stack([o for _, o in outs])[None])

        rag, orc = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P(),) * 7,
            out_specs=(P("c"), P("c")), check_vma=False))(
            XS_R, XS_O, WQ, WDKV, WUK, WUV, WO)
        rag = np.asarray(rag, np.float32)   # [8, 2, T, B, D]
        orc = np.asarray(orc, np.float32)   # [8, 2, B, T, D]
        for ci in range(2):
            for b, s0 in enumerate(starts):
                err = np.abs(rag[:, ci, s0:, b]
                             - orc[:, ci, b, :T - s0]).max()
                assert err <= 2e-2, (N, ci, b, err)
        print("RAGGED MLA OK N =", N)
    """, timeout=1800)


@pytest.mark.multidevice
def test_flash_decode_ragged_cluster_shards():
    """flash_decode over cluster-sharded caches: each rank runs the
    vmapped ragged kernel on its sequence shard with rank-local per-slot
    live spans and must match the per-sequence reference on that shard,
    at cluster sizes 1, 2, 4."""
    run_multidevice("""
    from repro.kernels.flash_decode.ops import flash_decode
    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    B, S, q_loc, kv_loc, hd = 3, 32, 2, 1, 16
    rng = np.random.default_rng(3)
    Q = jnp.asarray(rng.standard_normal((B, q_loc, hd)) * 0.3, jnp.float32)
    KC = jnp.asarray(rng.standard_normal((S, B, kv_loc, hd)) * 0.3,
                     jnp.float32)
    VC = jnp.asarray(rng.standard_normal((S, B, kv_loc, hd)) * 0.3,
                     jnp.float32)
    LENS = jnp.asarray([0, 13, S], jnp.int32)

    for N in (1, 2, 4):
        s_blk = S // N

        def body(q, kc, vc, lens):
            rank = jax.lax.axis_index("c") % N
            shard_k = jax.lax.dynamic_slice_in_dim(kc, rank * s_blk,
                                                   s_blk, axis=0)
            shard_v = jax.lax.dynamic_slice_in_dim(vc, rank * s_blk,
                                                   s_blk, axis=0)
            eff = jnp.clip(lens - rank * s_blk, 0, s_blk)

            def one(qb, kb, vb, cl, use_ref):
                return flash_decode(qb[None], kb, vb, cl, block_s=8,
                                    interpret=True, use_ref=use_ref)[0]

            o_rag = jax.vmap(lambda *a: one(*a, False),
                             in_axes=(0, 1, 1, 0))(q, shard_k, shard_v,
                                                   eff)
            o_ref = jnp.stack([one(q[b], shard_k[:, b], shard_v[:, b],
                                   eff[b], True) for b in range(B)])
            return o_rag[None], o_ref[None]

        o_rag, o_ref = jax.jit(shard_map(
            body, mesh=mesh, in_specs=(P(),) * 4,
            out_specs=(P("c"), P("c")), check_vma=False))(Q, KC, VC, LENS)
        o_rag, o_ref = np.asarray(o_rag), np.asarray(o_ref)
        assert np.isfinite(o_rag).all(), N   # empty shards emit 0, not NaN
        # the ref softmax NaNs on empty rank-local spans where the kernel
        # correctly emits zeros — normalize before comparing
        err = np.abs(o_rag - np.nan_to_num(o_ref)).max()
        assert err <= 3e-5, (N, err)
        print("RAGGED FLASH OK N =", N, err)
    """, timeout=1200)
