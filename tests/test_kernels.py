"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_decode.ops import fused_decode, rope_at
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.fused_mla_decode.ops import fused_mla_decode
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rwkv6_scan.ops import rwkv6_scan


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("B,D,S,q_loc,kv_loc,hd", [
    (2, 128, 512, 4, 2, 32),
    (4, 256, 1024, 4, 1, 64),     # MQA
    (1, 64, 256, 8, 8, 16),       # MHA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cache_len", [0, 100, -1])
def test_fused_decode_sweep(B, D, S, q_loc, kv_loc, hd, dtype, cache_len):
    cache_len = S - 1 if cache_len < 0 else min(cache_len, S - 1)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    P_ = (q_loc + 2 * kv_loc) * hd
    x = (jax.random.normal(ks[0], (B, D)) * 0.2).astype(dtype)
    wqkv = (jax.random.normal(ks[1], (D, P_)) * 0.05).astype(dtype)
    bqkv = (jax.random.normal(ks[2], (P_,)) * 0.01).astype(dtype)
    wo = (jax.random.normal(ks[3], (q_loc * hd, D)) * 0.05).astype(dtype)
    kc = (jax.random.normal(ks[4], (S, kv_loc, hd)) * 0.3).astype(dtype)
    vc = (jax.random.normal(ks[5], (S, kv_loc, hd)) * 0.3).astype(dtype)
    cos, sin = rope_at(cache_len, hd)
    args = (x, wqkv, bqkv, wo, kc, vc, cache_len, cos, sin)
    kw = dict(q_heads=q_loc, kv_heads=kv_loc)
    o, kn, vn, m, l = fused_decode(*args, **kw, interpret=True, block_s=128)
    o_r, kn_r, vn_r, m_r, l_r = fused_decode(*args, **kw, use_ref=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_r, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_r),
                               rtol=1e-2, atol=1e-2)
    np.testing.assert_allclose(np.asarray(kn, np.float32),
                               np.asarray(kn_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,cap", [(0, 0.0), (128, 0.0), (0, 30.0)])
def test_fused_decode_window_softcap(window, cap):
    B, D, S, q_loc, kv_loc, hd = 2, 128, 512, 4, 2, 32
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 8)
    P_ = (q_loc + 2 * kv_loc) * hd
    args = ((jax.random.normal(ks[0], (B, D)) * 0.2),
            jax.random.normal(ks[1], (D, P_)) * 0.05, None,
            jax.random.normal(ks[3], (q_loc * hd, D)) * 0.05,
            jax.random.normal(ks[4], (S, kv_loc, hd)) * 0.3,
            jax.random.normal(ks[5], (S, kv_loc, hd)) * 0.3,
            300, *rope_at(300, hd))
    kw = dict(q_heads=q_loc, kv_heads=kv_loc, window=window,
              attn_softcap=cap)
    o, *_ = fused_decode(*args, **kw, interpret=True, block_s=128)
    o_r, *_ = fused_decode(*args, **kw, use_ref=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=3e-5, atol=3e-5)


def test_fused_decode_partial_mode_combines():
    """fuse_out=False partials combine across a 2-way split of the KV
    sequence to the same answer as the monolithic kernel — the cross-chip
    ClusterReduce property (paper Alg. 3)."""
    from repro.core.primitives import flash_merge
    B, D, S, q_loc, kv_loc, hd = 2, 128, 512, 4, 2, 32
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 8)
    P_ = (q_loc + 2 * kv_loc) * hd
    x = jax.random.normal(ks[0], (B, D)) * 0.2
    wqkv = jax.random.normal(ks[1], (D, P_)) * 0.05
    wo = jax.random.normal(ks[3], (q_loc * hd, D)) * 0.05
    kc = jax.random.normal(ks[4], (S, kv_loc, hd)) * 0.3
    vc = jax.random.normal(ks[5], (S, kv_loc, hd)) * 0.3
    clen = 400
    cos, sin = rope_at(clen, hd)
    kw = dict(q_heads=q_loc, kv_heads=kv_loc)
    o_full, *_ = fused_decode(x, wqkv, None, wo, kc, vc, clen, cos, sin,
                              **kw, use_ref=True)
    # split: first half of the cache on "chip 0" (plus the new token),
    # second half on "chip 1"
    h = S // 2
    acc0, _, _, m0, l0 = fused_decode(x, wqkv, None, wo, kc[:h], vc[:h],
                                      min(clen, h), cos, sin, **kw,
                                      fuse_out=False, use_ref=True)
    # chip 1 sees the tail; mask new-token by zero-weight trick: include it
    # only on chip 0 ⇒ chip 1 computes cache-only partial via flash_decode
    q = (x @ wqkv)[:, : q_loc * hd].reshape(B, q_loc, hd)
    half = hd // 2
    c, s_ = cos, sin
    q = jnp.concatenate([q[..., :half] * c - q[..., half:] * s_,
                         q[..., half:] * c + q[..., :half] * s_], -1)
    s1 = jnp.einsum("bkqh,skh->bkqs",
                    q.reshape(B, kv_loc, q_loc // kv_loc, hd),
                    kc[h:]) / np.sqrt(hd)
    valid = (jnp.arange(h) + h) < clen
    s1 = jnp.where(valid[None, None, None], s1, -jnp.inf)
    m1 = jnp.max(s1, -1)
    m1s = jnp.where(jnp.isfinite(m1), m1, -1e30)
    p1 = jnp.where(valid[None, None, None], jnp.exp(s1 - m1s[..., None]), 0)
    l1 = p1.sum(-1)
    o1 = jnp.einsum("bkqs,skh->bkqh", p1, vc[h:])
    m, l, o = flash_merge(
        (m0.reshape(B, kv_loc, -1), l0.reshape(B, kv_loc, -1),
         acc0.reshape(B, kv_loc, q_loc // kv_loc, hd)),
        (m1s, l1, o1))
    att = (o / l[..., None]).reshape(B, q_loc * hd)
    o_comb = att @ wo
    np.testing.assert_allclose(np.asarray(o_comb), np.asarray(o_full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,D,S,q_loc,kv_loc,hd,d_out", [
    (2, 128, 512, 4, 2, 32, 64),
    (1, 64, 256, 8, 4, 16, 64),
])
@pytest.mark.parametrize("cache_len", [0, 100, -1])
def test_fused_decode_partial_o_vs_oracle(B, D, S, q_loc, kv_loc, hd,
                                          d_out, cache_len):
    """``fuse_out="partial_o"``: the in-kernel per-head Output-Projection
    of the unnormalized accumulator matches the jnp oracle, and
    normalizing + summing heads reproduces the monolithic fused output
    through the flat wo."""
    cache_len = S - 1 if cache_len < 0 else min(cache_len, S - 1)
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 6)
    P_ = (q_loc + 2 * kv_loc) * hd
    x = jax.random.normal(ks[0], (B, D)) * 0.2
    wqkv = jax.random.normal(ks[1], (D, P_)) * 0.05
    wo3 = jax.random.normal(ks[2], (q_loc, hd, d_out)) * 0.05
    kc = jax.random.normal(ks[3], (S, kv_loc, hd)) * 0.3
    vc = jax.random.normal(ks[4], (S, kv_loc, hd)) * 0.3
    cos, sin = rope_at(cache_len, hd)
    kw = dict(q_heads=q_loc, kv_heads=kv_loc, fuse_out="partial_o")
    o, kn, vn, m, l = fused_decode(x, wqkv, None, wo3, kc, vc, cache_len,
                                   cos, sin, **kw, interpret=True,
                                   block_s=64)
    o_r, _, _, m_r, l_r = fused_decode(x, wqkv, None, wo3, kc, vc,
                                       cache_len, cos, sin, **kw,
                                       use_ref=True)
    assert o.shape == (B, q_loc, d_out)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_r),
                               rtol=1e-5, atol=1e-5)
    # normalize per head + sum over heads == fuse_out=True through the
    # flat [q_loc*hd, d_out] wo (the serve-layout identity)
    o_flat, *_ = fused_decode(x, wqkv, None, wo3.reshape(q_loc * hd, d_out),
                              kc, vc, cache_len, cos, sin,
                              q_heads=q_loc, kv_heads=kv_loc, use_ref=True)
    comb = (np.asarray(o) / np.asarray(l)[..., None]).sum(1)
    np.testing.assert_allclose(comb, np.asarray(o_flat),
                               rtol=1e-4, atol=1e-4)


def test_fused_decode_partial_o_cluster_combine():
    """partial_o partials from a 2-way KV-sequence split flash-merge to
    the monolithic answer — the single-ClusterReduce property of the
    prepacked serve layout (projection inside the kernel, combine after)."""
    from repro.core.primitives import flash_merge
    B, D, S, q_loc, kv_loc, hd, d_out = 2, 128, 512, 4, 2, 32, 96
    key = jax.random.PRNGKey(12)
    ks = jax.random.split(key, 6)
    P_ = (q_loc + 2 * kv_loc) * hd
    x = jax.random.normal(ks[0], (B, D)) * 0.2
    wqkv = jax.random.normal(ks[1], (D, P_)) * 0.05
    wo3 = jax.random.normal(ks[2], (q_loc, hd, d_out)) * 0.05
    kc = jax.random.normal(ks[3], (S, kv_loc, hd)) * 0.3
    vc = jax.random.normal(ks[4], (S, kv_loc, hd)) * 0.3
    clen = 400
    cos, sin = rope_at(clen, hd)
    kw = dict(q_heads=q_loc, kv_heads=kv_loc, fuse_out="partial_o")
    h = S // 2
    # "chip 0": first half of the cache, owns the new token
    o0, _, _, m0, l0 = fused_decode(
        x, wqkv, None, wo3, kc[:h], vc[:h], min(clen, h), cos, sin, **kw,
        interpret=True, block_s=64, include_new=jnp.int32(1))
    # "chip 1": second half (positions offset by h), new token excluded
    o1, _, _, m1, l1 = fused_decode(
        x, wqkv, None, wo3, kc[h:], vc[h:], clen, cos, sin, **kw,
        interpret=True, block_s=64, include_new=jnp.int32(0),
        pos=jnp.arange(h, S, dtype=jnp.int32), pos_base=jnp.int32(h))
    m, l, o = flash_merge((np.asarray(m0), np.asarray(l0), np.asarray(o0)),
                          (np.asarray(m1), np.asarray(l1), np.asarray(o1)))
    comb = (np.asarray(o) / np.asarray(l)[..., None]).sum(1)
    o_full, *_ = fused_decode(x, wqkv, None,
                              wo3.reshape(q_loc * hd, d_out), kc, vc, clen,
                              cos, sin, q_heads=q_loc, kv_heads=kv_loc,
                              use_ref=True)
    np.testing.assert_allclose(comb, np.asarray(o_full),
                               rtol=1e-4, atol=1e-4)


def test_fused_mla_partial_o_fold():
    """MLA partial_o through the prepacked W_UV·W_O fold equals the
    monolithic fuse_out=True result with the unfolded weights."""
    B, D, S, q_loc = 2, 128, 512, 4
    l_rank, rope_d, nope, v_dim, d_out = 32, 8, 16, 16, 96
    key = jax.random.PRNGKey(13)
    ks = jax.random.split(key, 8)
    x = jax.random.normal(ks[0], (B, D)) * 0.2
    wq = jax.random.normal(ks[1], (D, q_loc * (nope + rope_d))) * 0.05
    wdkv = jax.random.normal(ks[2], (D, l_rank + rope_d)) * 0.05
    wuk = jax.random.normal(ks[3], (q_loc, nope, l_rank)) * 0.05
    wuv = jax.random.normal(ks[4], (q_loc, l_rank, v_dim)) * 0.05
    wo = jax.random.normal(ks[5], (q_loc * v_dim, d_out)) * 0.05
    cc = jax.random.normal(ks[6], (S, l_rank + rope_d)) * 0.3
    clen = 300
    cos, sin = rope_at(clen, rope_d)
    wproj = jnp.einsum("qlv,qvd->qld", wuv, wo.reshape(q_loc, v_dim, d_out))
    kw = dict(q_heads=q_loc, nope=nope, rope_d=rope_d, l_rank=l_rank)
    o, cn, m, l = fused_mla_decode(
        x, wq, wdkv, wuk, wproj, jnp.zeros((1, 1)), cc, clen, cos, sin,
        **kw, v_dim=d_out, fuse_out="partial_o", interpret=True, block_s=64)
    o_r, cn_r, m_r, l_r = fused_mla_decode(
        x, wq, wdkv, wuk, wproj, jnp.zeros((1, 1)), cc, clen, cos, sin,
        **kw, v_dim=d_out, fuse_out="partial_o", use_ref=True)
    assert o.shape == (B, q_loc, d_out)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(cn), np.asarray(cn_r),
                               rtol=1e-5, atol=1e-5)
    o_full, *_ = fused_mla_decode(x, wq, wdkv, wuk, wuv, wo, cc, clen,
                                  cos, sin, **kw, v_dim=v_dim,
                                  fuse_out=True, use_ref=True)
    comb = (np.asarray(o) / np.asarray(l)[..., None]).sum(1)
    np.testing.assert_allclose(comb, np.asarray(o_full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,q_loc,kv_loc,hd,clen", [
    (512, 4, 2, 32, 77), (256, 8, 1, 64, 256), (1024, 2, 2, 16, 1000)])
def test_flash_decode_sweep(S, q_loc, kv_loc, hd, clen):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, q_loc, hd)) * 0.3
    kc = jax.random.normal(ks[1], (S, kv_loc, hd)) * 0.3
    vc = jax.random.normal(ks[2], (S, kv_loc, hd)) * 0.3
    o = flash_decode(q, kc, vc, min(clen, S), block_s=128, interpret=True)
    o_r = flash_decode(q, kc, vc, min(clen, S), use_ref=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("l_rank,rope_d,nope,v_dim", [
    (64, 16, 32, 32), (32, 8, 16, 16)])
@pytest.mark.parametrize("fuse_out", [True, False])
def test_fused_mla_sweep(l_rank, rope_d, nope, v_dim, fuse_out):
    B, D, S, q_loc = 2, 128, 512, 4
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 8)
    x = jax.random.normal(ks[0], (B, D)) * 0.2
    wq = jax.random.normal(ks[1], (D, q_loc * (nope + rope_d))) * 0.05
    wdkv = jax.random.normal(ks[2], (D, l_rank + rope_d)) * 0.05
    wuk = jax.random.normal(ks[3], (q_loc, nope, l_rank)) * 0.05
    wuv = jax.random.normal(ks[4], (q_loc, l_rank, v_dim)) * 0.05
    wo = jax.random.normal(ks[5], (q_loc * v_dim, D)) * 0.05
    cc = jax.random.normal(ks[6], (S, l_rank + rope_d)) * 0.3
    clen = 300
    cos, sin = rope_at(clen, rope_d)
    kw = dict(q_heads=q_loc, nope=nope, rope_d=rope_d, l_rank=l_rank,
              v_dim=v_dim, fuse_out=fuse_out)
    o, cn, m, l = fused_mla_decode(x, wq, wdkv, wuk, wuv, wo, cc, clen, cos,
                                   sin, block_s=128, interpret=True, **kw)
    o_r, cn_r, m_r, l_r = fused_mla_decode(x, wq, wdkv, wuk, wuv, wo, cc,
                                           clen, cos, sin, use_ref=True, **kw)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(np.asarray(cn), np.asarray(cn_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S,C", [(2, 256, 128), (1, 64, 512), (4, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(B, S, C, dtype):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    la = (-jnp.abs(jax.random.normal(ks[0], (B, S, C))) * 0.1).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, C)) * 0.2).astype(dtype)
    h0 = jax.random.normal(ks[2], (B, C)) * 0.3
    o, hf = rglru_scan(la, b, h0, block_t=64, block_c=64, interpret=True)
    o_r, hf_r = rglru_scan(la, b, h0, use_ref=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_r, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_r),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("B,S,H,hd", [(2, 64, 4, 16), (1, 128, 2, 32)])
def test_rwkv6_scan_sweep(B, S, H, hd):
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.3
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
    o, sf = rwkv6_scan(r, k, v, w, u, s0, block_t=16, block_h=2,
                       interpret=True)
    o_r, sf_r = rwkv6_scan(r, k, v, w, u, s0, use_ref=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_r),
                               rtol=1e-5, atol=1e-5)
