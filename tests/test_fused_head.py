"""Fused LM-head/sampling tail (kernels/fused_head, DESIGN.md §7 L5).

* Kernel vs pure-jnp oracle across dtype × softcap × block_v sweeps —
  EXACT (max value and argmax index): the kernel mirrors
  ``lm_head_logits``'s pinned f32 staging bit-for-bit.
* block_v tiling invariance and lowest-index tie-breaking (within a
  tile, across tiles, and across vocab shards).
* ``greedy_sample`` cross-shard tie-breaking: equal-max logits on
  different ranks pick the LOWEST global index on EVERY rank —
  regression-locks the semantics the fused head reduce reproduces
  (pre-fix, first-argument-wins ties made ranks disagree).
* Fused tail (``engine._fused_head_tail``) ≡ the unfused
  ``rms_norm``/``lm_head_logits``/``softcap``/``greedy_sample``
  composition — single device and, via ``run_multidevice``, on an
  8-rank model axis at cluster sizes {1, 2, 4}, token-EXACT, including
  zeroed free-slot rows.
* Full-engine token exactness: the prepacked Pallas engine with the
  fused head vs the SAME engine with ``fuse_head=False`` (identical
  fused layers, loose XLA tail) — token-for-token over a forced stream
  at cluster {1, 2, 4}, including a retired (free) scheduler slot.
* Trace-time proof: ONE ``head_pallas_kernel`` + ONE
  ``head_cluster_reduce`` + ZERO ``lm_head_logits`` per fused step —
  the ``[B, V]`` logits never materialize; the full dense step is
  embed psum + 2 launches/layer + 1 head launch + 1 head reduce.
* Modeled byte columns + ``ServePlan.block_v`` schema self-heal.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # tier-1 container: deterministic shim
    from _minihyp import given, settings, strategies as st

from helpers import run_multidevice


def _mk(rng, shape, dtype, scale=0.3):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# Kernel vs oracle (single device, interpret mode)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cap", [0.0, 30.0])
@pytest.mark.parametrize("bv", [8, 16, 64])
def test_fused_head_kernel_vs_ref_exact(dtype, cap, bv):
    from repro.kernels.fused_head.ops import fused_head
    rng = np.random.default_rng(0)
    B, D, V = 3, 32, 64
    x = _mk(rng, (B, D), dtype)
    tab = _mk(rng, (V, D), dtype, 0.05)
    ln = _mk(rng, (D,), jnp.float32, 0.1)
    mk_, ik = fused_head(x, tab, ln, logit_softcap=cap, block_v=bv,
                         interpret=True)
    mr, ir = fused_head(x, tab, ln, logit_softcap=cap, use_ref=True)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(mk_), np.asarray(mr))


def test_fused_head_block_v_tiling_invariance():
    """The vocab tile size must not change the result — every logit is
    computed identically regardless of which tile holds it, and the
    strict cross-tile merge preserves argmax-first semantics."""
    from repro.kernels.fused_head.ops import fused_head
    rng = np.random.default_rng(1)
    B, D, V = 2, 16, 64
    for dtype in (jnp.float32, jnp.bfloat16):
        for cap in (0.0, 30.0):
            x = _mk(rng, (B, D), dtype)
            tab = _mk(rng, (V, D), dtype, 0.05)
            ln = _mk(rng, (D,), jnp.float32, 0.1)
            outs = [fused_head(x, tab, ln, logit_softcap=cap, block_v=bv,
                               interpret=True) for bv in (4, 8, 16, 32, 64)]
            for m, i in outs[1:]:
                np.testing.assert_array_equal(np.asarray(outs[0][0]),
                                              np.asarray(m))
                np.testing.assert_array_equal(np.asarray(outs[0][1]),
                                              np.asarray(i))


def test_fused_head_tie_breaks_to_lowest_index_across_tiles():
    """Equal maxima planted in DIFFERENT vocab tiles (and inside one
    tile) must pick the lowest index — ``jnp.argmax`` semantics, the
    contract the cross-shard merge extends globally."""
    from repro.kernels.fused_head.ops import fused_head  # noqa: F811
    x = jnp.zeros((1, 8), jnp.float32).at[0, 0].set(1.0)
    ln = jnp.zeros((8,), jnp.float32)
    tab = jnp.zeros((32, 8), jnp.float32).at[5, 0].set(7.0).at[21, 0].set(7.0)
    for bv in (4, 8, 16, 32):
        _, ik = fused_head(x, tab, ln, block_v=bv, interpret=True)
        assert int(ik[0, 0]) == 5, (bv, ik)
    # within-tile tie too
    tab2 = jnp.zeros((32, 8), jnp.float32).at[9, 0].set(7.0).at[11, 0].set(7.0)
    _, ik2 = fused_head(x, tab2, ln, block_v=16, interpret=True)
    assert int(ik2[0, 0]) == 9


@pytest.mark.slow
@given(st.integers(0, 2 ** 31), st.integers(1, 4), st.booleans(),
       st.booleans())
@settings(max_examples=25, deadline=None)
def test_fused_head_property_exact(seed, B, capped, bf16):
    """Property (hypothesis full profile nightly / "ci" profile or the
    _minihyp shim in tier-1): for random seeds, batch sizes, softcap and
    dtype, kernel ≡ oracle exactly — THE invariant that makes the fused
    tail a drop-in for lm_head_logits + greedy_sample."""
    from repro.kernels.fused_head.ops import fused_head
    rng = np.random.default_rng(seed)
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    D, V = 16, 32
    x = _mk(rng, (B, D), dtype)
    tab = _mk(rng, (V, D), dtype, 0.05)
    ln = _mk(rng, (D,), jnp.float32, 0.1)
    cap = 30.0 if capped else 0.0
    mk_, ik = fused_head(x, tab, ln, logit_softcap=cap, block_v=8,
                         interpret=True)
    mr, ir = fused_head(x, tab, ln, logit_softcap=cap, use_ref=True)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(mk_), np.asarray(mr))


# ---------------------------------------------------------------------------
# Fused tail ≡ unfused composition (single device)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cap", [0.0, 30.0])
def test_fused_tail_matches_unfused_single_device(cap):
    from repro.configs import get_config, reduced
    from repro.core import dataflow as df
    from repro.models.ctx import single_device_ctx
    from repro.models.layers import lm_head_logits, rms_norm, softcap
    from repro.serving.engine import (ServeConfig, _fused_head_tail,
                                      greedy_sample_pair)
    cfg = reduced(get_config("gemma2-27b" if cap else "llama2-7b"))
    ctx = single_device_ctx()
    scfg = ServeConfig(max_seq=16, batch_local=3, backend="pallas",
                       interpret=True, block_v=16)
    rng = np.random.default_rng(2)
    B, D, V = 3, cfg.d_model, 64
    x = _mk(rng, (B, D), jnp.bfloat16).at[1].set(0.0)   # free-slot row
    tab = _mk(rng, (V, D), jnp.bfloat16, 0.05)
    ln = _mk(rng, (D,), jnp.float32, 0.1)
    w = df.PackedHeadWeights(table=tab, ln=ln)
    # the tail now returns the k-wide (values, indices) candidate lists,
    # sorted value-descending; candidate 0 IS the greedy (max, argmax)
    # pair, so both halves must match the PR-5 composition bit-for-bit
    # (the value feeds the check_finite per-slot sentinel)
    cand_v, cand_i = _fused_head_tail(ctx, cfg, scfg, w, x)
    logits = lm_head_logits(ctx, tab, rms_norm(x, ln, cfg.norm_eps))
    if cap:
        logits = softcap(logits, cap)
    want_tok, want_val = greedy_sample_pair(ctx, logits)
    np.testing.assert_array_equal(np.asarray(cand_i[:, 0]),
                                  np.asarray(want_tok))
    np.testing.assert_allclose(np.asarray(cand_v[:, 0]),
                               np.asarray(want_val), rtol=1e-6)
    # candidates are strictly value-sorted and index-deduplicated
    cv, ci = np.asarray(cand_v), np.asarray(cand_i)
    assert (cv[:, :-1] >= cv[:, 1:]).all()
    for b in range(cv.shape[0]):
        assert len(set(ci[b].tolist())) == ci.shape[1]


# ---------------------------------------------------------------------------
# Modeled byte columns + plan plumbing
# ---------------------------------------------------------------------------
def test_head_bytes_model():
    from repro.configs import get_config, reduced
    from repro.core.autotune import (head_hbm_logits_bytes_per_step,
                                     head_ici_bytes_per_step)
    cfg = reduced(get_config("llama2-7b"))
    kw = dict(model_axis=8, batch=2)
    # unfused tails pay the [B, V_loc] logits write; the fused head
    # (prepacked pallas) deletes it
    unfused = head_hbm_logits_bytes_per_step(cfg, backend="xla",
                                             prepack=False, **kw)
    assert unfused == 2 * (cfg.vocab_size // 8) * 4
    assert head_hbm_logits_bytes_per_step(cfg, backend="pallas",
                                          prepack=False, **kw) == unfused
    assert head_hbm_logits_bytes_per_step(cfg, backend="pallas",
                                          prepack=True, **kw) == 0.0
    # the (value, index) pair reduce is identical on both tails, zero on
    # a single-shard axis
    ici_f = head_ici_bytes_per_step(cfg, backend="pallas", prepack=True, **kw)
    ici_u = head_ici_bytes_per_step(cfg, backend="xla", prepack=False, **kw)
    assert ici_f == ici_u > 0
    assert head_ici_bytes_per_step(cfg, model_axis=1, batch=2,
                                   backend="xla", prepack=False) == 0.0


def test_serve_plan_block_v_selfheal(tmp_path):
    """A pre-fused-head (PR-4 schema) table entry lacks ``block_v`` and
    must self-heal by re-tuning through the TypeError path."""
    from repro.configs import get_config, reduced
    from repro.core.autotune import load_table, save_table, tune_serving
    cfg = reduced(get_config("llama2-7b"))
    path = str(tmp_path / "tune.json")
    p = tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                     backend="auto", table_path=path)
    assert p.block_v > 0
    table = load_table(path)
    key = next(iter(table))
    del table[key]["block_v"]
    save_table(path, table)
    p2 = tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                      backend="auto", table_path=path)
    assert p2 == p
    assert "block_v" in load_table(path)[key]


def test_bundle_head_pure_aliasing():
    """The head bundle duplicates ZERO bytes: ``table`` IS the training
    tree's embed (tied) / lm_head buffer and ``ln`` IS ``final_norm`` —
    for both the standalone pass and the full prepack; ``head_view``
    returns exactly what decode samples with."""
    from repro.configs import get_config, reduced
    from repro.core.dataflow import PackedHeadWeights
    from repro.models.transformer import Layout, init_device_major
    from repro.serving.prepack import (bundle_head, head_view,
                                       prepack_for_serving)
    for arch, src in (("llama2-7b", "lm_head"), ("gemma2-27b", "embed")):
        cfg = reduced(get_config(arch))
        lay = Layout(4, heads_sub=2)
        params = init_device_major(cfg, lay, jax.random.PRNGKey(0))
        packed = prepack_for_serving(cfg, lay, params, backend="pallas")
        h = packed["head"]
        assert isinstance(h, PackedHeadWeights)
        assert h.table is params[src]
        assert h.ln is params["final_norm"]
        # xla serve layout keeps the loose tail (no bundle)
        assert "head" not in prepack_for_serving(cfg, lay, params,
                                                 backend="xla")
        # the standalone pass and the view helper agree (same buffers)
        b2 = bundle_head(cfg, params)["head"]
        assert b2.table is h.table and b2.ln is h.ln
        hv_pair = head_view(cfg, {"train": params, "serve": packed})
        assert hv_pair.table is h.table and hv_pair.ln is h.ln
        # unpacked trees yield the equivalent train view
        hv = head_view(cfg, params)
        assert hv.table is params[src] and hv.ln is params["final_norm"]


# ---------------------------------------------------------------------------
# greedy_sample cross-shard tie-breaking — 8 emulated devices
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_greedy_sample_tiebreak_across_vocab_shards():
    """Equal-max logits on different vocab shards must pick the LOWEST
    global index, and EVERY rank must return the same token (the merge
    is commutative, so per-rank tree association orders agree) — the
    semantics the fused head reduce reproduces."""
    run_multidevice("""
    from repro.models.ctx import make_train_ctx
    from repro.serving.engine import greedy_sample
    mesh = jax.make_mesh((8,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    V_LOC, B = 4, 2
    # scenarios: (shard, local_idx) pairs planted with the SAME max
    scenarios = [
        [(3, 2), (6, 1)],              # expect 3*4+2 = 14
        [(0, 3), (7, 0)],              # expect 3
        [(2, 1), (2, 3), (5, 0)],      # within-shard + cross-shard: 9
        [(1, 0), (0, 0)],              # adjacent shards: 0
    ]
    for plant in scenarios:
        want = min(s * V_LOC + i for s, i in plant)
        base = np.full((8, B, V_LOC), -2.0, np.float32)
        for s, i in plant:
            base[s, :, i] = 5.0
        logits = jnp.asarray(base)

        def body(lg):
            ctx = make_train_ctx("model", heads_sub=8, model_size=8)
            r = jax.lax.axis_index("model")
            tok = greedy_sample(ctx, lg[r])
            return tok[None]

        toks = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(),),
                                 out_specs=P("model"),
                                 check_vma=False))(logits)
        toks = np.asarray(toks)                      # [8, B] per rank
        assert (toks == want).all(), (plant, want, toks)
        print("TIEBREAK OK", plant, "->", want)
    """)


# ---------------------------------------------------------------------------
# Fused tail ≡ unfused composition — cluster sweep, 8 emulated devices
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_fused_head_tail_cluster_sweep_token_exact():
    """Fused head tail vs the unfused lm_head_logits + greedy_sample
    composition on a sharded 8-rank model axis at cluster sizes
    {1, 2, 4} (heads × cluster factorings — the head reduce spans the
    FULL model axis and must be factoring-invariant), dtypes f32 + bf16,
    softcap on/off, with a zeroed free-slot row.  Token-EXACT, and the
    per-rank results all agree."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.core import dataflow as df
    from repro.models.ctx import make_train_ctx
    from repro.models.layers import lm_head_logits, rms_norm, softcap
    from repro.serving.engine import (ServeConfig, _fused_head_tail,
                                      greedy_sample)
    mesh = jax.make_mesh((8,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.default_rng(0)
    B, V = 3, 64
    for arch, cap in (("llama2-7b", 0.0), ("gemma2-27b", 30.0)):
        cfg = reduced(get_config(arch))
        D = cfg.d_model
        for dt in (jnp.float32, jnp.bfloat16):
            X = jnp.asarray(rng.standard_normal((B, D)) * 0.3, dt)
            X = X.at[1].set(0.0)       # free-slot row: zeroed stream
            TAB = jnp.asarray(rng.standard_normal((V, D)) * 0.05, dt)
            LN = jnp.asarray(rng.standard_normal((D,)) * 0.1, jnp.float32)
            for N in (1, 2, 4):
                scfg = ServeConfig(max_seq=16, batch_local=B,
                                   backend="pallas", interpret=True,
                                   block_v=4)

                def body(x, tab, ln):
                    ctx = make_train_ctx("model", heads_sub=8 // N,
                                         model_size=8)
                    r = jax.lax.axis_index("model")
                    v_loc = V // 8
                    tab_l = jax.lax.dynamic_slice_in_dim(
                        tab, r * v_loc, v_loc, axis=0)
                    w = df.PackedHeadWeights(table=tab_l, ln=ln)
                    cv, ci = _fused_head_tail(ctx, cfg, scfg, w, x)
                    lg = lm_head_logits(ctx, tab_l,
                                        rms_norm(x, ln, cfg.norm_eps))
                    if cap:
                        lg = softcap(lg, cap)
                    # candidate 0 of the k-wide merge IS the greedy token
                    return ci[:, 0][None], greedy_sample(ctx, lg)[None]

                got, want = jax.jit(shard_map(
                    body, mesh=mesh, in_specs=(P(),) * 3,
                    out_specs=(P("model"), P("model")),
                    check_vma=False))(X, TAB, LN)
                got = np.asarray(got)            # [8, B] per-rank tokens
                want = np.asarray(want)
                assert (got == want).all(), (arch, dt, N, got, want)
                assert (got == got[0]).all(), (arch, dt, N, got)
            print("FUSED HEAD TAIL OK", arch, dt.__name__)
    """, timeout=1800)


# ---------------------------------------------------------------------------
# Full-engine token exactness + trace-count proof — 8 emulated devices
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_engine_fused_head_token_exact_cluster_sweep():
    """The prepacked Pallas engine with the fused head vs the SAME
    engine built with ``fuse_head=False`` (identical fused layers, loose
    XLA head tail): token-for-token EXACT over prefill + a forced decode
    stream at cluster {1, 2, 4}, including a retired (free) slot whose
    meaningless token must also agree.  Plus the trace-count proof: the
    fused step is embed-psum + 2 launches/layer + 1 head launch + 1 head
    reduce, with ZERO [B, V] logits materializations."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.core import tracecount
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import EngineOptions, build_engine_full
    for arch in ("llama2-7b", "gemma2-27b"):
        cfg = reduced(get_config(arch))
        period = len(cfg.block_pattern)
        mesh = make_test_mesh()
        for n in (1, 2, 4):
            res = {}
            for label, fh in (("fused", True), ("nohead", False)):
                h = build_engine_full(
                    cfg, mesh, max_seq=32, batch_global=4,
                    options=EngineOptions(cluster=n, backend="pallas",
                                          interpret=True, fuse_head=fh))
                tok0 = jnp.zeros((4,), jnp.int32)
                with tracecount.counting() as c:
                    jax.eval_shape(h.decode_fn, h.params["serve"],
                                   h.state, tok0)
                c = dict(c)
                if fh:
                    assert c.get("head_pallas_kernel") == 1, (arch, n, c)
                    assert c.get("head_cluster_reduce") == 1, (arch, n, c)
                    assert c.get("lm_head_logits", 0) == 0, (arch, n, c)
                    assert c.get("pallas_kernel") == 2 * period + 1, \\
                        (arch, n, c)
                    assert c.get("psum_model") == 1, (arch, n, c)
                else:
                    assert c.get("head_pallas_kernel", 0) == 0, c
                    assert c.get("lm_head_logits") == 1, c
                key = jax.random.PRNGKey(0)
                prompts = jax.random.randint(key, (4, 12), 0,
                                             cfg.vocab_size)
                nxt, st = h.prefill_fn(h.params["train"], h.state,
                                       prompts, None)
                # retire slot 2: its cache_len freezes at -1 and its
                # (ignored) sampled token must still match exactly
                st = h.retire_fn(st, jnp.asarray([0, 0, 1, 0], jnp.int32))
                toks = jax.random.randint(jax.random.PRNGKey(3), (6, 4),
                                          0, cfg.vocab_size)
                outs = [np.asarray(nxt)]
                for t in range(6):
                    o, st = h.decode_fn(h.params["serve"], st, toks[t])
                    outs.append(np.asarray(o))
                res[label] = np.stack(outs)
            np.testing.assert_array_equal(res["fused"], res["nohead"])
            print("ENGINE FUSED HEAD OK", arch, "N =", n)
    """, timeout=1800)
