"""Fused transformer-block tail (kernels/fused_ffn, DESIGN.md §7).

* Kernel vs pure-jnp oracle across gated/ungated × post-norm ×
  residual-fold × dtype sweeps (interpret mode).
* Fused tail (:func:`repro.serving.engine._fused_ffn_tail`) vs the
  unfused ``rms_norm``/``ffn_apply``/residual composition — single
  device and, via ``run_multidevice``, on an 8-rank model axis at
  cluster sizes {1, 2, 4} (the FFN reduce spans the FULL model axis; the
  sweep proves the fused ClusterReduce is invariant to the heads ×
  cluster factoring the attention side picks).
* Ragged slot masks: the FFN is slot-local — each batch row's output
  equals its own single-row run, and all-zero (free-slot) rows stay
  finite.
* A ``_minihyp``-compatible shrinkable property: fused block ≡ unfused
  layer over random shapes/seeds.
* Full-engine token parity: the fused-FFN Pallas path vs the XLA oracle
  at forced cluster sizes {1, 2, 4} for a GQA arch (llama2) and an MLA
  arch (deepseek), per-step over a forced token stream.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # tier-1 container: deterministic shim
    from _minihyp import given, settings, strategies as st

from helpers import run_multidevice


def _mk(rng, shape, dtype, scale=0.3):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# Kernel vs oracle (single device, interpret mode)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("gated", [True, False])
@pytest.mark.parametrize("act", ["silu", "gelu_tanh"])
def test_fused_ffn_kernel_vs_ref(dtype, gated, act):
    from repro.kernels.fused_ffn.ops import fused_ffn
    rng = np.random.default_rng(0)
    B, D, F = 3, 32, 24
    x = _mk(rng, (B, D), dtype)
    a = _mk(rng, (B, D), dtype)
    wi = _mk(rng, (D, F), dtype, 0.05)
    wg = _mk(rng, (D, F), dtype, 0.05) if gated else None
    wo = _mk(rng, (F, D), dtype, 0.05)
    ln2 = _mk(rng, (D,), jnp.float32, 0.1)
    p1 = _mk(rng, (D,), jnp.float32, 0.1)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    for post1 in (None, p1):
        for add_r in (0.0, 1.0):
            kw = dict(act=act, eps=1e-6, block_f=8)
            o_k, r_k = fused_ffn(x, a, wi, wg, wo, ln2, post1,
                                 jnp.float32(add_r), interpret=True, **kw)
            o_r, r_r = fused_ffn(x, a, wi, wg, wo, ln2, post1,
                                 jnp.float32(add_r), use_ref=True, **kw)
            np.testing.assert_allclose(
                np.asarray(o_k, np.float32), np.asarray(o_r, np.float32),
                rtol=tol, atol=tol, err_msg=f"post={post1 is not None}")
            np.testing.assert_allclose(
                np.asarray(r_k, np.float32), np.asarray(r_r, np.float32),
                rtol=tol, atol=tol)


def test_fused_ffn_block_f_tiling_invariance():
    """The d_ff tile size must not change the result (f32: exactly the
    same accumulation order per output element)."""
    from repro.kernels.fused_ffn.ops import fused_ffn
    rng = np.random.default_rng(1)
    B, D, F = 2, 16, 32
    args = (_mk(rng, (B, D), jnp.float32), _mk(rng, (B, D), jnp.float32),
            _mk(rng, (D, F), jnp.float32, 0.05),
            _mk(rng, (D, F), jnp.float32, 0.05),
            _mk(rng, (F, D), jnp.float32, 0.05),
            _mk(rng, (D,), jnp.float32, 0.1), None, jnp.float32(1.0))
    outs = [fused_ffn(*args, act="silu", block_f=bf, interpret=True)[0]
            for bf in (4, 8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Fused tail vs the unfused layer composition (single device)
# ---------------------------------------------------------------------------
def _unfused_tail(ctx, cfg, x, a, fp, ln2, post1, post2):
    from repro.models.layers import ffn_apply, rms_norm
    eps = cfg.norm_eps
    av = rms_norm(a, post1, eps) if post1 is not None else a
    x1 = x + av
    h = rms_norm(x1, ln2, eps)
    f = ffn_apply(ctx, fp, h, cfg.ffn_act)
    if post2 is not None:
        f = rms_norm(f, post2, eps)
    return x1 + f


@pytest.mark.parametrize("gated,post", [(True, False), (True, True),
                                        (False, False)])
def test_fused_tail_matches_unfused_layer_single_device(gated, post):
    from repro.configs import get_config, reduced
    from repro.core import dataflow as df
    from repro.models.ctx import single_device_ctx
    from repro.models.layers import FFNParams
    from repro.serving.engine import ServeConfig, _fused_ffn_tail
    cfg = reduced(get_config("llama2-7b"))
    ctx = single_device_ctx()
    scfg = ServeConfig(max_seq=16, batch_local=3, backend="pallas",
                       interpret=True, block_f=8)
    rng = np.random.default_rng(2)
    B, D, F = 3, cfg.d_model, 48
    x = _mk(rng, (B, D), jnp.float32)
    a = _mk(rng, (B, D), jnp.float32)
    fp = FFNParams(w_in=_mk(rng, (D, F), jnp.float32, 0.05),
                   w_out=_mk(rng, (F, D), jnp.float32, 0.05),
                   w_gate=_mk(rng, (D, F), jnp.float32, 0.05)
                   if gated else None)
    ln2 = _mk(rng, (D,), jnp.float32, 0.1)
    p1 = _mk(rng, (D,), jnp.float32, 0.1) if post else None
    p2 = _mk(rng, (D,), jnp.float32, 0.1) if post else None
    blk = {"ffn": df.PackedFFNWeights(w_in=fp.w_in, w_out=fp.w_out,
                                      ln2=ln2, w_gate=fp.w_gate,
                                      post_ln1=p1), "ln2": ln2}
    if post:
        blk["post_ln1"] = p1
        blk["post_ln2"] = p2
    got = _fused_ffn_tail(ctx, cfg, scfg, blk, x, a, blk["ffn"])
    want = _unfused_tail(ctx, cfg, x, a, fp, ln2, p1, p2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_ffn_ragged_slot_independence():
    """Slot-local: row b of a batched call equals its own single-row run,
    and an all-zero (free scheduler slot) row stays finite."""
    from repro.kernels.fused_ffn.ops import fused_ffn
    rng = np.random.default_rng(3)
    B, D, F = 4, 16, 12
    x = _mk(rng, (B, D), jnp.float32).at[2].set(0.0)   # free slot: zeroed
    a = _mk(rng, (B, D), jnp.float32).at[2].set(0.0)   # residual stream
    wi = _mk(rng, (D, F), jnp.float32, 0.05)
    wg = _mk(rng, (D, F), jnp.float32, 0.05)
    wo = _mk(rng, (F, D), jnp.float32, 0.05)
    ln2 = _mk(rng, (D,), jnp.float32, 0.1)
    kw = dict(act="silu", block_f=4, interpret=True)
    o_b, r_b = fused_ffn(x, a, wi, wg, wo, ln2, None, jnp.float32(1.0), **kw)
    assert np.isfinite(np.asarray(o_b)).all()
    for b in range(B):
        o_1, _ = fused_ffn(x[b:b + 1], a[b:b + 1], wi, wg, wo, ln2, None,
                           jnp.float32(1.0), **kw)
        np.testing.assert_allclose(np.asarray(o_b[b]), np.asarray(o_1[0]),
                                   rtol=1e-5, atol=1e-5, err_msg=f"slot {b}")


# ---------------------------------------------------------------------------
# Shrinkable property: fused block ≡ unfused layer
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 31), st.integers(1, 4), st.booleans())
@settings(max_examples=10, deadline=None)
def test_fused_block_equals_unfused_layer_property(seed, B, gated):
    """Property (hypothesis or the _minihyp shim): for random seeds,
    batch sizes and gating, the fused block tail equals the unfused
    rms→FFN→residual composition — THE invariant that makes the
    two-launch layer a drop-in replacement."""
    from repro.configs import get_config, reduced
    from repro.core import dataflow as df
    from repro.models.ctx import single_device_ctx
    from repro.models.layers import FFNParams
    from repro.serving.engine import ServeConfig, _fused_ffn_tail
    cfg = reduced(get_config("llama2-7b"))
    ctx = single_device_ctx()
    scfg = ServeConfig(max_seq=16, batch_local=B, backend="pallas",
                       interpret=True, block_f=16)
    rng = np.random.default_rng(seed)
    D, F = cfg.d_model, 32
    x = _mk(rng, (B, D), jnp.float32)
    a = _mk(rng, (B, D), jnp.float32)
    fp = FFNParams(w_in=_mk(rng, (D, F), jnp.float32, 0.05),
                   w_out=_mk(rng, (F, D), jnp.float32, 0.05),
                   w_gate=_mk(rng, (D, F), jnp.float32, 0.05)
                   if gated else None)
    ln2 = _mk(rng, (D,), jnp.float32, 0.1)
    w = df.PackedFFNWeights(w_in=fp.w_in, w_out=fp.w_out, ln2=ln2,
                            w_gate=fp.w_gate)
    got = _fused_ffn_tail(ctx, cfg, scfg, {"ffn": w, "ln2": ln2}, x, a, w)
    want = _unfused_tail(ctx, cfg, x, a, fp, ln2, None, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Cluster sweeps — 8 emulated devices in a subprocess
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_fused_ffn_tail_cluster_sweep():
    """Fused tail vs the ffn_apply oracle on a sharded 8-rank model axis
    at cluster sizes {1, 2, 4} (heads × cluster factorings), gated +
    ungated, pre- and post-norm, with a ragged batch that includes a
    zeroed free slot.  The fused ClusterReduce spans the FULL model axis
    regardless of the attention factoring — the sweep proves the
    replacement for psum_model is factoring-invariant."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.core import dataflow as df
    from repro.models.ctx import make_train_ctx
    from repro.models.layers import FFNParams, ffn_apply, rms_norm
    from repro.serving.engine import ServeConfig, _fused_ffn_tail
    mesh = jax.make_mesh((8,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = reduced(get_config("llama2-7b"))
    rng = np.random.default_rng(0)
    B, D, F = 3, cfg.d_model, 48
    X = jnp.asarray(rng.standard_normal((B, D)) * 0.3, jnp.float32)
    A = jnp.asarray(rng.standard_normal((B, D)) * 0.3, jnp.float32)
    X = X.at[1].set(0.0)          # free-slot row: zeroed residual stream
    A = A.at[1].set(0.0)
    WI = jnp.asarray(rng.standard_normal((D, F)) * 0.05, jnp.float32)
    WG = jnp.asarray(rng.standard_normal((D, F)) * 0.05, jnp.float32)
    WOUT = jnp.asarray(rng.standard_normal((F, D)) * 0.05, jnp.float32)
    LN2 = jnp.asarray(rng.standard_normal((D,)) * 0.1, jnp.float32)
    P1 = jnp.asarray(rng.standard_normal((D,)) * 0.1, jnp.float32)
    P2 = jnp.asarray(rng.standard_normal((D,)) * 0.1, jnp.float32)

    for N in (1, 2, 4):
        for gated in (True, False):
            for post in (False, True):
                scfg = ServeConfig(max_seq=16, batch_local=B,
                                   backend="pallas", interpret=True,
                                   block_f=4)

                def body(x, a, wi, wg, wout, ln2, p1, p2):
                    ctx = make_train_ctx("model", heads_sub=8 // N,
                                         model_size=8)
                    r = jax.lax.axis_index("model")
                    floc = F // 8
                    dsl = jax.lax.dynamic_slice_in_dim
                    wi_l = dsl(wi, r * floc, floc, axis=1)
                    wg_l = dsl(wg, r * floc, floc, axis=1) if gated \\
                        else None
                    wo_l = dsl(wout, r * floc, floc, axis=0)
                    w = df.PackedFFNWeights(
                        w_in=wi_l, w_out=wo_l, ln2=ln2, w_gate=wg_l,
                        post_ln1=p1 if post else None)
                    blk = {"ffn": w, "ln2": ln2}
                    if post:
                        blk["post_ln1"] = p1
                        blk["post_ln2"] = p2
                    fused = _fused_ffn_tail(ctx, cfg, scfg, blk, x, a, w)
                    av = rms_norm(a, p1, cfg.norm_eps) if post else a
                    x1 = x + av
                    h = rms_norm(x1, ln2, cfg.norm_eps)
                    f = ffn_apply(ctx, FFNParams(w_in=wi_l, w_out=wo_l,
                                                 w_gate=wg_l),
                                  h, cfg.ffn_act)
                    if post:
                        f = rms_norm(f, p2, cfg.norm_eps)
                    return fused[None], (x1 + f)[None]

                got, want = jax.jit(shard_map(
                    body, mesh=mesh, in_specs=(P(),) * 8,
                    out_specs=(P("model"), P("model")),
                    check_vma=False))(X, A, WI, WG, WOUT, LN2, P1, P2)
                got = np.asarray(got, np.float32)
                assert np.isfinite(got).all(), (N, gated, post)
                err = np.abs(got - np.asarray(want, np.float32)).max()
                assert err <= 1e-4, (N, gated, post, err)
        print("FUSED FFN TAIL OK N =", N)
    """, timeout=1800)


@pytest.mark.multidevice
def test_engine_fullblock_parity_cluster_sweep():
    """Full-engine token parity of the two-launch fused layer vs the XLA
    oracle at forced cluster sizes {1, 2, 4}, GQA (llama2, fused FFN) +
    MLA (deepseek, fused in-kernel norm): the first sampled token (pure
    prefill) must agree exactly, and per-step greedy tokens over a
    FORCED token stream (no cascade) must agree on ≥90% of (step, slot)
    cells — bf16 near-ties flip the argmax at this reduced scale on the
    pre-existing paths too."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import build_engine
    for arch in ("llama2-7b", "deepseek-v2-lite"):
        cfg = reduced(get_config(arch))
        mesh = make_test_mesh()
        for n in (1, 2, 4):
            res = {}
            for backend in ("xla", "pallas"):
                params, pf, dec, state, lay, scfg = build_engine(
                    cfg, mesh, max_seq=48, batch_global=4, cluster=n,
                    backend=backend, interpret=(backend == "pallas"))
                assert scfg.prepack == (backend == "pallas"), scfg
                key = jax.random.PRNGKey(0)
                prompts = jax.random.randint(key, (4, 12), 0,
                                             cfg.vocab_size)
                nxt, st = pf(params["train"], state, prompts, None)
                toks = jax.random.randint(jax.random.PRNGKey(3), (8, 4),
                                          0, cfg.vocab_size)
                outs = [np.asarray(nxt)]
                for t in range(8):
                    o, st = dec(params["serve"], st, toks[t])
                    outs.append(np.asarray(o))
                res[backend] = np.stack(outs)
            # prefill goes through the training layout on both builds
            np.testing.assert_array_equal(res["xla"][0], res["pallas"][0])
            agree = (res["xla"] == res["pallas"]).mean()
            assert agree >= 0.9, (arch, n, agree)
            print("ENGINE FULL-BLOCK PARITY OK", arch, "N =", n, agree)
    """, timeout=1800)
