"""SDC hardening: integrity fingerprints, bit-level fault sweeps, and
the router's quarantine/heal path (serving/integrity.py,
serving/sweep.py, DESIGN.md §9).

Fast (unmarked) tier: FaultSpec/FaultSweep validation and the
host-vs-device checksum algebra — pure array math, no engines.

Chaos tier (the CI ``chaos`` job):

* single-bit KV flips (mantissa / low- and high-exponent) are detected
  within ≤ 1 tick by the KV fingerprint probe and recover to streams
  byte-equal to the fault-free oracle;
* single-bit weight flips are detected by the rotating weight probe
  within the deferred-commit window, the replica HEALS (serve layout
  re-materialized from the train view, fingerprints re-verified) and
  rejoins — streams stay byte-equal;
* the fault-free control: ALL probes enabled over a slot-reusing trace
  (re-admits included — the fingerprint recompute-on-admit path) fires
  ZERO signals and produces streams byte-equal to the probes-off run,
  with the probe overhead accounted in the tracecount probe counters;
* the shadow recompute catches head-path corruption with the weight
  probe disabled;
* the requeue-storm guard terminally FAILs requests past the cap;
* the small deterministic sub-sweep (the same grid the bench emits)
  reports 100% detection and 100% oracle exactness.

The full 16-bit systematic sweep is the slow tier (nightly CI).
"""
import math

import jax
import numpy as np
import pytest

from repro.core import tracecount
from repro.serving.faults import (ALL_FAULT_KINDS, BIT_FAULT_KINDS,
                                  FaultInjector, FaultSpec, FaultSweep)
from repro.serving.integrity import (IntegrityConfig, IntegrityMonitor,
                                     _np_u32, kv_entry_fp, np_kv_entry_fp,
                                     weight_leaves)
from repro.serving.router import Router
from repro.serving.scheduler import Request
from repro.serving.sweep import format_coverage, run_sdc_sweep


# ---------------------------------------------------------------------------
# Fast tier: spec validation + checksum algebra (no engines)
# ---------------------------------------------------------------------------
def test_fault_spec_validation_names_offending_field():
    with pytest.raises(ValueError, match="step"):
        FaultSpec("kill", step=-1)
    with pytest.raises(ValueError, match="replica"):
        FaultSpec("kill", step=0, replica=-2)
    with pytest.raises(ValueError, match="target"):
        FaultSpec("kill", step=0, target=-1)
    with pytest.raises(ValueError, match="bit"):
        FaultSpec("flip_kv_bit", step=0)            # bit required
    with pytest.raises(ValueError, match="bit"):
        FaultSpec("flip_kv_bit", step=0, bit=16)    # out of bf16 range
    with pytest.raises(ValueError, match="bit"):
        FaultSpec("kill", step=0, bit=3)            # bit is flip_*-only
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("rowhammer", step=0)
    # the valid corners construct
    FaultSpec("flip_kv_bit", step=0, bit=0)
    FaultSpec("flip_weight_bit", step=0, bit=15)


def test_injector_rejects_duplicate_spec_address():
    a = FaultSpec("flip_kv_bit", step=2, target=0, bit=3)
    b = FaultSpec("flip_kv_bit", step=2, target=0, bit=9)
    with pytest.raises(ValueError, match="duplicate"):
        FaultInjector([a, b])       # same (kind, target, step, replica)
    FaultInjector([a, FaultSpec("flip_kv_bit", step=3, target=0, bit=9)])


def test_fault_sweep_grid_is_systematic():
    sw = FaultSweep(kinds=BIT_FAULT_KINDS, targets=(0, 1),
                    bits=(0, 7, 15), steps=(2, 4), replicas=(0,))
    specs = sw.specs()
    assert len(specs) == 2 * 2 * 3 * 2
    assert len(set(specs)) == len(specs)            # no duplicates
    assert all(s.kind in BIT_FAULT_KINDS for s in specs)
    assert {s.bit for s in specs} == {0, 7, 15}
    assert set(ALL_FAULT_KINDS) >= set(sw.kinds)
    # default grid covers every bf16 bit position
    assert {s.bit for s in FaultSweep().specs()} == set(range(16))


def _rand_entry(rng, n_groups=2, s_blk=3, B=2, rows_per=2, hd=4):
    import ml_dtypes
    from types import SimpleNamespace
    shape = (n_groups, s_blk, B * rows_per, hd)
    k = (rng.standard_normal(shape) * 4).astype(ml_dtypes.bfloat16)
    v = (rng.standard_normal(shape) * 4).astype(ml_dtypes.bfloat16)
    return SimpleNamespace(k=k, v=v), B


def test_checksum_host_device_parity_and_bit_sensitivity():
    """The jnp and numpy checksum mirrors agree mod 2^32, and flipping
    ANY single bit of any element moves exactly the victim slot's
    checksum — the property the ≤1-tick KV detection bound rests on."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    entry, B = _rand_entry(rng)
    dev_entry = type(entry)(k=jnp.asarray(entry.k), v=jnp.asarray(entry.v))
    dev = _np_u32(np.asarray(kv_entry_fp(dev_entry, B)))
    host = np_kv_entry_fp(entry.k[None, None], entry.v[None, None], B)[0, 0]
    assert dev.shape == host.shape == (2, B)
    assert (dev == host).all()

    for trial in range(12):
        r2 = np.random.default_rng(100 + trial)
        bit = int(r2.integers(16))
        flat = entry.k.reshape(-1).view(np.uint16).copy()
        i = int(r2.integers(flat.size))
        flat[i] ^= np.uint16(1 << bit)
        k2 = flat.view(entry.k.dtype).reshape(entry.k.shape)
        host2 = np_kv_entry_fp(k2[None, None], entry.v[None, None], B)[0, 0]
        changed = host2 != host
        # exactly the (group, slot) owning element i moved
        g, _, row, _ = np.unravel_index(i, entry.k.shape)
        slot = (row % (entry.k.shape[-2])) // (entry.k.shape[-2] // B)
        assert changed.sum() == 1, (trial, bit)
        assert changed[g, slot], (trial, bit)


def test_format_coverage_renders_all_rows():
    cells = {
        "fault_free": {"false_positive_signals": 0.0, "streams_match": 1.0,
                       "probe_bytes_per_tick": 1234.0},
        "flip_kv_bit_bit7": {"detected_pct": 100.0, "detect_steps": 0.0,
                             "oracle_exact_pct": 100.0},
    }
    out = format_coverage(cells)
    assert "flip_kv_bit_bit7" in out and "fault_free" in out
    assert "100.0" in out and "signals=0" in out


# ---------------------------------------------------------------------------
# Chaos tier: live engines
# ---------------------------------------------------------------------------
_FLEET = None


def _fleet():
    """Module-cached 2-replica GQA fleet with every integrity leaf
    enabled (build_replicas defaults kv_fingerprint/shadow_head ON)."""
    global _FLEET
    if _FLEET is None:
        import dataclasses

        from repro.configs import get_config, reduced
        from repro.launch.mesh import make_test_mesh
        from repro.launch.serve import EngineOptions, build_replicas
        cfg = reduced(get_config("llama2-7b"))
        if cfg.moe is not None:
            cfg = dataclasses.replace(cfg, moe=None)
        mesh = make_test_mesh(data=1, model=1)
        _FLEET = cfg, build_replicas(
            cfg, mesh, n_replicas=2, max_seq=32, batch_global=2,
            options=EngineOptions(backend="xla", check_finite=True,
                                  kv_fingerprint=True, shadow_head=True))
    return _FLEET


def _mk_trace(cfg, seed, n_req=6):
    rng = np.random.default_rng(seed)
    trace = []
    for rid in range(n_req):
        plen = int(rng.integers(2, 7))
        trace.append((int(rng.integers(0, 4)), Request(
            rid, [int(t) for t in rng.integers(1, cfg.vocab_size, plen)],
            int(rng.integers(3, 7)))))
    return trace


def _run(engines, trace, *, injectors=None, integrity=None,
         max_requeues=None):
    return Router(engines, prompt_cap=8, max_new_cap=8,
                  injectors=injectors, integrity=integrity,
                  max_requeues=max_requeues).run(
        [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])


def _restore(engines):
    for eng in engines:
        eng.params["serve"] = eng.repack_fn(eng.params["train"])


def _streams(journal):
    return {rid: list(e.tokens) for rid, e in journal.items()}


@pytest.mark.chaos
def test_engine_flags_gate_integrity_leaves_and_traces():
    """kv_fingerprint=False builds a step that traces ZERO fp updates
    and carries no checksum leaves (the bench path is untouched);
    kv_fingerprint=True traces exactly one update per program."""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import build_engine_full
    cfg = reduced(get_config("llama2-7b"))
    mesh = make_test_mesh(data=1, model=1)
    counts = {}
    for flag in (False, True):
        from repro.launch.serve import EngineOptions
        eng = build_engine_full(
            cfg, mesh, max_seq=16, batch_global=1,
            options=EngineOptions(backend="xla", kv_fingerprint=flag,
                                  shadow_head=flag))
        assert ("kv_fp" in eng.state) == flag
        assert ("head_resid" in eng.state) == flag
        with tracecount.counting() as c:
            tok = np.zeros((1,), np.int32)
            eng.decode_fn(eng.params["serve"], eng.state, tok)
            counts[flag] = c.get("kv_fp_update", 0)
        if not flag:
            with pytest.raises(ValueError, match="kv_fingerprint"):
                IntegrityMonitor(eng, IntegrityConfig())
            with pytest.raises(ValueError, match="shadow_head"):
                IntegrityMonitor(eng, IntegrityConfig(kv=False))
    assert counts[False] == 0
    assert counts[True] == 1


@pytest.mark.chaos
def test_fault_free_all_probes_zero_signals_streams_equal():
    """The false-positive control (satellite): a fault-free trace that
    REUSES slots (6 requests over 2 slots — re-admits exercise the
    recompute-on-admit fingerprint path) with every probe enabled fires
    zero signals and emits streams byte-equal to the probes-off run,
    with the probe overhead accounted in the tracecount counters."""
    cfg, engines = _fleet()
    trace = _mk_trace(cfg, seed=0)
    oracle = _streams(_run(engines, trace))
    tracecount.reset_signals()
    tracecount.reset_probes()
    icfg = IntegrityConfig(weight_leaves_per_tick=4)
    router = Router(engines, prompt_cap=8, max_new_cap=8, integrity=icfg)
    assert router.commit_lag == math.ceil(
        len(weight_leaves(engines[0].params["serve"])) / 4)
    journal = router.run(
        [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])
    assert sum(tracecount.signal_totals().values()) == 0
    assert not router.detections
    assert router.availability() == 1.0
    assert _streams(journal) == oracle
    pt = tracecount.probe_totals()
    assert pt["probe_ticks"] == router.tick * len(engines)
    for fam in ("probe_bytes_kv", "probe_bytes_weights",
                "probe_bytes_shadow"):
        assert pt[fam] > 0, fam


@pytest.mark.chaos
@pytest.mark.parametrize("bit", [0, 7, 14], ids=["mantissa0", "exp7",
                                                 "exp14"])
def test_flip_kv_bit_detected_within_one_tick_streams_exact(bit):
    """Acceptance: single-bit KV flips — including exponent bits below
    the non-finite floor — are detected by the fingerprint probe within
    ≤ 1 tick and recover to byte-exact streams."""
    cfg, engines = _fleet()
    trace = _mk_trace(cfg, seed=0)
    oracle = _streams(_run(engines, trace))
    tracecount.reset_signals()
    inj = FaultInjector([FaultSpec("flip_kv_bit", step=2, target=0,
                                   bit=bit)])
    icfg = IntegrityConfig(weight_leaves_per_tick=4)
    router = Router(engines, prompt_cap=8, max_new_cap=8,
                    injectors={0: inj}, integrity=icfg)
    journal = router.run(
        [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])
    assert len(inj.fired) == 1
    lat = router.detection_latency(inj)
    assert lat[0] in (0, 1), lat
    sig = tracecount.signal_totals()
    assert sig["detect_kv_fingerprint"] >= 1
    if bit < 14:
        # mantissa / low-exponent flips stay finite — BELOW the
        # non-finite floor, the fingerprint is the only detector.
        # (A bit-14 flip of a value in [2, 4) lands exactly on Inf,
        # so the sentinel may fire too — defense in depth.)
        assert sig["detect_nonfinite"] == 0
    assert _streams(journal) == oracle
    assert all(e.done for e in journal.values())
    _restore(engines)


@pytest.mark.chaos
@pytest.mark.parametrize("bit", [0, 14], ids=["mantissa0", "exp14"])
def test_flip_weight_bit_detected_healed_streams_exact(bit):
    """Acceptance: a persistent single-bit weight flip is caught by the
    rotating fingerprint probe within the deferred-commit window, the
    replica heals (repack from train + full re-verification) and
    rejoins, and every stream stays byte-equal to the oracle."""
    cfg, engines = _fleet()
    trace = _mk_trace(cfg, seed=0)
    oracle = _streams(_run(engines, trace))
    tracecount.reset_signals()
    inj = FaultInjector([FaultSpec("flip_weight_bit", step=2, target=1,
                                   bit=bit)])
    icfg = IntegrityConfig(weight_leaves_per_tick=4)
    router = Router(engines, prompt_cap=8, max_new_cap=8,
                    injectors={0: inj}, integrity=icfg)
    journal = router.run(
        [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])
    assert len(inj.fired) == 1 and inj.flipped_weight
    lat = router.detection_latency(inj)
    assert 0 <= lat[0] <= router.commit_lag, (lat, router.commit_lag)
    sig = tracecount.signal_totals()
    assert sig["detect_weight_fingerprint"] >= 1
    assert sig["replica_healed"] == 1         # quarantined, then rejoined
    heal_events = [e for e in router.events if e[1] == "heal"]
    assert len(heal_events) == 1
    # the corrupt leaf was named in the detection details
    det = router.detections[0]
    assert any(inj.flipped_weight[0] in d for d in det["details"])
    # healed replica re-verifies clean NOW
    mon = router.replicas[0].monitor
    assert mon.verify_weights_full() == []
    assert _streams(journal) == oracle
    assert all(e.done for e in journal.values())
    # availability dipped during quarantine and recovered
    assert 0.0 < router.availability() < 1.0
    assert router.live_frac[-1] == 1.0
    _restore(engines)


@pytest.mark.chaos
def test_shadow_recompute_catches_head_corruption():
    """The shadow probe re-derives a committed token's winning logit
    from the stashed pre-head residual and the PRISTINE host head copy:
    a positive control on a live slot (and on an empty slot's all-zero
    stash), then single-bit / single-component corruption of each stash
    leg — logit value (exact 2×, finite, so the non-finite sentinel is
    blind to it), token id, and residual — every one caught, with the
    KV and weight probes disabled."""
    cfg, engines = _fleet()
    eng = engines[0]
    from repro.serving.scheduler import SlotScheduler
    mon = IntegrityMonitor(eng, IntegrityConfig(kv=False, weights=False))
    assert mon.commit_lag() == 0              # no rotation → no deferral
    sched = SlotScheduler(eng, prompt_cap=8)
    rng = np.random.default_rng(0)
    sched.submit(Request(0, [int(t) for t in rng.integers(
        1, cfg.vocab_size, 4)], 6))
    for _ in range(3):
        sched.step()
    state = sched.state
    assert mon.verify_shadow(state, 0)        # live stash passes
    assert mon.verify_shadow(state, 1)        # empty slot passes trivially

    val = np.array(jax.device_get(state["head_val"]))
    assert float(val.reshape(-1, sched.n_slots)[0, 0]) != 0.0
    u = val.reshape(-1).view(np.uint32)
    u[0] ^= np.uint32(1 << 23)                # f32 exponent LSB: exact 2x
    assert not mon.verify_shadow({**state, "head_val": val}, 0)

    tok = np.array(jax.device_get(state["head_tok"]))
    tok.reshape(-1, sched.n_slots)[:, 0] = (
        tok.reshape(-1, sched.n_slots)[:, 0] + 1) % cfg.vocab_size
    assert not mon.verify_shadow({**state, "head_tok": tok}, 0)

    resid = np.array(jax.device_get(state["head_resid"]))
    r16 = resid.reshape(-1).view(np.uint16)
    r16[:cfg.d_model] ^= np.uint16(1 << 7)    # bf16 exponent LSB row flip
    assert not mon.verify_shadow({**state, "head_resid": resid}, 0)

    # the engine's own state was never touched — probe still clean
    assert mon.verify_shadow(sched.state, 0)


@pytest.mark.chaos
def test_max_requeues_terminal_failed_status():
    """The requeue-storm guard (satellite): with max_requeues=0, a
    replica failure terminally FAILs its in-flight requests in the
    journal instead of re-queueing; untouched requests still finish."""
    cfg, engines = _fleet()
    trace = _mk_trace(cfg, seed=0)
    tracecount.reset_signals()
    inj = FaultInjector([FaultSpec("kill", step=2, replica=0)])
    router = Router(engines, prompt_cap=8, max_new_cap=8,
                    injectors={0: inj}, max_requeues=0)
    journal = router.run(
        [(t, Request(r.rid, r.prompt, r.max_new)) for t, r in trace])
    failed = [e for e in journal.values() if e.failed]
    assert failed                               # the in-flight victims
    assert all(not e.done and e.requeues == 1 for e in failed)
    assert tracecount.signal_totals()["request_failed"] == len(failed)
    assert any(ev[1] == "request_failed" for ev in router.events)
    done = [e for e in journal.values() if e.done]
    assert done and all(not e.failed for e in done)
    with pytest.raises(ValueError, match="max_requeues"):
        Router(engines, prompt_cap=8, max_new_cap=8, max_requeues=-1)


@pytest.mark.chaos
def test_router_rejects_out_of_range_injector_replica():
    cfg, engines = _fleet()
    inj = FaultInjector([FaultSpec("kill", step=0, replica=0)])
    with pytest.raises(ValueError, match="replica"):
        Router(engines, prompt_cap=8, max_new_cap=8, injectors={7: inj})
    bad = FaultInjector([FaultSpec("kill", step=0, replica=5)])
    with pytest.raises(ValueError, match="replica"):
        Router(engines, prompt_cap=8, max_new_cap=8, injectors={0: bad})


@pytest.mark.chaos
def test_deterministic_sub_sweep_full_coverage():
    """The CI sub-sweep (the same grid the bench's sdc_sweep section
    emits): representative mantissa/exponent bits over both flip kinds
    — 100% detection, 100% oracle exactness, zero false positives,
    KV flips within ≤ 1 tick."""
    cfg, engines = _fleet()
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(1, cfg.vocab_size, 4)]
               for _ in range(3)]
    cells = run_sdc_sweep(
        engines, prompts=prompts, max_new=6, prompt_cap=8,
        sweep=FaultSweep(bits=(0, 7, 14)),
        icfg=IntegrityConfig(weight_leaves_per_tick=4))
    ff = cells.pop("fault_free")
    assert ff["false_positive_signals"] == 0
    assert ff["streams_match"] == 1.0
    assert ff["probe_bytes_per_tick"] > 0
    assert len(cells) == 6                    # 2 kinds × 3 bits
    for key, c in cells.items():
        assert c["detected_pct"] == 100.0, key
        assert c["oracle_exact_pct"] == 100.0, key
        if key.startswith("flip_kv_bit"):
            assert c["detect_steps"] <= 1, (key, c)


@pytest.mark.slow
@pytest.mark.chaos
def test_full_systematic_sweep_every_bit_position():
    """Nightly: the FULL single-bit grid — every bf16 bit position, both
    fault kinds — detects 100% with byte-exact recovery (the measured
    detection floor DESIGN.md §9 cites)."""
    cfg, engines = _fleet()
    rng = np.random.default_rng(1)
    prompts = [[int(t) for t in rng.integers(1, cfg.vocab_size, 4)]
               for _ in range(3)]
    cells = run_sdc_sweep(
        engines, prompts=prompts, max_new=6, prompt_cap=8,
        sweep=FaultSweep(),                   # all 16 bits
        icfg=IntegrityConfig(weight_leaves_per_tick=4))
    print(format_coverage(cells))
    ff = cells.pop("fault_free")
    assert ff["false_positive_signals"] == 0
    assert ff["streams_match"] == 1.0
    assert len(cells) == 32                   # 2 kinds × 16 bits
    for key, c in cells.items():
        assert c["detected_pct"] == 100.0, key
        assert c["oracle_exact_pct"] == 100.0, key
        if key.startswith("flip_kv_bit"):
            assert c["detect_steps"] <= 1, (key, c)
