"""Dry-run machinery on CI scale: lower+compile representative cells in a
512-host-device subprocess (the full 40-cell × 2-mesh sweep is run by
``python -m repro.launch.dryrun --all --both-meshes``; its results are
recorded in EXPERIMENTS.md)."""
import os
import subprocess
import sys


SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run_cells(cells, multi_pod=False, timeout=560):
    code = f"""
import repro.launch.dryrun as dr
import sys
fail = 0
for arch, shape in {cells!r}:
    r = dr.run_cell(arch, shape, multi_pod={multi_pod})
    if "error" in r:
        fail += 1
sys.exit(fail)
"""
    proc = subprocess.run([sys.executable, "-c", code], timeout=timeout,
                          capture_output=True, text=True,
                          env={**os.environ, "PYTHONPATH": SRC})
    assert proc.returncode == 0, proc.stderr[-3000:] + proc.stdout[-2000:]
    return proc.stdout


def test_dryrun_decode_cells():
    out = _run_cells([("granite-8b", "decode_32k"),
                      ("rwkv6-3b", "long_500k")])
    assert "dominant" in out


def test_dryrun_train_cell_multipod():
    out = _run_cells([("minitron-4b", "train_4k")], multi_pod=True)
    assert "2x16x16" in out


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[64]{0} all-reduce(%y), to_apply=%add
  %cp = bf16[4,4]{1,0} collective-permute(%z)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 8 * 128 * 2
    assert got["all-reduce"] == 64 * 4
    assert got["collective-permute"] == 32
    assert got["total"] == 8 * 128 * 2 + 256 + 32
