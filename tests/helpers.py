"""Test helpers: subprocess runner for multi-device tests.

The main pytest process keeps ONE CPU device (per assignment: no global
XLA_FLAGS).  Tests that need a mesh spawn a subprocess that sets
``--xla_force_host_platform_device_count=8`` before importing jax.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {src!r})
import repro  # installs repro.compat JAX version shims
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
"""


def run_multidevice(body: str, timeout: int = 900) -> str:
    """Run ``body`` (python source) in a subprocess with 8 host devices.
    Raises on nonzero exit; returns stdout."""
    script = PRELUDE.format(src=os.path.abspath(SRC)) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=timeout,
        env={**os.environ, "PYTHONPATH": os.path.abspath(SRC)})
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout[-4000:]}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout
