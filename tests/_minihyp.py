"""Deterministic fallback for the hypothesis API subset our property
tests use — so ``test_properties.py`` RUNS in tier-1 even when the
container lacks hypothesis (it is in requirements-dev.txt; CI installs
the real thing and gets shrinking + the registered "ci" profile from
conftest.py).

Semantics: ``@given`` draws ``max_examples`` example tuples from a
PRNG seeded by the test name (stable across runs and machines — a
failure reproduces by just re-running the test) and calls the test
once per tuple.  No shrinking, no database; strategies implement only
what the suite draws: integers, floats, sampled_from, lists, composite.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example_from(self, rng):
        return self._sample(rng)

    def map(self, f):
        return _Strategy(lambda rng: f(self._sample(rng)))


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value, **_):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        def sample(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.example_from(rng) for _ in range(n)]
        return _Strategy(sample)

    @staticmethod
    def composite(fn):
        def build(*args, **kw):
            def sample(rng):
                return fn(lambda s: s.example_from(rng), *args, **kw)
            return _Strategy(sample)
        return build


# expose the usual alias
st = strategies


def settings(max_examples=20, deadline=None, **_):
    def deco(fn):
        fn._minihyp_max_examples = max_examples
        return fn
    return deco


def given(*strats, **kw_strats):
    assert not kw_strats, "minihyp supports positional strategies only"

    def deco(fn):
        n = getattr(fn, "_minihyp_max_examples", 20)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(
                zlib.crc32(fn.__name__.encode()) & 0x7FFFFFFF)
            for _ in range(n):
                drawn = tuple(s.example_from(rng) for s in strats)
                fn(*args, *drawn, **kwargs)

        # pytest must not see the drawn parameters as fixtures
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
