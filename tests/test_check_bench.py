"""The CI perf-regression gate (scripts/check_bench.py).

The gate diffs the deterministic BENCH_tpot.json columns (trace-time
launch/psum counts, modeled ICI/HBM bytes) against the committed
baseline; these tests lock its comparison semantics: counters exact in
both directions, byte columns one-sided with tolerance, vanished cells
fail, new cells and improvements pass, and the delta table always
names the offending column.
"""
import copy
import importlib.util
import json
import os

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "..", "scripts",
                       "check_bench.py")


@pytest.fixture(scope="module")
def cb():
    spec = importlib.util.spec_from_file_location("check_bench", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _report(**overrides):
    cell = {
        "tpot_us": 123.4,                     # wall time: never gated
        "pallas_launches_per_step": 5,
        "psum_model_per_step": 1,
        "ici_weight_gather_bytes_per_step": 0.0,
        "ffn_psum_ici_bytes_per_step": 0.0,
        "ffn_fused_reduce_ici_bytes_per_step": 3072.0,
        "head_ici_bytes_per_step": 768.0,
        "head_hbm_logits_bytes_per_step": 0.0,
        "head_sample_k": 8,
    }
    cell.update(overrides)
    return {"archs": {"llama2-7b": {"variants": {"pallas_prepack": cell}}}}


def _chaos_report(**overrides):
    cell = {
        "detect_steps": 0,
        "recovery_steps": 4,
        "availability_pct": 62.5,
        "oracle_exact_pct": 100.0,
        "ticks": 11,                          # context, never gated
    }
    cell.update(overrides)
    rep = _report()
    rep["router_chaos"] = {"arch": "llama2-7b",
                           "faults": {"corrupt_kv": cell}}
    return rep


def test_identical_reports_pass(cb):
    base = _report()
    ok, table = cb.check(copy.deepcopy(base), base)
    assert ok
    assert "0 regressions" in table


def test_wall_time_changes_are_not_gated(cb):
    ok, _ = cb.check(_report(tpot_us=9999.0), _report(tpot_us=1.0))
    assert ok


def test_counter_change_fails_both_directions(cb):
    for launches in (4, 6):                   # drop AND rise both fail
        ok, table = cb.check(_report(pallas_launches_per_step=launches),
                             _report())
        assert not ok, launches
        assert "pallas_launches_per_step" in table
        assert "count changed" in table


def test_head_sample_k_gates_exactly_both_directions(cb):
    """The fused tail's candidate width is a count column: silently
    widening (more ICI per step) or narrowing (smaller top-k/top-p
    exactness envelope) both fail, even though the byte columns would
    only catch the widening."""
    for k in (4, 16):
        ok, table = cb.check(_report(head_sample_k=k), _report())
        assert not ok, k
        assert "head_sample_k" in table
        assert "count changed" in table
    ok, _ = cb.check(_report(), _report())
    assert ok


def test_byte_increase_beyond_tolerance_fails(cb):
    ok, table = cb.check(_report(head_hbm_logits_bytes_per_step=4096.0),
                         _report())
    assert not ok
    assert "head_hbm_logits_bytes_per_step" in table
    assert "bytes up" in table


def test_byte_increase_within_tolerance_passes(cb):
    ok, _ = cb.check(_report(head_ici_bytes_per_step=768.0 * 1.005),
                     _report())
    assert ok


def test_byte_decrease_is_an_improvement(cb):
    ok, table = cb.check(_report(ffn_fused_reduce_ici_bytes_per_step=0.0),
                         _report())
    assert ok
    assert "improved" in table
    assert "refresh" in table                 # baseline-update nudge


def test_vanished_cell_fails_new_cell_passes(cb):
    base = _report()
    cur = copy.deepcopy(base)
    # a whole variant silently dropping out of the bench is a regression
    del cur["archs"]["llama2-7b"]["variants"]["pallas_prepack"]
    cur["archs"]["llama2-7b"]["variants"]["pallas_new"] = \
        copy.deepcopy(base["archs"]["llama2-7b"]["variants"]["pallas_prepack"])
    ok, table = cb.check(cur, base)
    assert not ok
    assert "vanished" in table
    assert "NEW" in table
    # symmetric: only adding is fine
    ok2, _ = cb.check(cur, {"archs": {}})
    assert ok2


def test_missing_column_in_current_fails(cb):
    cur = _report()
    del cur["archs"]["llama2-7b"]["variants"]["pallas_prepack"][
        "head_hbm_logits_bytes_per_step"]
    ok, table = cb.check(cur, _report())
    assert not ok
    assert "head_hbm_logits_bytes_per_step" in table


def test_main_exit_codes_and_table(cb, tmp_path, capsys):
    b = tmp_path / "base.json"
    c = tmp_path / "cur.json"
    b.write_text(json.dumps(_report()))
    c.write_text(json.dumps(_report()))
    assert cb.main(["check_bench", str(c), str(b)]) == 0
    out = capsys.readouterr().out
    assert "PASS" in out and "arch/variant" in out
    c.write_text(json.dumps(_report(psum_model_per_step=7)))
    assert cb.main(["check_bench", str(c), str(b)]) == 1
    assert "FAIL" in capsys.readouterr().out


def _sdc_report(cell="flip_kv_bit_bit7", **overrides):
    cells = {
        "fault_free": {"false_positive_signals": 0.0,
                       "streams_match": 1.0,
                       "probe_bytes_per_tick": 431128.0},
        "flip_kv_bit_bit7": {"detected_pct": 100.0, "detect_steps": 0.0,
                             "oracle_exact_pct": 100.0},
    }
    cells[cell] = {**cells[cell], **overrides}
    rep = _report()
    rep["sdc_sweep"] = {"arch": "llama2-7b", "cells": cells}
    return rep


def test_router_chaos_cells_gate_exactly(cb):
    """Every fleet-chaos column is a robustness invariant: slower
    detection, longer recovery, lower availability, or a stream
    diverging from the oracle all FAIL exactly — in both directions
    (an unexplained improvement means the scenario changed)."""
    base = _chaos_report()
    ok, table = cb.check(copy.deepcopy(base), base)
    assert ok
    for col, bad in (("detect_steps", 2), ("recovery_steps", 9),
                     ("availability_pct", 50.0),
                     ("oracle_exact_pct", 83.3)):
        ok, table = cb.check(_chaos_report(**{col: bad}), base)
        assert not ok, col
        assert col in table and "router_chaos/corrupt_kv" in table
    # wall-free context columns (tick counts) are never gated
    ok, _ = cb.check(_chaos_report(ticks=99), base)
    assert ok
    # a fault kind vanishing from the sweep is a regression
    cur = copy.deepcopy(base)
    del cur["router_chaos"]["faults"]["corrupt_kv"]
    ok, table = cb.check(cur, base)
    assert not ok
    assert "vanished" in table


def test_sdc_sweep_cells_gate(cb):
    """The SDC coverage matrix is a robustness invariant: coverage,
    latency, exactness, false-positive count and stream equality gate
    EXACTLY in both directions; the probe-overhead bytes column is
    one-sided with tolerance (more probing is not a regression signal
    by itself — less coverage shows up in the count columns)."""
    base = _sdc_report()
    ok, _ = cb.check(copy.deepcopy(base), base)
    assert ok
    for col, bad in (("detected_pct", 50.0), ("detect_steps", 3.0),
                     ("oracle_exact_pct", 66.7)):
        ok, table = cb.check(_sdc_report(**{col: bad}), base)
        assert not ok, col
        assert col in table and "sdc_sweep/flip_kv_bit_bit7" in table
    for col, bad in (("false_positive_signals", 1.0),
                     ("streams_match", 0.0)):
        ok, table = cb.check(_sdc_report("fault_free", **{col: bad}),
                             base)
        assert not ok, col
        assert "sdc_sweep/fault_free" in table
    # probe bytes: one-sided with 5% tolerance
    ok, _ = cb.check(_sdc_report("fault_free",
                                 probe_bytes_per_tick=431128.0 * 1.04),
                     base)
    assert ok
    ok, table = cb.check(_sdc_report("fault_free",
                                     probe_bytes_per_tick=431128.0 * 1.2),
                         base)
    assert not ok
    assert "bytes up" in table
    ok, table = cb.check(_sdc_report("fault_free",
                                     probe_bytes_per_tick=100.0), base)
    assert ok
    assert "improved" in table
    # a coverage cell vanishing from the sweep is a regression
    cur = copy.deepcopy(base)
    del cur["sdc_sweep"]["cells"]["flip_kv_bit_bit7"]
    ok, table = cb.check(cur, base)
    assert not ok
    assert "vanished" in table


def test_committed_baseline_gates_itself(cb):
    """The committed baseline must pass against itself and carry every
    gated column for every cell — guards against committing a stale or
    column-less baseline."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_baseline.json")
    with open(path) as f:
        base = json.load(f)
    ok, _ = cb.check(copy.deepcopy(base), base)
    assert ok
    for arch, e in base["archs"].items():
        for v, d in e["variants"].items():
            for col in cb.GATED_COLUMNS:
                assert col in d, (arch, v, col)
    # the chaos sweep must be in the baseline with every gated column
    # for every fault kind the harness defines
    from repro.serving.faults import FAULT_KINDS
    faults = base["router_chaos"]["faults"]
    assert set(faults) == set(FAULT_KINDS)
    for kind, d in faults.items():
        for col in cb.ROUTER_GATED_COLUMNS:
            assert col in d, (kind, col)
    # the SDC sweep must be in the baseline: the fault-free control row
    # plus one row per (bit fault kind x smoke bit position)
    from repro.serving.faults import BIT_FAULT_KINDS
    cells = base["sdc_sweep"]["cells"]
    assert "fault_free" in cells
    for col in ("false_positive_signals", "streams_match",
                "probe_bytes_per_tick"):
        assert col in cells["fault_free"], col
    smoke_bits = base["sdc_sweep"]["bits"]
    assert smoke_bits, "baseline sdc_sweep ran with no bit positions"
    for kind in BIT_FAULT_KINDS:
        for b in smoke_bits:
            d = cells[f"{kind}_bit{b}"]
            for col in ("detected_pct", "detect_steps",
                        "oracle_exact_pct"):
                assert col in d, (kind, b, col)
    # the detection floor the baseline locks in: full coverage,
    # byte-exact recovery, zero false positives (DESIGN.md §9)
    for key, d in cells.items():
        if key == "fault_free":
            assert d["false_positive_signals"] == 0.0
            assert d["streams_match"] == 1.0
        else:
            assert d["detected_pct"] == 100.0, key
            assert d["oracle_exact_pct"] == 100.0, key
