"""Trace-driven continuous-batching tests (serving/scheduler.py).

* A pure-Python reference simulator replays random arrival/termination
  traces and must agree EVENT-FOR-EVENT (admission slots/ticks, finish
  ticks) with the real scheduler — same policy, no device state.
* Every request's tokens must equal a dense single-request reference run
  through the SAME jitted admit/decode programs (slot independence: the
  other slots' occupancy must not leak into a sequence).
* Invariants: no slot double-assignment, retired slots accumulate ZERO
  attend-step work (state["work_blocks"] — core/tracecount.py) while
  live neighbors keep paying, and the whole-batch decode dispatch stops
  when no slot is active.
* The PR-2 footgun guard: stepping with the full {"train","serve"}
  param pair raises a ValueError naming the fix.
"""
import jax
import numpy as np
import pytest

from helpers import run_multidevice

from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import EngineOptions, build_engine_full
from repro.serving.scheduler import Request, SlotScheduler, replay_trace


# ---------------------------------------------------------------------------
# Pure-Python reference simulator (mirrors SlotScheduler's policy)
# ---------------------------------------------------------------------------
def simulate(trace, n_slots):
    """FIFO queue, lowest-free-slot admission, admit → pre-retire →
    emit → retire each tick.  Returns (events, occupancy)."""
    queue, slots, remaining = [], [None] * n_slots, {}
    events, occ = [], []
    pending = sorted(trace, key=lambda ar: ar[0])
    i, tick = 0, 0

    def idle():
        return not queue and all(s is None for s in slots)

    while i < len(pending) or not idle():
        while i < len(pending) and pending[i][0] <= tick:
            queue.append(pending[i][1])
            i += 1
        free = [b for b, s in enumerate(slots) if s is None]
        while queue and free:
            req, b = queue.pop(0), free.pop(0)
            slots[b] = req.rid
            remaining[req.rid] = req.max_new - 1   # prefill emitted one
            events.append((tick, "admit", req.rid, b))
        for b, rid in enumerate(slots):            # one-token requests
            if rid is not None and remaining[rid] <= 0:
                events.append((tick, "finish", rid, b))
                slots[b] = None
        active = [b for b, s in enumerate(slots) if s is not None]
        for b in active:
            remaining[slots[b]] -= 1
        for b in active:
            if slots[b] is not None and remaining[slots[b]] <= 0:
                events.append((tick, "finish", slots[b], b))
                slots[b] = None
        occ.append(len(active) / n_slots)
        tick += 1
    return events, occ


def _random_trace(rng, n_req, vocab, prompt_cap, max_new_cap):
    trace = []
    for rid in range(n_req):
        arrival = int(rng.integers(0, n_req))
        plen = int(rng.integers(1, prompt_cap + 1))
        n_new = int(rng.integers(1, max_new_cap + 1))
        trace.append((arrival, Request(
            rid, [int(t) for t in rng.integers(0, vocab, plen)], n_new)))
    return trace


def _build(arch="llama2-7b", n_slots=3, max_seq=48, **kw):
    cfg = reduced(get_config(arch))
    mesh = make_test_mesh(data=1, model=1)
    eng = build_engine_full(
        cfg, mesh, max_seq=max_seq, batch_global=n_slots,
        options=EngineOptions(backend="xla", track_work=True, **kw))
    return cfg, eng


def _reference_tokens(eng, prompt_cap, req):
    """Dense single-request run through the same jitted programs: admit
    into slot 0 of an all-free batch, decode alone."""
    B = eng.batch_global
    state = eng.retire_fn(eng.state, np.ones((B,), np.int32))
    toks = np.zeros((B, prompt_cap), np.int32)
    lens = np.zeros((B,), np.int32)
    toks[0, :len(req.prompt)] = np.asarray(req.prompt, np.int32)
    lens[0] = len(req.prompt)
    first, st = eng.admit_fn(eng.params["train"], state, toks, lens)
    out = [int(np.asarray(jax.device_get(first)).reshape(-1)[0])]
    for _ in range(req.max_new - 1):
        tok_in = np.zeros((B,), np.int32)
        tok_in[0] = out[-1]
        nxt, st = eng.decode_fn(eng.params["serve"], st, tok_in)
        out.append(int(np.asarray(jax.device_get(nxt)).reshape(-1)[0]))
    return out


# ---------------------------------------------------------------------------
# The trace test
# ---------------------------------------------------------------------------
def test_scheduler_trace_matches_simulator_and_reference():
    cfg, eng = _build()
    rng = np.random.default_rng(7)
    trace = _random_trace(rng, n_req=7, vocab=cfg.vocab_size,
                          prompt_cap=8, max_new_cap=6)
    sched = SlotScheduler(eng, prompt_cap=8)
    results = replay_trace(sched, trace)

    # 1) event-for-event equality with the pure-Python simulator
    sim_events, sim_occ = simulate(trace, sched.n_slots)
    assert sched.events == sim_events, (sched.events, sim_events)
    np.testing.assert_allclose(sched.occupancy, sim_occ)

    # 2) no slot double-assignment: a slot must finish before re-admit
    in_use = {}
    for tick, kind, rid, slot in sched.events:
        if kind == "admit":
            assert slot not in in_use, (slot, rid, tick)
            in_use[slot] = rid
        else:
            assert in_use.pop(slot) == rid
    assert not in_use                          # everything drained

    # 3) token-for-token equality with the dense per-request reference
    for _, req in trace:
        want = _reference_tokens(eng, 8, req)
        got = results[req.rid].tokens
        assert got == want, (req.rid, got, want)
        assert len(got) == req.max_new

    # 4) drained state: every slot free again
    assert (sched.cache_lens() == -1).all()


def test_retired_slots_do_zero_attend_work():
    """The acceptance scenario: a long request keeps decoding while a
    short one retires and its slot is re-admitted — with the freed
    slot's attend-step counter FROZEN in between, and no decode
    dispatch at all once everything drains."""
    cfg, eng = _build(n_slots=2)
    rng = np.random.default_rng(3)
    vocab = cfg.vocab_size
    long_req = Request(0, [int(t) for t in rng.integers(0, vocab, 6)], 14)
    short_req = Request(1, [int(t) for t in rng.integers(0, vocab, 4)], 2)
    late_req = Request(2, [int(t) for t in rng.integers(0, vocab, 5)], 3)
    sched = SlotScheduler(eng, prompt_cap=8)
    sched.submit(long_req)
    sched.submit(short_req)

    work, lens = [], []
    for tick in range(8):
        if tick == 5:
            sched.submit(late_req)
        sched.step()
        work.append(sched.work_blocks().copy())
        lens.append(sched.cache_lens().copy())
    ev = {(k, r): t for t, k, r, s in sched.events}
    t_fin = ev[("finish", 1)]
    t_re = ev[("admit", 2)]
    assert t_fin < t_re                       # slot 1 freed, then reused
    assert ev[("admit", 2)] is not None
    assert all(s == 1 for t, k, r, s in sched.events if r in (1, 2)
               and k == "admit")              # both rode slot 1

    for t in range(t_fin + 1, t_re):
        # freed slot: zero attend-step work, frozen length …
        assert work[t][1] == work[t - 1][1], (t, work)
        assert lens[t][1] == -1
        # … while the long request keeps paying every tick
        assert work[t][0] > work[t - 1][0], (t, work)

    # drain; once idle the scheduler stops dispatching decode entirely
    sched.run()
    n_calls = sched.decode_calls
    for _ in range(3):
        assert sched.idle()
    assert sched.decode_calls == n_calls
    assert (sched.work_blocks() >= 0).all()


def test_params_pair_guard():
    """PR-2 footgun: decode_step/prefill called with the whole
    {"train","serve"} pair raise a ValueError naming the fix."""
    from repro.serving.engine import decode_step
    from repro.serving.prefill import prefill
    pair = {"train": {}, "serve": {}}
    with pytest.raises(ValueError, match=r"params\['serve'\]"):
        decode_step(None, None, None, pair, None, None)
    with pytest.raises(ValueError, match=r"params\['train'\]"):
        prefill(None, None, None, pair, None, None)


# ---------------------------------------------------------------------------
# Admission hardening
# ---------------------------------------------------------------------------
def test_submit_rejects_malformed_requests():
    """Zero-length and oversized prompts (and degenerate budgets) are
    rejected AT SUBMIT with a ValueError naming the violated limit —
    they must never reach the device admit path."""
    cfg, eng = _build(n_slots=2, max_seq=16)
    sched = SlotScheduler(eng, prompt_cap=8)
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(Request(0, [], 3))
    with pytest.raises(ValueError, match="prompt_cap=8"):
        sched.submit(Request(1, [1] * 9, 3))
    with pytest.raises(ValueError, match="max_seq=16"):
        SlotScheduler(eng, prompt_cap=32).submit(Request(2, [1] * 20, 3))
    with pytest.raises(ValueError, match="max_new"):
        sched.submit(Request(3, [1, 2], 0))
    with pytest.raises(ValueError, match="replay"):
        sched.submit(Request(4, [1, 2], 2, replay=[5, 6]))
    sched.submit(Request(5, [1, 2], 3))
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(Request(5, [1, 2], 3))
    # rejected requests left no trace: the accepted one runs clean
    res = sched.run()
    assert set(res) == {5} and len(res[5].tokens) == 3


def test_same_slot_retire_readmit_no_kv_leak():
    """Regression for the recovery path: retire a request and re-admit a
    DIFFERENT one into the same slot — the successor's tokens must equal
    its dense single-request reference (no stale KV rows, lengths, or
    finite-sentinel state leaking across the slot's lifetimes)."""
    cfg, eng = _build(n_slots=1, check_finite=True)
    rng = np.random.default_rng(21)
    vocab = cfg.vocab_size
    first = Request(0, [int(t) for t in rng.integers(1, vocab, 7)], 6)
    second = Request(1, [int(t) for t in rng.integers(1, vocab, 3)], 8)
    sched = SlotScheduler(eng, prompt_cap=8)
    sched.submit(first)
    sched.submit(second)
    res = sched.run()
    # both rode slot 0, sequentially
    admits = [(r, s) for t, k, r, s in sched.events if k == "admit"]
    assert admits == [(0, 0), (1, 0)]
    for req in (first, second):
        want = _reference_tokens(eng, 8, req)
        assert res[req.rid].tokens == want, (req.rid, res[req.rid].tokens,
                                             want)
    assert (sched.cache_lens() == -1).all()
    assert sched.replay_mismatches() == 0


def test_replay_reconstruction_matches_uninterrupted_run():
    """The recovery primitive in isolation: run a request to completion,
    then resubmit it with the first k tokens as ``replay`` — the replayed
    stream must be byte-identical and report zero mismatches."""
    cfg, eng = _build(n_slots=2)
    rng = np.random.default_rng(5)
    req = Request(0, [int(t) for t in rng.integers(1, cfg.vocab_size, 5)], 7)
    full = SlotScheduler(eng, prompt_cap=8)
    full.submit(req)
    want = full.run()[0].tokens
    for k in (1, 3, len(want) - 1):
        sched = SlotScheduler(eng, prompt_cap=8)
        sched.submit(Request(0, list(req.prompt), req.max_new,
                             replay=want[:k]))
        got = sched.run()[0].tokens
        assert got == want, (k, got, want)
        assert sched.replay_mismatches() == 0
    # a WRONG journal is flagged, and the journal value stays authoritative
    sched = SlotScheduler(eng, prompt_cap=8)
    bad = [want[0] + 1] + want[1:3]
    sched.submit(Request(0, list(req.prompt), req.max_new, replay=bad))
    got = sched.run()[0].tokens
    assert sched.replay_mismatches() >= 1
    assert got[:3] == bad                      # journal wins the stream


@pytest.mark.multidevice
def test_scheduler_backend_parity_pallas_prepack():
    """The same trace through the scheduler on backend=xla and on the
    fully fused pallas+prepack path produces the same events and
    (near-)identical tokens, on a 2-device model axis."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import EngineOptions, build_engine_full
    from repro.serving.scheduler import Request, SlotScheduler, replay_trace
    cfg = reduced(get_config("llama2-7b"))
    rng = np.random.default_rng(11)
    trace = []
    for rid in range(4):
        trace.append((rid // 2, Request(
            rid, [int(t) for t in rng.integers(0, cfg.vocab_size,
                                               int(rng.integers(2, 7)))],
            int(rng.integers(2, 5)))))
    outs = {}
    for backend in ("xla", "pallas"):
        mesh = make_test_mesh(data=1, model=2)
        eng = build_engine_full(
            cfg, mesh, max_seq=32, batch_global=2,
            options=EngineOptions(backend=backend,
                                  interpret=(backend == "pallas"),
                                  track_work=True))
        assert eng.scfg.prepack == (backend == "pallas")
        sched = SlotScheduler(eng, prompt_cap=8)
        res = replay_trace(sched, trace)
        outs[backend] = ([(r, res[r].tokens) for r in sorted(res)],
                         sched.events)
    assert outs["xla"][1] == outs["pallas"][1]       # same schedule
    tok_x = np.concatenate([t for _, t in outs["xla"][0]])
    tok_p = np.concatenate([t for _, t in outs["pallas"][0]])
    agree = (tok_x == tok_p).mean()
    assert agree >= 0.9, (agree, outs)
    print("SCHED BACKEND PARITY OK", agree)
    """, timeout=1500)
