"""Per-request stochastic sampling over the fused head's k candidates
(serving/sampling.py, kernels/fused_head/topk.py — DESIGN.md §7 sampled
tail, §8 pt 0 at width k).

* The k-merge ClusterReduce operator (``topk_pair_merge``): commutative,
  associative under ANY tree association order, cross-shard ties resolve
  to the LOWEST global index, -inf padding never survives against real
  candidates — plus a ``_minihyp``-compatible property equating every
  fold order with the flat ``select_topk`` spec.
* ``SamplingParams`` validation: out-of-range fields raise ``ValueError``
  naming the offending field at ``submit()``.
* ``finalize_candidates`` semantics: temperature 0 ≡ candidate 0
  (bit-identical greedy); top-k restricts support by rank; top-p keeps
  rank 0 unconditionally; the positional PRNG makes streams a pure
  function of (seed, emit offset).
* ``EngineOptions``: the legacy-kwargs deprecation shim warns ONCE,
  rejects unknown kwargs by name, and builds an engine token-identical
  to the options-built one.
* Scheduler: per-request params ride admission into the device leaves;
  heterogeneous batches record effective params on ``RequestResult``;
  same seed ⇒ same stream, different seed ⇒ different stream.
* Fused-vs-oracle EXACTNESS with heterogeneous per-slot params (incl. a
  retired slot) at cluster {1, 2, 4} on 8 emulated devices: the fused
  candidate path and the ``fuse_head=False`` full-logits path emit
  token-identical SAMPLED streams — the k-candidate contract.
* Chaos tier: kill a replica mid-stream while temperature > 0 requests
  are in flight — the router's journaled ``SamplingParams`` + positional
  PRNG reconstruct every sampled stream byte-equal to a fault-free
  oracle (DESIGN.md §9).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # tier-1 container: deterministic shim
    from _minihyp import given, settings, strategies as st

from helpers import run_multidevice

from repro.kernels.fused_head.topk import select_topk, topk_pair_merge
from repro.serving.sampling import (CAND_K, GREEDY, SamplingParams,
                                    finalize_candidates,
                                    init_sampling_state, validate_sampling)


# ---------------------------------------------------------------------------
# The k-merge ClusterReduce operator
# ---------------------------------------------------------------------------
def _mk_shard(rng, b, m, k, shard, v_loc):
    """A sorted candidate set from one vocab shard: ids drawn inside the
    shard's disjoint global range ``[shard·v_loc, (shard+1)·v_loc)``."""
    vals = jnp.asarray(rng.standard_normal((b, m)), jnp.float32)
    ids = jnp.asarray(
        np.stack([rng.choice(v_loc, size=m, replace=False)
                  for _ in range(b)]) + shard * v_loc, jnp.int32)
    return select_topk(vals, ids, k)


def test_topk_pair_merge_commutative():
    rng = np.random.default_rng(0)
    for k in (1, 2, 4, 8):
        a = _mk_shard(rng, 3, 16, k, shard=0, v_loc=32)
        b = _mk_shard(rng, 3, 16, k, shard=1, v_loc=32)
        ab_v, ab_i = topk_pair_merge(a, b)
        ba_v, ba_i = topk_pair_merge(b, a)
        np.testing.assert_array_equal(np.asarray(ab_v), np.asarray(ba_v))
        np.testing.assert_array_equal(np.asarray(ab_i), np.asarray(ba_i))


def test_topk_pair_merge_associative_any_tree_order():
    """Four shards folded left-to-right, right-to-left and as a balanced
    tree (every association a rank's ClusterReduce could pick) must all
    yield the spec: flat ``select_topk`` over the full concatenation."""
    rng = np.random.default_rng(1)
    for k in (1, 3, 8):
        shards = [_mk_shard(rng, 2, 12, k, shard=s, v_loc=16)
                  for s in range(4)]
        flat_v = jnp.concatenate([v for v, _ in shards], axis=-1)
        flat_i = jnp.concatenate([i for _, i in shards], axis=-1)
        spec = select_topk(flat_v, flat_i, k)
        folds = {
            "ltr": topk_pair_merge(topk_pair_merge(topk_pair_merge(
                shards[0], shards[1]), shards[2]), shards[3]),
            "rtl": topk_pair_merge(shards[0], topk_pair_merge(
                shards[1], topk_pair_merge(shards[2], shards[3]))),
            "tree": topk_pair_merge(topk_pair_merge(shards[0], shards[1]),
                                    topk_pair_merge(shards[2], shards[3])),
            "perm": topk_pair_merge(topk_pair_merge(shards[2], shards[0]),
                                    topk_pair_merge(shards[3], shards[1])),
        }
        for name, (gv, gi) in folds.items():
            np.testing.assert_array_equal(np.asarray(spec[0]),
                                          np.asarray(gv), err_msg=name)
            np.testing.assert_array_equal(np.asarray(spec[1]),
                                          np.asarray(gi), err_msg=name)


def test_topk_merge_cross_shard_tie_break_lowest_index():
    """Equal values planted on DIFFERENT shards must resolve to the
    lowest global index at every rank of the merged set — the k-wide
    generalization of the ``_greedy_pair_merge`` tie-break fix."""
    k = 4
    # shard 0 holds ids {8, 9}, shard 1 holds ids {3, 5} globally lower?
    # no — make shard 1's ids HIGHER so order of args must not matter.
    a = (jnp.asarray([[7.0, 2.0, -jnp.inf, -jnp.inf]]),
         jnp.asarray([[5, 1, 2 ** 31 - 1, 2 ** 31 - 1]], jnp.int32))
    b = (jnp.asarray([[7.0, 2.0, -jnp.inf, -jnp.inf]]),
         jnp.asarray([[21, 9, 2 ** 31 - 1, 2 ** 31 - 1]], jnp.int32))
    for x, y in ((a, b), (b, a)):
        mv, mi = topk_pair_merge(x, y)
        # both 7.0s kept, lowest index FIRST; both 2.0s likewise
        np.testing.assert_array_equal(np.asarray(mv),
                                      [[7.0, 7.0, 2.0, 2.0]])
        np.testing.assert_array_equal(np.asarray(mi), [[5, 21, 1, 9]])
    assert int(topk_pair_merge(a, b)[1][0, 0]) == 5   # the greedy slot


def test_select_topk_padding_never_beats_real_candidates():
    """M < k pads with (-inf, INT32_MAX); merging padding against real
    candidates must keep every real one."""
    v, i = select_topk(jnp.asarray([[1.0, 3.0]]),
                       jnp.asarray([[4, 2]], jnp.int32), k=4)
    np.testing.assert_array_equal(np.asarray(v)[0, :2], [3.0, 1.0])
    np.testing.assert_array_equal(np.asarray(i)[0, :2], [2, 4])
    assert np.isneginf(np.asarray(v)[0, 2:]).all()
    real = (jnp.asarray([[2.0, 0.5, -1.0, -2.0]]),
            jnp.asarray([[10, 11, 12, 13]], jnp.int32))
    mv, mi = topk_pair_merge((v, i), real)
    np.testing.assert_array_equal(np.asarray(mv), [[3.0, 2.0, 1.0, 0.5]])
    np.testing.assert_array_equal(np.asarray(mi), [[2, 10, 4, 11]])


@given(st.integers(0, 2 ** 31), st.integers(1, 8), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_topk_merge_property_any_fold_equals_spec(seed, k, n_shards):
    """Property (hypothesis or the _minihyp shim): for random candidate
    sets over disjoint shard id ranges, folding shard-by-shard with the
    pair merge — in arrival order OR reversed — equals flat
    ``select_topk`` over everything at once."""
    rng = np.random.default_rng(seed)
    shards = [_mk_shard(rng, 2, 8, k, shard=s, v_loc=16)
              for s in range(n_shards)]
    spec = select_topk(jnp.concatenate([v for v, _ in shards], axis=-1),
                       jnp.concatenate([i for _, i in shards], axis=-1), k)
    for order in (shards, shards[::-1]):
        acc = order[0]
        for nxt in order[1:]:
            acc = topk_pair_merge(acc, nxt)
        np.testing.assert_array_equal(np.asarray(spec[0]),
                                      np.asarray(acc[0]))
        np.testing.assert_array_equal(np.asarray(spec[1]),
                                      np.asarray(acc[1]))


# ---------------------------------------------------------------------------
# SamplingParams validation — errors name the offending field
# ---------------------------------------------------------------------------
def test_sampling_params_validation_names_offending_field():
    validate_sampling(0, GREEDY)
    validate_sampling(0, SamplingParams(temperature=0.7, top_k=4,
                                        top_p=0.9, seed=3))
    for sp, field in (
            (SamplingParams(temperature=-0.1), "temperature"),
            (SamplingParams(top_k=0), "top_k"),
            (SamplingParams(top_k=CAND_K + 1), "top_k"),
            (SamplingParams(top_p=0.0), "top_p"),
            (SamplingParams(top_p=1.5), "top_p")):
        with pytest.raises(ValueError, match=field) as ei:
            validate_sampling(7, sp)
        assert "request 7" in str(ei.value)
    # the CAND_K cap is explained, not just enforced
    with pytest.raises(ValueError, match="CAND_K"):
        validate_sampling(0, SamplingParams(top_k=99))


# ---------------------------------------------------------------------------
# finalize_candidates semantics (pure jnp, single device)
# ---------------------------------------------------------------------------
def _cands(rng, b=4, k=CAND_K, v=64):
    vals = jnp.asarray(
        np.sort(rng.standard_normal((b, k)))[:, ::-1].copy(), jnp.float32)
    ids = jnp.asarray(
        np.stack([rng.choice(v, size=k, replace=False)
                  for _ in range(b)]), jnp.int32)
    return vals, ids


def _leaves(b, **over):
    samp = init_sampling_state(b)
    for name, val in over.items():
        samp[name] = jnp.asarray(val, samp[name].dtype)
    return samp


def test_finalize_greedy_default_is_candidate_zero():
    rng = np.random.default_rng(2)
    vals, ids = _cands(rng)
    tok, hv = finalize_candidates(vals, ids, _leaves(4))
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(ids[:, 0]))
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(vals[:, 0]))


def test_finalize_topk_restricts_support_by_rank():
    """temp > 0 with top_k = j must only ever emit one of the first j
    candidates; head_val is the RAW pre-temperature logit."""
    rng = np.random.default_rng(3)
    vals, ids = _cands(rng, b=1)
    for j in (1, 2, 3):
        for seed in range(24):
            tok, hv = finalize_candidates(
                vals[:1], ids[:1],
                _leaves(1, temp=[1.5], topk=[j], seed=[seed]))
            r = list(np.asarray(ids)[0, :j])
            assert int(tok[0]) in r, (j, seed)
            rank = r.index(int(tok[0]))
            assert float(hv[0]) == float(vals[0, rank])


def test_finalize_topp_keeps_rank_zero_always():
    """A top_p below the best candidate's own probability collapses the
    nucleus to rank 0 — never an empty distribution."""
    rng = np.random.default_rng(4)
    vals, ids = _cands(rng, b=2)
    for seed in range(16):
        tok, _ = finalize_candidates(
            vals, ids, _leaves(2, temp=[1.0, 2.0], topp=[1e-6, 1e-6],
                               seed=[seed, seed + 100]))
        np.testing.assert_array_equal(np.asarray(tok),
                                      np.asarray(ids[:, 0]))


def test_finalize_positional_prng_is_pure_in_seed_and_step():
    """Same (seed, step) ⇒ same token regardless of history; stepping
    the emit offset varies the stream; distinct seeds give distinct
    streams — the property fleet replay rests on."""
    rng = np.random.default_rng(5)
    vals, ids = _cands(rng, b=1)

    def tok(seed, step):
        t, _ = finalize_candidates(
            vals, ids, _leaves(1, temp=[1.2], seed=[seed], step=[step]))
        return int(t[0])

    for seed in (0, 7, 123):
        for step in (0, 1, 9):
            assert tok(seed, step) == tok(seed, step)
    stream_a = [tok(7, s) for s in range(12)]
    stream_b = [tok(8, s) for s in range(12)]
    assert len(set(stream_a)) > 1          # the offset actually varies it
    assert stream_a != stream_b            # and so does the seed


# ---------------------------------------------------------------------------
# EngineOptions + the legacy-kwargs deprecation shim (1-device engine)
# ---------------------------------------------------------------------------
def _tiny_engine(**kw):
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import build_engine_full
    cfg = reduced(get_config("llama2-7b"))
    mesh = make_test_mesh(data=1, model=1)
    return cfg, build_engine_full(cfg, mesh, max_seq=32, batch_global=2,
                                  **kw)


def test_engine_options_legacy_shim_warns_once_and_matches():
    import warnings

    from repro.launch import serve
    from repro.launch.serve import EngineOptions
    serve._LEGACY_KWARGS_WARNED = False
    with pytest.warns(DeprecationWarning, match="EngineOptions"):
        cfg, legacy = _tiny_engine(backend="xla", track_work=True)
    with warnings.catch_warnings():       # once per process, not per call
        warnings.simplefilter("error")
        _, modern = _tiny_engine(
            options=EngineOptions(backend="xla", track_work=True))
        _tiny_engine(backend="xla")       # legacy again: still silent
    # the shimmed engine is the SAME engine: token-identical streams
    prompts = np.asarray([[3, 5, 7, 2], [11, 2, 9, 4]], np.int32)
    streams = []
    for eng in (legacy, modern):
        nxt, stt = eng.prefill_fn(eng.params["train"], eng.state, prompts,
                                  None)
        out = [np.asarray(nxt)]
        for t in range(4):
            o, stt = eng.decode_fn(eng.params["serve"], stt,
                                   jnp.asarray([t + 1, t + 2], jnp.int32))
            out.append(np.asarray(o))
        streams.append(np.stack(out))
    np.testing.assert_array_equal(streams[0], streams[1])


def test_engine_options_unknown_kwarg_raises_by_name():
    with pytest.raises(TypeError, match="fuse_hea"):
        _tiny_engine(backend="xla", fuse_hea=True)


def test_engine_options_mixed_with_options_object():
    """options= plus legacy kwargs: the kwargs override ON TOP of the
    given options (dataclasses.replace semantics)."""
    from repro.launch import serve
    from repro.launch.serve import EngineOptions
    serve._LEGACY_KWARGS_WARNED = False
    with pytest.warns(DeprecationWarning):
        _, eng = _tiny_engine(options=EngineOptions(backend="xla"),
                              track_work=True)
    assert eng.state.get("work_blocks") is not None


# ---------------------------------------------------------------------------
# Scheduler: per-request params ride admission; streams are seeded
# ---------------------------------------------------------------------------
_SCHED_ENGINE = None


def _sched_engine():
    global _SCHED_ENGINE
    if _SCHED_ENGINE is None:
        from repro.launch.serve import EngineOptions
        _SCHED_ENGINE = _tiny_engine(
            options=EngineOptions(backend="xla", track_work=True))
    return _SCHED_ENGINE


def _run_sched(trace, prompt_cap=6):
    from repro.serving.scheduler import SlotScheduler, replay_trace
    cfg, eng = _sched_engine()
    sched = SlotScheduler(eng, prompt_cap=prompt_cap)
    return replay_trace(sched, trace)


def test_scheduler_submit_rejects_bad_sampling_by_name():
    from repro.serving.scheduler import Request, SlotScheduler
    cfg, eng = _sched_engine()
    sched = SlotScheduler(eng, prompt_cap=6)
    with pytest.raises(ValueError, match="top_k"):
        sched.submit(Request(0, [1, 2], 3,
                             sampling=SamplingParams(top_k=CAND_K + 3)))
    with pytest.raises(ValueError, match="temperature"):
        sched.submit(Request(1, [1, 2], 3,
                             sampling=SamplingParams(temperature=-1.0)))


def test_scheduler_heterogeneous_sampling_recorded_and_seeded():
    """One batch, one greedy + one sampled request: effective params land
    on RequestResult; the sampled stream reruns bit-equal under the same
    seed and moves under a different seed; greedy rides along unchanged
    (slot independence of the sampling leaves)."""
    from repro.serving.scheduler import Request
    sp = SamplingParams(temperature=0.9, top_k=6, top_p=0.95, seed=41)
    prompts = ([5, 9, 2, 8], [4, 4, 1])

    def trace(seed):
        s = SamplingParams(temperature=0.9, top_k=6, top_p=0.95,
                           seed=seed)
        return [(0, Request(0, list(prompts[0]), 8)),
                (0, Request(1, list(prompts[1]), 8, sampling=s))]

    res = _run_sched(trace(41))
    assert res[0].sampling == GREEDY
    assert res[1].sampling == sp
    res2 = _run_sched(trace(41))
    assert res2[1].tokens == res[1].tokens       # same seed ⇒ same stream
    assert res2[0].tokens == res[0].tokens
    res3 = _run_sched(trace(1234))
    assert res3[0].tokens == res[0].tokens       # greedy slot untouched
    assert res3[1].tokens != res[1].tokens       # seed moved the stream
    # the sampled stream is NOT the greedy stream (temperature mattered)
    res_g = _run_sched([(0, Request(0, list(prompts[0]), 8)),
                        (0, Request(1, list(prompts[1]), 8))])
    assert res[1].tokens != res_g[1].tokens


# ---------------------------------------------------------------------------
# Fused vs unfused oracle: heterogeneous per-slot params, cluster sweep
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_fused_sampling_token_exact_heterogeneous_cluster_sweep():
    """The exactness contract at width k (DESIGN.md §8 pt 0): the fused
    candidate path and the ``fuse_head=False`` full-logits oracle emit
    IDENTICAL sampled streams for a batch mixing greedy, top-k, top-p
    and distinct seeds — including a retired slot — at cluster {1,2,4}.
    Also proves the stochastic slots actually left the greedy stream."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import EngineOptions, build_engine_full
    for arch in ("llama2-7b", "gemma2-27b"):
        cfg = reduced(get_config(arch))
        mesh = make_test_mesh()
        for n in (1, 2, 4):
            res = {}
            for label, fh in (("fused", True), ("nohead", False)):
                h = build_engine_full(
                    cfg, mesh, max_seq=32, batch_global=4,
                    options=EngineOptions(cluster=n, backend="pallas",
                                          interpret=True, fuse_head=fh))
                key = jax.random.PRNGKey(0)
                prompts = jax.random.randint(key, (4, 12), 0,
                                             cfg.vocab_size)
                nxt, st = h.prefill_fn(h.params["train"], h.state,
                                       prompts, None)
                # retire slot 2 (its meaningless token must still agree)
                st = h.retire_fn(st, jnp.asarray([0, 0, 1, 0], jnp.int32))
                # heterogeneous per-slot params: slot 0 greedy, slot 1
                # temp+top-k, slot 2 retired-but-parameterized, slot 3
                # temp+top-p — exactly what a mixed continuous batch
                # has.  State leaves ride the lifted [dp, model, B_loc]
                # layout (launch/specs.state_spec_tree) with the batch
                # split over dp — fold the global per-slot row into it.
                def set_leaf(old, row):
                    dp, ms, bl = old.shape
                    r = jnp.asarray(row, old.dtype).reshape(dp, 1, bl)
                    return jnp.broadcast_to(r, old.shape)
                st["sampling"] = dict(
                    st["sampling"],
                    temp=set_leaf(st["sampling"]["temp"],
                                  [0.0, 0.9, 0.8, 0.7]),
                    topk=set_leaf(st["sampling"]["topk"], [8, 4, 8, 8]),
                    topp=set_leaf(st["sampling"]["topp"],
                                  [1.0, 1.0, 1.0, 0.6]),
                    seed=set_leaf(st["sampling"]["seed"], [0, 11, 5, 3]))
                toks = jax.random.randint(jax.random.PRNGKey(3), (5, 4),
                                          0, cfg.vocab_size)
                outs = []
                for t in range(5):
                    o, st = h.decode_fn(h.params["serve"], st, toks[t])
                    outs.append(np.asarray(o))
                res[label] = np.stack(outs)
                if fh:
                    # greedy rerun for the did-it-actually-sample check
                    _, st_g = h.prefill_fn(h.params["train"], h.state,
                                           prompts, None)
                    st_g = h.retire_fn(st_g,
                                       jnp.asarray([0, 0, 1, 0],
                                                   jnp.int32))
                    g = []
                    for t in range(5):
                        o, st_g = h.decode_fn(h.params["serve"], st_g,
                                              toks[t])
                        g.append(np.asarray(o))
                    res["greedy"] = np.stack(g)
            np.testing.assert_array_equal(res["fused"], res["nohead"])
            assert res["fused"][:, 0].tolist() == \\
                res["greedy"][:, 0].tolist(), (arch, n)   # slot 0 greedy
            assert res["fused"][:, 1].tolist() != \\
                res["greedy"][:, 1].tolist(), (arch, n)   # slot 1 sampled
            print("HETEROGENEOUS SAMPLING EXACT", arch, "N =", n)
    """, timeout=1800)


# ---------------------------------------------------------------------------
# Chaos tier: kill mid-stream at temperature > 0 → byte-equal replay
# ---------------------------------------------------------------------------
def test_sampled_streams_survive_replica_kill_byte_equal():
    """Fleet recovery of STOCHASTIC streams (DESIGN.md §9 + the
    positional PRNG): kill replica 0 two ticks in while temperature > 0
    requests are mid-flight; the survivor replays each journaled prefix
    and continues sampling from the journaled SamplingParams + emit
    offsets — every stream byte-equals the fault-free oracle."""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import EngineOptions, build_replicas
    from repro.serving.faults import FaultInjector, FaultSpec
    from repro.serving.router import Router
    from repro.serving.scheduler import Request

    cfg = reduced(get_config("llama2-7b"))
    mesh = make_test_mesh(data=1, model=1)
    engines = build_replicas(
        cfg, mesh, n_replicas=2, max_seq=32, batch_global=2,
        options=EngineOptions(backend="xla", check_finite=True,
                              kv_fingerprint=True, shadow_head=True))
    rng = np.random.default_rng(6)
    trace = []
    for rid in range(5):
        sp = SamplingParams(temperature=0.9, top_k=6, top_p=0.9,
                            seed=rid * 7 + 1)
        plen = int(rng.integers(2, 7))
        trace.append((int(rng.integers(0, 3)), Request(
            rid, [int(t) for t in rng.integers(1, cfg.vocab_size, plen)],
            int(rng.integers(4, 8)), sampling=sp)))

    def run(injectors=None):
        return Router(engines, prompt_cap=8, max_new_cap=8,
                      injectors=injectors).run(
            [(t, Request(r.rid, r.prompt, r.max_new, sampling=r.sampling))
             for t, r in trace])

    oracle = run()
    assert all(e.sampling.temperature == 0.9 for e in oracle.values())
    inj = FaultInjector([FaultSpec("kill", step=2, target=0, replica=0)])
    journal = run(injectors={0: inj})
    assert len(inj.fired) == 1
    got = {rid: list(e.tokens) for rid, e in journal.items()}
    want = {rid: list(e.tokens) for rid, e in oracle.items()}
    assert got == want
    # at least one sampled stream was actually cut over mid-flight
    requeued = [e for e in journal.values() if e.requeues]
    assert requeued
    assert all(e.replicas[-1] == 1 for e in requeued)
    # and the journal carries the params that made the replay exact
    assert all(e.sampling.seed == e.rid * 7 + 1
               for e in journal.values())
