"""Multi-device integration (8-host-device subprocesses): sharded model ==
oracle, train convergence + compression + FSDP, dataflow oracles, serve
consistency, pipeline parallelism, overlap ring."""
import pytest

from helpers import run_multidevice

pytestmark = pytest.mark.multidevice

SHARDED_BODY = """
from repro.configs import get_config, reduced
from repro.models import (forward, init_logical, layout_for, loss_fn,
                          param_specs, single_device_ctx, to_device_major,
                          unwrap_local, make_train_ctx)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
MS = 4
key = jax.random.PRNGKey(0)
for arch in {archs}:
    cfg = reduced(get_config(arch))
    logical = init_logical(cfg, key)
    lay = layout_for(cfg, MS)
    dm = to_device_major(cfg, lay, logical)
    specs = param_specs(cfg, dm)
    B, S = 4, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(key, (B, cfg.frontend.num_positions,
                                     cfg.frontend.feature_dim), jnp.float32)
    local1 = unwrap_local(to_device_major(cfg, layout_for(cfg, 1), logical))
    ctx1 = single_device_ctx()
    h_ref = forward(ctx1, cfg, local1, tokens, fe, remat=False)
    nll_r, cnt_r = loss_fn(ctx1, cfg, local1,
                           {{"tokens": tokens, "targets": tokens,
                             "frontend_embeds": fe}}, remat=False)
    ctx = make_train_ctx("model", heads_sub=lay.heads_sub, model_size=MS,
                         data=("data",))
    def f(params, tok, fe_):
        local = unwrap_local(params)
        h = forward(ctx, cfg, local, tok, fe_, remat=False)
        nll, cnt = loss_fn(ctx, cfg, local,
                           {{"tokens": tok, "targets": tok,
                             "frontend_embeds": fe_}}, remat=False)
        return h, jax.lax.psum(nll, "data")[None], \\
            jax.lax.psum(cnt, "data")[None]
    in_specs = (specs, P("data"), P("data") if fe is not None else P())
    hs, nll_s, cnt_s = jax.jit(shard_map(
        f, mesh=mesh, in_specs=in_specs,
        out_specs=(P("data"), P(None), P(None)), check_vma=False))(
        dm, tokens, fe)
    a = np.asarray(hs, np.float32); b = np.asarray(h_ref, np.float32)
    frac = (np.abs(a - b) > (8e-2 + 1e-1 * np.abs(b))).mean()
    assert frac < 0.02, (arch, frac)
    assert abs(float(nll_s[0] / cnt_s[0]) - float(nll_r / cnt_r)) < 2e-2
    print(arch, "OK")
"""


@pytest.mark.parametrize("archs", [
    ["qwen2-72b", "gemma2-27b", "granite-8b"],
    ["kimi-k2-1t-a32b", "arctic-480b", "deepseek-v2-lite"],
    ["recurrentgemma-9b", "rwkv6-3b"],
    ["seamless-m4t-medium", "internvl2-2b", "minitron-4b", "llama2-7b"],
])
def test_sharded_equals_oracle(archs):
    run_multidevice(SHARDED_BODY.format(archs=repr(archs)))


TRAIN_BODY = """
from repro.configs import get_config, reduced
from repro.models import (init_logical, layout_for, param_specs,
                          to_device_major, make_train_ctx)
from repro.models.transformer import grad_sync_tree
from repro.training.train_step import (TrainConfig, init_train_state,
                                       make_train_step)
from repro.training.optimizer import OptConfig
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
MS, DP = 4, 2
cfg = reduced(get_config({arch!r}))
key = jax.random.PRNGKey(0)
lay = layout_for(cfg, MS)
dm = to_device_major(cfg, lay, init_logical(cfg, key))
specs = param_specs(cfg, dm)
sync = grad_sync_tree(cfg, lay, dm)
ctx = make_train_ctx("model", heads_sub=lay.heads_sub, model_size=MS,
                     data=("data",))
tcfg = TrainConfig(opt=OptConfig(lr=1e-2), microbatches=2,
                   grad_compress={compress}, zero1=True)
step_fn = make_train_step(ctx, cfg, tcfg, ("data",), DP, sync_tree=sync)
B, S = 8, 32
tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
def driver(params, tok):
    rank = jax.lax.axis_index("data")
    opt, ef = init_train_state(cfg, tcfg, params, DP, rank)
    losses = []
    batch = {{"tokens": tok, "targets": tok}}
    for i in range(8):
        params, opt, ef, m = step_fn(params, opt, ef, batch)
        losses.append(m["loss"])
    return jnp.stack(losses)[None], jax.tree.leaves(params)[0][None]
losses, leaf0 = jax.jit(shard_map(
    driver, mesh=mesh, in_specs=(specs, P("data")),
    out_specs=(P(("data", "model")), P(("data", "model"))),
    check_vma=False))(dm, tokens)
losses = np.asarray(losses)
assert np.allclose(losses, losses[0:1], atol=1e-3)
assert losses[0, -1] < losses[0, 0] - 0.5, losses[0]
leaf0 = np.asarray(leaf0).reshape((2, 4) + np.asarray(leaf0).shape[1:])
np.testing.assert_allclose(leaf0[1], leaf0[0], atol=1e-6)
print("TRAIN OK", {arch!r}, "compress={compress}")
"""


@pytest.mark.parametrize("arch,compress", [
    ("qwen2-72b", True), ("kimi-k2-1t-a32b", False),
    ("recurrentgemma-9b", False),
])
def test_train_converges_and_copies_consistent(arch, compress):
    run_multidevice(TRAIN_BODY.format(arch=arch, compress=compress))


def test_fsdp_matches_plain_training():
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.models import (init_logical, layout_for, param_specs,
                              to_device_major, make_train_ctx)
    from repro.models.transformer import (fsdp_axes, fsdp_param_specs,
                                          grad_sync_tree)
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)
    from repro.training.optimizer import OptConfig
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    MS, DP = 4, 2
    cfg = reduced(get_config("granite-8b"))
    key = jax.random.PRNGKey(0)
    lay = layout_for(cfg, MS)
    dm = to_device_major(cfg, lay, init_logical(cfg, key))
    sync = grad_sync_tree(cfg, lay, dm)
    ctx = make_train_ctx("model", heads_sub=lay.heads_sub, model_size=MS,
                         data=("data",))
    tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)

    def run(fsdp):
        tcfg = TrainConfig(opt=OptConfig(lr=1e-2), zero1=True, fsdp=fsdp)
        ax = fsdp_axes(dm, DP) if fsdp else None
        specs = (fsdp_param_specs(cfg, dm, ax, ("data",)) if fsdp
                 else param_specs(cfg, dm))
        step_fn = make_train_step(ctx, cfg, tcfg, ("data",), DP,
                                  sync_tree=sync, fsdp_ax=ax)
        def driver(params, tok):
            rank = jax.lax.axis_index("data")
            opt, ef = init_train_state(cfg, tcfg, params, DP, rank,
                                       fsdp_ax=ax)
            batch = {"tokens": tok, "targets": tok}
            losses = []
            for i in range(6):
                params, opt, ef, m = step_fn(params, opt, ef, batch)
                losses.append(m["loss"])
            return jnp.stack(losses)[None]
        return np.asarray(jax.jit(shard_map(
            driver, mesh=mesh, in_specs=(specs, P("data")),
            out_specs=P(("data", "model")), check_vma=False))(dm, tokens))

    plain = run(False)
    fsdp = run(True)
    np.testing.assert_allclose(fsdp[0], plain[0], rtol=2e-3, atol=2e-3)
    print("FSDP == plain:", np.round(fsdp[0], 4))
    """)


def test_pipeline_forward():
    run_multidevice("""
    from repro.distributed.pipeline import pipeline_forward
    mesh = jax.make_mesh((4,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    # 4 stages, each multiplies by (stage+2); 3 microbatches
    def stage_fn(w, x):
        return x * w
    x = jnp.arange(3 * 2 * 4, dtype=jnp.float32).reshape(3, 2, 4) + 1.0
    ws = jnp.array([2.0, 3.0, 4.0, 5.0])
    def f(w):
        return pipeline_forward(stage_fn, w[0], x, "pod")[None]
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                            out_specs=P("pod"), check_vma=False))(ws)
    out = np.asarray(out)
    np.testing.assert_allclose(out[3], np.asarray(x) * 2 * 3 * 4 * 5)
    print("PIPELINE OK")
    """)


def test_overlap_ag_matmul():
    run_multidevice("""
    from repro.distributed.overlap import overlap_ag_matmul
    mesh = jax.make_mesh((4,), ("m",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 64))          # global [8, 64]
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    def f(x_loc, w_):
        return overlap_ag_matmul(x_loc, w_, "m")[None]
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(None, "m"), P()),
                            out_specs=P("m"), check_vma=False))(x, w)
    ref = np.asarray(x) @ np.asarray(w)
    for r in range(4):
        np.testing.assert_allclose(np.asarray(out)[r], ref, rtol=2e-5,
                                   atol=2e-5)
    print("OVERLAP OK")
    """)


def test_serve_matches_oracle_incremental():
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import build_engine, generate
    from repro.models import (forward, init_logical, layout_for,
                              to_device_major, unwrap_local,
                              single_device_ctx)
    for arch in ("qwen2-72b", "deepseek-v2-lite"):
        cfg = reduced(get_config(arch))
        mesh = make_test_mesh()
        params, pf, dec, state, lay, scfg = build_engine(
            cfg, mesh, max_seq=48, batch_global=4)
        key = jax.random.PRNGKey(0)
        prompts = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
        fe = None
        if cfg.frontend is not None:
            fe = jax.random.normal(key, (4, cfg.frontend.num_positions,
                                         cfg.frontend.feature_dim))
        toks, _ = generate(cfg, params, pf, dec, state, prompts, 4, fe)
        toks = np.asarray(toks)
        logical = init_logical(cfg, jax.random.PRNGKey(0))
        local1 = unwrap_local(to_device_major(cfg, layout_for(cfg, 1),
                                              logical))
        ctx1 = single_device_ctx()
        seq = np.asarray(prompts)
        agree = 0.0
        for t in range(4):
            h = forward(ctx1, cfg, local1, jnp.asarray(seq), fe, remat=False)
            table = local1["embed"] if cfg.tie_embeddings \\
                else local1["lm_head"]
            logits = h[:, -1] @ table.T.astype(h.dtype)
            if cfg.logit_softcap:
                logits = jnp.tanh(logits / cfg.logit_softcap) \\
                    * cfg.logit_softcap
            ref = np.asarray(jnp.argmax(logits[:, :cfg.vocab_size], -1))
            agree += (ref == toks[:, t]).mean()
            seq = np.concatenate([seq, toks[:, t:t + 1]], axis=1)
        assert agree / 4 >= 0.9, (arch, agree / 4)
        print("SERVE OK", arch, agree / 4)
    """, timeout=1800)
