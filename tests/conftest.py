"""Shared test config: the hypothesis CI profile.

The "ci" profile pins property runs deterministic — derandomized (fixed
seed), no deadline (CPU-emulated runs have wild per-example variance),
no local example database (stateless runners).  CI selects it via
``HYPOTHESIS_PROFILE=ci`` (.github/workflows/ci.yml); locally the
default profile keeps random exploration.  When hypothesis is absent
entirely, test_properties.py falls back to the deterministic shim in
``tests/_minihyp.py`` and this registration is a no-op.
"""
import os

try:
    import hypothesis

    hypothesis.settings.register_profile(
        "ci", derandomize=True, deadline=None, database=None,
        max_examples=50)
    if os.environ.get("HYPOTHESIS_PROFILE"):
        hypothesis.settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])
except ImportError:
    pass
