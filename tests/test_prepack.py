"""Serve-layout weight prepacking (serving/prepack.py, DESIGN.md §2).

* Layout math: the load-time pack equals what the runtime adapters
  gathered/sliced per step.
* Trace-time op counts: the prepacked Pallas dataflow performs ZERO
  per-step weight-segment gathers and ZERO weight ``dynamic_slice``s,
  and issues exactly ONE Pallas kernel + ONE fused ClusterReduce per
  attention layer; the engine-level decode step shows zero weight
  movement end-to-end.
* Derived state: checkpoints round-trip training-layout weights
  untouched ({"train","serve"} pairs are stripped to "train"), and the
  serve layout re-derives bit-identically after restore.
* Autotune: ``ServePlan.prepack`` resolution + schema self-heal for
  pre-prepack table entries.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_multidevice


# ---------------------------------------------------------------------------
# Layout math (single process — the pack is pure reshape/slice)
# ---------------------------------------------------------------------------
def test_gather_seg_matches_runtime_gather():
    from repro.serving.prepack import _gather_seg
    hs, n, D, q, hd_n = 2, 4, 6, 3, 4
    ms = hs * n
    wq = jax.random.normal(jax.random.PRNGKey(0), (ms, D, q, hd_n))
    g = _gather_seg(wq, hs, n, 3)
    assert g.shape == (ms, D, q, hd_n * n)
    for h in range(hs):
        for c in range(n):
            r = h * n + c
            # runtime: cluster_gather_tiled concats segment of rank
            # (h, c') at offset c' — every rank of the group agrees
            want = np.concatenate(
                [np.asarray(wq[h * n + cc]) for cc in range(n)], axis=-1)
            np.testing.assert_array_equal(np.asarray(g[r]), want)


def test_col_tile_matches_runtime_slice():
    from repro.serving.prepack import _col_tile
    hs, n, R, D = 2, 2, 8, 12
    ms = hs * n
    wo = jax.random.normal(jax.random.PRNGKey(1), (ms, R, D))
    t = _col_tile(wo, hs, n, 2)
    assert t.shape == (ms, R, D // n)
    for h in range(hs):
        for c in range(n):
            r = h * n + c
            want = np.asarray(wo[r])[:, c * (D // n):(c + 1) * (D // n)]
            np.testing.assert_array_equal(np.asarray(t[r]), want)


def _small_gqa_setup(cluster=2):
    from repro.configs import get_config, reduced
    from repro.models.transformer import Layout, init_device_major
    cfg = reduced(get_config("qwen2-72b"))           # GQA with qkv bias
    ms = 4
    lay = Layout(ms, heads_sub=ms // cluster)
    params = init_device_major(cfg, lay, jax.random.PRNGKey(0))
    return cfg, lay, params


def test_prepack_tree_gqa_shapes_and_passthrough():
    from repro.core.dataflow import (PackedFFNWeights,
                                     PackedSplitTokenWeights,
                                     SplitTokenWeights)
    from repro.serving.prepack import prepack_for_serving
    cfg, lay, params = _small_gqa_setup(cluster=2)
    ms, n = lay.model_size, lay.cluster
    hd = cfg.resolved_head_dim
    q_loc = cfg.n_heads // lay.heads_sub
    kv_loc = max(1, cfg.n_kv_heads // lay.heads_sub)

    packed = prepack_for_serving(cfg, lay, params, backend="pallas")
    a = packed["blocks"][0]["attn"]
    assert isinstance(a, PackedSplitTokenWeights)
    G = params["blocks"][0]["ln1"].shape[1]          # stacked group dim
    assert a.wqkv.shape == (ms, G, cfg.d_model, (q_loc + 2 * kv_loc) * hd)
    assert a.wo.shape == (ms, G, q_loc, hd, cfg.d_model)
    assert a.bqkv.shape == (ms, G, (q_loc + 2 * kv_loc) * hd)
    # the pre-attention norm scale rides the pack (fused in-kernel norm)
    assert a.ln1.shape == (ms, G, cfg.d_model)
    # non-attention leaves ride through untouched (same objects)
    assert packed["embed"] is params["embed"]
    # dense FFN: the bundle is PURE aliasing — every weight field IS the
    # training tree's buffer (full-width down rows are already the serve
    # layout), only the fused norm scales are bound alongside
    pf = packed["blocks"][0]["ffn"]
    tf = params["blocks"][0]["ffn"]
    assert isinstance(pf, PackedFFNWeights)
    assert pf.w_in is tf.w_in and pf.w_out is tf.w_out
    assert pf.w_gate is tf.w_gate
    assert pf.ln2 is params["blocks"][0]["ln2"]

    # xla serve layout: plain dataflow weights with the wo tile pre-sliced
    packed_x = prepack_for_serving(cfg, lay, params, backend="xla")
    ax = packed_x["blocks"][0]["attn"]
    assert isinstance(ax, SplitTokenWeights)
    assert ax.wo.shape == (ms, G, q_loc * hd, cfg.d_model // n)
    assert ax.wq is params["blocks"][0]["attn"].wq
    # the xla path keeps the unfused FFN
    assert packed_x["blocks"][0]["ffn"] is params["blocks"][0]["ffn"]


def test_prepack_mla_fold_matches_manual():
    """wproj = W_UV · W_O rows, per head, per rank — checked against a
    manual einsum on every rank."""
    from repro.configs import get_config, reduced
    from repro.core.dataflow import PackedMLAWeights
    from repro.models.transformer import Layout, init_device_major
    from repro.serving.prepack import prepack_for_serving
    cfg = reduced(get_config("deepseek-v2-lite"))
    ms = 4
    lay = Layout(ms, heads_sub=2)                    # cluster 2
    params = init_device_major(cfg, lay, jax.random.PRNGKey(0))
    packed = prepack_for_serving(cfg, lay, params, backend="pallas")
    a_t = params["blocks"][0]["attn"]
    a_p = packed["blocks"][0]["attn"]
    assert isinstance(a_p, PackedMLAWeights)
    v = cfg.mla.v_head_dim
    q_loc = a_t.wuk.shape[2]
    for r in range(ms):
        wuv = np.asarray(a_t.wuv[r, 0], np.float32)  # [q, l, v]
        wo = np.asarray(a_t.wo[r, 0], np.float32)    # [q*v, D]
        want = np.einsum("qlv,qvd->qld", wuv,
                         wo.reshape(q_loc, v, -1))
        got = np.asarray(a_p.wproj[r, 0], np.float32)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# Trace-time op counts: zero weight movement, one kernel + one ClusterReduce
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_counters_dataflow_packed_vs_adapter():
    run_multidevice("""
    from repro.core import dataflow as df
    from repro.core import primitives as prim
    from repro.core import tracecount
    from repro.serving.engine import _split_token_weights
    from repro.models.ctx import ParallelCtx

    mesh = jax.make_mesh((8,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    heads = prim.SubAxis("c", 2, minor_size=4)
    clus = prim.SubAxis("c", 4, minor_size=1)
    D, n_heads, kv_heads, hd, B, N, H = 64, 4, 2, 32, 2, 4, 2
    q_loc, kv_loc = n_heads // H, kv_heads // H
    s_blk = 4
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    X = jax.random.normal(ks[0], (B, D)) * 0.3
    WQKV = jax.random.normal(ks[1], (D, (q_loc + 2 * kv_loc) * hd)) * 0.05
    WO3 = jax.random.normal(ks[2], (q_loc, hd, D)) * 0.05
    cache = df.KVBlock(k=jnp.zeros((s_blk, B * kv_loc, hd), jnp.bfloat16),
                       v=jnp.zeros((s_blk, B * kv_loc, hd), jnp.bfloat16),
                       pos=jnp.full((s_blk,), -1, jnp.int32))

    spec_p = df.ClusterSpec(heads=heads, cluster=clus, backend="pallas",
                            interpret=True, block_s=2)

    def body_packed(x, wqkv, wo3, k, v, pos):
        w = df.PackedSplitTokenWeights(wqkv=wqkv, wo=wo3, bqkv=None)
        o, nc = df.split_token_attention(
            spec_p, x, w, df.KVBlock(k, v, pos), jnp.int32(3))
        return o[None]

    args = (X, WQKV, WO3, cache.k, cache.v, cache.pos)
    sm = shard_map(body_packed, mesh=mesh, in_specs=(P(),) * 6,
                   out_specs=P("c"), check_vma=False)
    with tracecount.counting() as c:
        jax.eval_shape(sm, *args)
    c = dict(c)
    # prepacked: ONE kernel + ONE fused ClusterReduce (the heads-axis
    # atomicAdd reduction is the only other collective); ZERO weight
    # gathers, ZERO weight slices, ZERO gathers of any kind.
    assert c.get("pallas_kernel") == 1, c
    assert c.get("cluster_combine") == 1, c
    assert c.get("tree_reduce") == 2, c      # fused combine + heads reduce
    assert c.get("tree_gather", 0) == 0, c
    assert c.get("weight_gather", 0) == 0, c
    assert c.get("weight_slice", 0) == 0, c
    print("PACKED COUNTS OK", c)

    # adapter (train-layout) Pallas path for comparison: pays 3 weight
    # gathers per step and a per-layer weight slice in the adapter.
    WQ = jax.random.normal(ks[3], (D, q_loc, hd // N)) * 0.05
    WO = jax.random.normal(ks[4], (q_loc * hd, D)) * 0.05

    def body_adapter(x, wq, wo, k, v, pos):
        ctx = ParallelCtx(model="c", heads=heads, cluster=clus,
                          model_static=8)
        w = _split_token_weights(
            ctx, type("A", (), dict(wq=wq, wk=wq[:, :kv_loc], wv=wq[:, :kv_loc],
                                    wo=wo, bq=None, bk=None, bv=None))())
        o, nc = df.split_token_attention(
            spec_p, x, w, df.KVBlock(k, v, pos), jnp.int32(3))
        return o[None]

    sm2 = shard_map(body_adapter, mesh=mesh, in_specs=(P(),) * 6,
                    out_specs=P("c"), check_vma=False)
    with tracecount.counting() as c2:
        jax.eval_shape(sm2, X, WQ, WO, cache.k, cache.v, cache.pos)
    c2 = dict(c2)
    assert c2.get("weight_slice", 0) >= 1, c2
    assert c2.get("weight_gather", 0) >= 3, c2
    assert c2.get("tree_gather", 0) >= 3, c2
    print("ADAPTER COUNTS OK", c2)
    """)


@pytest.mark.multidevice
def test_counters_engine_zero_weight_movement():
    """End-to-end decode step (gemma2 GQA ring + softcap, forced
    cluster 2): the prepacked engine traces with zero weight gathers and
    zero weight slices; the PR-1 adapter engine pays both."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.core import tracecount
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import build_engine

    cfg = reduced(get_config("gemma2-27b"))
    mesh = make_test_mesh()
    counts = {}
    for label, pp in (("prepack", "on"), ("adapter", "off")):
        params, pf, dec, state, lay, scfg = build_engine(
            cfg, mesh, max_seq=32, batch_global=4, cluster=2,
            backend="pallas", interpret=True, prepack=pp)
        tok = jnp.zeros((4,), jnp.int32)
        with tracecount.counting() as c:
            jax.eval_shape(dec, params["serve"], state, tok)
        counts[label] = dict(c)
        print(label, counts[label])
    assert counts["prepack"].get("weight_gather", 0) == 0, counts
    assert counts["prepack"].get("weight_slice", 0) == 0, counts
    assert counts["prepack"].get("weight_slice_hoisted", 0) == 0, counts
    assert counts["prepack"].get("pallas_kernel", 0) >= 1, counts
    assert counts["adapter"].get("weight_gather", 0) >= 3, counts
    assert counts["adapter"].get("weight_slice_hoisted", 0) >= 1, counts
    # the hoisted adapter path never slices inside the per-layer body
    assert counts["adapter"].get("weight_slice", 0) == 0, counts
    print("ENGINE COUNTS OK")
    """)


@pytest.mark.multidevice
def test_counters_fullblock_two_launches_zero_ffn_psum():
    """Full-block decode fusion proof (DESIGN.md §7): the fused prepacked
    decode step traces with exactly TWO ``pallas_call`` launches per
    dense-FFN attention layer (fused attention + fused FFN tail) plus
    ONE fused LM-head launch per STEP (the L5 sampling tail —
    kernels/fused_head, counted in detail in tests/test_fused_head.py),
    and exactly ONE activation ``psum_model`` per STEP (the embedding
    lookup — zero per-layer FFN psums, replaced by one fused
    ClusterReduce per layer); the unfused XLA step pays one FFN psum
    per layer on top."""
    run_multidevice("""
    from repro.configs import get_config, reduced
    from repro.core import tracecount
    from repro.launch.mesh import make_test_mesh
    from repro.launch.serve import build_engine

    # llama2: dense gated FFN, 1-position pattern; gemma2: ring + softcap,
    # 2-position pattern — the count scales with distinct layer positions
    for arch in ("llama2-7b", "gemma2-27b"):
        cfg = reduced(get_config(arch))
        period = len(cfg.block_pattern)
        mesh = make_test_mesh()
        counts = {}
        for label, kw in (("fused", dict(backend="pallas", interpret=True)),
                          ("xla", dict(backend="xla"))):
            params, pf, dec, state, lay, scfg = build_engine(
                cfg, mesh, max_seq=32, batch_global=4, **kw)
            tok = jnp.zeros((4,), jnp.int32)
            with tracecount.counting() as c:
                jax.eval_shape(dec, params["serve"], state, tok)
            counts[label] = dict(c)
            print(arch, label, counts[label])
        f = counts["fused"]
        # exactly 2 launches per traced layer position (fused attention +
        # fused FFN tail; the scan re-dispatches the same pair per group)
        # + 1 per-step fused LM-head launch (L5)
        assert f.get("pallas_kernel") == 2 * period + 1, (arch, f)
        assert f.get("ffn_pallas_kernel") == period, (arch, f)
        assert f.get("head_pallas_kernel") == 1, (arch, f)
        # zero per-layer activation psums: the only psum_model in the
        # whole step is the embedding assembly
        assert f.get("psum_model") == 1, (arch, f)
        assert f.get("ffn_cluster_reduce") == period, (arch, f)
        # no weight movement either (PR-2 invariant still holds)
        assert f.get("weight_gather", 0) == 0, (arch, f)
        assert f.get("weight_slice", 0) == 0, (arch, f)
        # the unfused step pays embed + one FFN psum per layer position
        assert counts["xla"].get("psum_model") == 1 + period, (arch, counts)
        assert counts["xla"].get("pallas_kernel", 0) == 0, (arch, counts)
    print("FULL-BLOCK COUNTS OK")
    """, timeout=1200)


# ---------------------------------------------------------------------------
# Derived state: checkpoints keep the training layout only
# ---------------------------------------------------------------------------
def test_checkpoint_round_trips_train_layout(tmp_path):
    from repro.checkpoint.manager import CheckpointManager, strip_derived
    from repro.serving.prepack import prepack_for_serving
    cfg, lay, params = _small_gqa_setup(cluster=2)
    packed = prepack_for_serving(cfg, lay, params, backend="pallas")

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(7, {"train": params, "serve": packed}, block=True)

    # only the training layout was written; it restores bit-identically
    restored, _ = mgr.restore(like=jax.tree.map(np.asarray, params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), b)

    # symmetric round-trip: restore also accepts the engine's
    # {"train","serve"} pair and strips it the same way save did
    restored2, _ = mgr.restore(
        like=jax.tree.map(np.asarray, {"train": params, "serve": packed}))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored2)):
        np.testing.assert_array_equal(np.asarray(a), b)

    # and the serve layout is re-derived, bit-identically, from it
    rederived = prepack_for_serving(
        cfg, lay, jax.tree.map(jnp.asarray, restored), backend="pallas")
    for a, b in zip(jax.tree.leaves(packed), jax.tree.leaves(rederived)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # recursive: an engine pair nested inside a larger snapshot strips
    # too; a NON-engine {"train","serve"} dict (e.g. metrics) does not
    eng = {"train": {"blocks": [], "tail": [], "x": 1},
           "serve": {"blocks": [], "tail": [], "x": 2}}
    out = strip_derived({"model": eng,
                         "metrics": {"train": 0.5, "serve": 0.7}})
    assert out == {"model": eng["train"],
                   "metrics": {"train": 0.5, "serve": 0.7}}
    assert strip_derived({"embed": 3}) == {"embed": 3}


# ---------------------------------------------------------------------------
# Autotune plumbing
# ---------------------------------------------------------------------------
def test_serve_plan_prepack_resolution(tmp_path):
    from repro.configs import get_config, reduced
    from repro.core.autotune import load_table, save_table, tune_serving
    cfg = reduced(get_config("llama2-7b"))
    path = str(tmp_path / "tune.json")
    p = tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                     backend="auto", table_path=path)
    assert p.backend == "pallas" and p.prepack is True
    p_x = tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                       backend="xla", table_path=path)
    assert p_x.prepack is False
    p_xf = tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                        backend="xla", prepack="on", table_path=path)
    assert p_xf.prepack is True
    # the table keys on the RESOLVED prepack bool: auto and an explicit
    # "on" that resolve identically share one cell (no duplicate tuning)
    p_on = tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                        backend="pallas", table_path=path)
    n_cells = len(load_table(path))
    p_on2 = tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                         backend="pallas", prepack=True, table_path=path)
    assert p_on2 == p_on and len(load_table(path)) == n_cells
    # typo'd knobs raise instead of silently disabling the fast path
    with pytest.raises(ValueError):
        tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                     backend="pallas", prepack="On", table_path=path)

    # a pre-prepack (PR-1 schema) table entry self-heals by re-tuning
    table = load_table(path)
    key = next(iter(table))
    del table[key]["prepack"]
    save_table(path, table)
    p2 = tune_serving(cfg, seq_len=512, batch=2, model_axis=4,
                      backend="auto", table_path=path)
    assert p2 == p
    assert "prepack" in load_table(path)[key]

    # attention-free archs never prepack under auto
    cfg_rec = reduced(get_config("rwkv6-3b"))
    p_rec = tune_serving(cfg_rec, seq_len=512, batch=2, model_axis=4,
                         backend="auto", table_path=path)
    assert p_rec.backend == "xla" and p_rec.prepack is False


def test_weight_gather_bytes_model():
    from repro.configs import get_config, reduced
    from repro.core.autotune import weight_gather_bytes_per_step
    cfg = reduced(get_config("llama2-7b"))
    kw = dict(model_axis=4, cluster_size=2)
    adapter = weight_gather_bytes_per_step(cfg, backend="pallas",
                                           prepack=False, **kw)
    assert adapter > 0
    assert weight_gather_bytes_per_step(cfg, backend="pallas",
                                        prepack=True, **kw) == 0.0
    assert weight_gather_bytes_per_step(cfg, backend="xla",
                                        prepack=False, **kw) == 0.0
    # cluster 1: the gathers are no-ops — nothing to model
    assert weight_gather_bytes_per_step(
        cfg, model_axis=4, cluster_size=1, backend="pallas",
        prepack=False) == 0.0
