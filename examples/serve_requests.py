"""Continuous batching demo: requests with staggered arrivals stream
through the slot scheduler over the ragged fused decode engine.

    PYTHONPATH=src python examples/serve_requests.py --arch llama2-7b

A short request retires mid-flight and its slot is re-admitted to a
later arrival while the long requests keep decoding — no lockstep
barrier, and free slots pay zero attend-step work (printed from the
per-slot work counters).

Fleet mode (``--replicas N``): the same trace runs through the
multi-replica router (serving/router.py) with queue-depth-aware
dispatch.  Add ``--fault KIND`` (any serving/faults.py kind) to inject
a deterministic fault into replica 0 mid-trace and watch the router
detect it, drain the replica, and recover every in-flight stream on the
survivors — the recap verifies the recovered streams byte-equal a
fault-free oracle run (DESIGN.md §9):

    PYTHONPATH=src python examples/serve_requests.py \\
        --replicas 2 --fault corrupt_kv

Single-bit SDC faults take ``--bit`` (``--fault flip_kv_bit --bit 7``
flips one exponent bit below the non-finite floor — only the integrity
fingerprints can see it).  ``--sweep`` runs the systematic single-bit
fault sweep (serving/sweep.py) over the fleet and prints the detection
coverage matrix (detected% / latency / oracle-exact% per fault kind ×
bit position, plus the fault-free false-positive control row):

    PYTHONPATH=src python examples/serve_requests.py --sweep
    PYTHONPATH=src python examples/serve_requests.py --sweep \\
        --sweep-bits all          # every bf16 bit position (nightly CI)
"""
import argparse
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import EngineOptions, build_engine_full
from repro.serving.scheduler import Request, SlotScheduler, replay_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b",
                    help="attention-only decoder configs")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-cap", type=int, default=12)
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "auto"))
    ap.add_argument("--prepack", default="auto",
                    choices=("auto", "on", "off"),
                    help="serve-layout weight prepack (auto: on whenever "
                         "the backend resolves to pallas — parity with "
                         "serve_decode.py)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="fleet mode: route the trace through a "
                         "multi-replica router (serving/router.py)")
    ap.add_argument("--fault", default=None,
                    help="inject a deterministic fault into replica 0 "
                         "(any serving/faults.py kind; implies fleet "
                         "mode with ≥2 replicas)")
    ap.add_argument("--fault-step", type=int, default=2,
                    help="fleet tick at which the fault arms")
    ap.add_argument("--bit", type=int, default=7,
                    help="bit position for the flip_* fault kinds "
                         "(bf16: 0-6 mantissa, 7-14 exponent, 15 sign)")
    ap.add_argument("--sweep", action="store_true",
                    help="run the systematic single-bit SDC fault sweep "
                         "and print the coverage matrix (implies fleet "
                         "mode)")
    ap.add_argument("--sweep-bits", default="0,7,14",
                    help="comma-separated bit positions for --sweep, or "
                         "'all' for the full 16-bit grid")
    args = ap.parse_args()
    if args.fault is not None or args.sweep:
        args.replicas = max(args.replicas, 2)
    if args.replicas > 1:
        return fleet_main(args)

    cfg = reduced(get_config(args.arch))
    mesh = make_test_mesh(data=1, model=8)
    rng = np.random.default_rng(args.seed)
    max_new_cap = 12
    eng = build_engine_full(
        cfg, mesh, max_seq=args.prompt_cap + max_new_cap + 8,
        batch_global=args.slots,
        options=EngineOptions(
            backend=args.backend, prepack=args.prepack,
            interpret=(args.backend != "xla"
                       and jax.default_backend() == "cpu"),
            track_work=True,
            # autotune keys on the max LIVE length, not the allocation
            plan_seq_len=args.prompt_cap + max_new_cap))
    sched = SlotScheduler(eng, prompt_cap=args.prompt_cap)

    trace = []
    for rid in range(args.requests):
        arrival = int(rng.integers(0, 3)) + rid // args.slots * 2
        plen = int(rng.integers(2, args.prompt_cap + 1))
        n_new = int(rng.integers(2, max_new_cap + 1))
        prompt = list(rng.integers(0, cfg.vocab_size, plen))
        trace.append((arrival, Request(rid, prompt, n_new)))
        print(f"req {rid}: arrive t={arrival} prompt_len={plen} "
              f"max_new={n_new}")

    t0 = time.time()
    results = replay_trace(sched, trace)
    dt = time.time() - t0
    print(f"\ndrained {args.requests} requests over {sched.tick} ticks "
          f"({sched.decode_calls} decode dispatches) in {dt:.2f}s")
    print(f"mean slot occupancy: "
          f"{np.mean(sched.occupancy):.2f}")
    print(f"per-slot attend-block work: {sched.work_blocks()}")
    # Token printout goes through the SERVE view of the head — with
    # --prepack the fused head bundle is what sampling consumed, not the
    # train tree (reaching into eng.params["train"] was the footgun);
    # head_table_np smoke-asserts the serve view aliases the train-
    # layout head bytes on the way.
    from repro.serving.prepack import head_table_np
    table = head_table_np(cfg, eng.params)
    for rid in sorted(results):
        r = results[rid]
        assert all(0 <= t < table.shape[0] for t in r.tokens), r.tokens
        norms = np.linalg.norm(table[np.asarray(r.tokens, np.int32)],
                               axis=-1) if r.tokens else np.array([])
        print(f"req {rid}: slot {r.slot} ticks "
              f"[{r.admit_tick}, {r.finish_tick}] tokens {r.tokens} "
              f"|e|={np.round(norms, 2)}")


def fleet_main(args):
    from repro.launch.serve import build_replicas
    from repro.serving.faults import (ALL_FAULT_KINDS, BIT_FAULT_KINDS,
                                      FaultInjector, FaultSpec)
    from repro.serving.router import Router

    cfg = reduced(get_config(args.arch))
    if cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=None)
    mesh = make_test_mesh(data=1, model=1)
    max_new_cap = 12
    rng = np.random.default_rng(args.seed)
    engines = build_replicas(
        cfg, mesh, n_replicas=args.replicas,
        max_seq=args.prompt_cap + max_new_cap + 8,
        batch_global=args.slots,
        options=EngineOptions(backend=args.backend, check_finite=True,
                              kv_fingerprint=True, shadow_head=True))
    trace = []
    for rid in range(args.requests):
        plen = int(rng.integers(2, args.prompt_cap + 1))
        trace.append((int(rng.integers(0, 4)), Request(
            rid, [int(t) for t in rng.integers(1, cfg.vocab_size, plen)],
            int(rng.integers(2, max_new_cap + 1)))))

    def run(injectors=None, integrity=None):
        r = Router(engines, prompt_cap=args.prompt_cap,
                   max_new_cap=max_new_cap, injectors=injectors,
                   integrity=integrity)
        journal = r.run([(t, Request(q.rid, q.prompt, q.max_new))
                         for t, q in trace])
        return r, journal

    print(f"fleet: {args.replicas} replicas, {args.requests} requests")
    if args.sweep:
        from repro.serving.faults import FaultSweep
        from repro.serving.integrity import IntegrityConfig
        from repro.serving.sweep import format_coverage, run_sdc_sweep
        bits = (tuple(range(16)) if args.sweep_bits == "all"
                else tuple(int(b) for b in args.sweep_bits.split(",")))
        print(f"systematic single-bit SDC sweep: kinds {BIT_FAULT_KINDS} "
              f"x bits {bits} x step {args.fault_step}")
        t0 = time.time()
        cells = run_sdc_sweep(
            engines, prompts=[q.prompt for _, q in trace],
            max_new=6, prompt_cap=args.prompt_cap,
            sweep=FaultSweep(bits=bits, steps=(args.fault_step,),
                             seed=args.seed),
            icfg=IntegrityConfig(weight_leaves_per_tick=4))
        print(format_coverage(cells))
        print(f"sweep drained in {time.time() - t0:.2f}s")
        return
    t0 = time.time()
    _, oracle = run()
    print(f"fault-free oracle drained in {time.time() - t0:.2f}s")
    if args.fault is None:
        for rid, e in sorted(oracle.items()):
            print(f"req {rid}: replicas {e.replicas} ticks "
                  f"[{e.submit_tick}, {e.finish_tick}] tokens {e.tokens}")
        return
    if args.fault not in ALL_FAULT_KINDS:
        raise SystemExit(f"--fault must be one of {ALL_FAULT_KINDS}")
    bit = args.bit if args.fault in BIT_FAULT_KINDS else -1
    inj = FaultInjector([FaultSpec(args.fault, step=args.fault_step,
                                   target=0, seed=args.seed, replica=0,
                                   bit=bit)])
    # single-bit faults are invisible to the PR-6 probes — they need the
    # integrity fingerprints (and the deferred-commit window they imply)
    icfg = None
    if args.fault in BIT_FAULT_KINDS:
        from repro.serving.integrity import IntegrityConfig
        icfg = IntegrityConfig(weight_leaves_per_tick=4)
    router, journal = run({0: inj}, integrity=icfg)
    print(f"\ninjected {args.fault} at tick {args.fault_step} "
          f"into replica 0")
    for d in router.detections:
        print(f"tick {d['tick']}: replica {d['replica']} FAILED — "
              f"signals {d['signals']}")
    lat = router.detection_latency(inj)
    print(f"detection latency: {lat} ticks | availability "
          f"{100 * router.availability():.1f}% | worst recovery "
          f"{router.recovery_steps()} ticks")
    exact = all(journal[r].tokens == oracle[r].tokens for r in oracle)
    for rid, e in sorted(journal.items()):
        mark = "=" if e.tokens == oracle[rid].tokens else "≠"
        flag = f" (requeued x{e.requeues})" if e.requeues else ""
        print(f"req {rid}: replicas {e.replicas}{flag} tokens "
              f"{e.tokens} {mark} oracle")
    print("zero-corruption recovery:",
          "OK — all streams byte-equal the oracle" if exact else "FAILED")
    assert exact


if __name__ == "__main__":
    main()
