"""Continuous batching demo: requests with staggered arrivals stream
through the slot scheduler over the ragged fused decode engine.

    PYTHONPATH=src python examples/serve_requests.py --arch llama2-7b

A short request retires mid-flight and its slot is re-admitted to a
later arrival while the long requests keep decoding — no lockstep
barrier, and free slots pay zero attend-step work (printed from the
per-slot work counters).
"""
import argparse
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import build_engine_full
from repro.serving.scheduler import Request, SlotScheduler, replay_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b",
                    help="attention-only decoder configs")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-cap", type=int, default=12)
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "auto"))
    ap.add_argument("--prepack", default="auto",
                    choices=("auto", "on", "off"),
                    help="serve-layout weight prepack (auto: on whenever "
                         "the backend resolves to pallas — parity with "
                         "serve_decode.py)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    mesh = make_test_mesh(data=1, model=8)
    rng = np.random.default_rng(args.seed)
    max_new_cap = 12
    eng = build_engine_full(
        cfg, mesh, max_seq=args.prompt_cap + max_new_cap + 8,
        batch_global=args.slots, backend=args.backend,
        prepack=args.prepack,
        interpret=(args.backend != "xla"
                   and jax.default_backend() == "cpu"),
        track_work=True,
        # autotune keys on the max LIVE length, not the allocation
        plan_seq_len=args.prompt_cap + max_new_cap)
    sched = SlotScheduler(eng, prompt_cap=args.prompt_cap)

    trace = []
    for rid in range(args.requests):
        arrival = int(rng.integers(0, 3)) + rid // args.slots * 2
        plen = int(rng.integers(2, args.prompt_cap + 1))
        n_new = int(rng.integers(2, max_new_cap + 1))
        prompt = list(rng.integers(0, cfg.vocab_size, plen))
        trace.append((arrival, Request(rid, prompt, n_new)))
        print(f"req {rid}: arrive t={arrival} prompt_len={plen} "
              f"max_new={n_new}")

    t0 = time.time()
    results = replay_trace(sched, trace)
    dt = time.time() - t0
    print(f"\ndrained {args.requests} requests over {sched.tick} ticks "
          f"({sched.decode_calls} decode dispatches) in {dt:.2f}s")
    print(f"mean slot occupancy: "
          f"{np.mean(sched.occupancy):.2f}")
    print(f"per-slot attend-block work: {sched.work_blocks()}")
    # Token printout goes through the SERVE view of the head — with
    # --prepack the fused head bundle is what sampling consumed, not the
    # train tree (reaching into eng.params["train"] was the footgun);
    # head_table_np smoke-asserts the serve view aliases the train-
    # layout head bytes on the way.
    from repro.serving.prepack import head_table_np
    table = head_table_np(cfg, eng.params)
    for rid in sorted(results):
        r = results[rid]
        assert all(0 <= t < table.shape[0] for t in r.tokens), r.tokens
        norms = np.linalg.norm(table[np.asarray(r.tokens, np.int32)],
                               axis=-1) if r.tokens else np.array([])
        print(f"req {rid}: slot {r.slot} ticks "
              f"[{r.admit_tick}, {r.finish_tick}] tokens {r.tokens} "
              f"|e|={np.round(norms, 2)}")


if __name__ == "__main__":
    main()
