"""Batched serving with the ClusterFusion dataflow: prefill a batch of
prompts, decode with the fused SplitToken path, and compare the
paper-faithful combine against the beyond-paper fused-merge combine.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-27b
"""
import argparse
import os
import time

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import build_engine, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "auto"),
                    help="local-stage compute backend (pallas runs the "
                         "fused decode kernels; interpret mode on CPU)")
    ap.add_argument("--prepack", default="auto",
                    choices=("auto", "on", "off"),
                    help="serve-layout weight prepack at load time "
                         "(auto: on whenever backend resolves to pallas; "
                         "checkpoints always keep the training layout)")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    mesh = make_test_mesh()
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (args.batch, 16), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(key, (args.batch,
                                     cfg.frontend.num_positions,
                                     cfg.frontend.feature_dim))
    outs = {}
    for fused_combine in (False, True):
        params, pf, dec, state, lay, scfg = build_engine(
            cfg, mesh, max_seq=64, batch_global=args.batch,
            fused_combine=fused_combine, backend=args.backend,
            prepack=args.prepack,
            interpret=(args.backend != "xla"
                       and jax.default_backend() == "cpu"))
        t0 = time.time()
        toks, _ = generate(cfg, params, pf, dec, state, prompts,
                           args.tokens, fe)
        dt = time.time() - t0
        label = "fused-merge" if fused_combine else "paper-faithful"
        label += f"/{args.backend}"
        if scfg.prepack:
            label += "+prepack"
        outs[fused_combine] = np.asarray(toks)
        print(f"{label:16s} combine: {args.tokens} tok × {args.batch} seq "
              f"in {dt:.2f}s  (cluster={lay.cluster})")
    agree = (outs[False] == outs[True]).mean()
    if scfg.prepack:
        # the prepacked partial_o path always runs the single-tree merge
        # (constitutive of its one-ClusterReduce contract), so the two
        # iterations above exercised the same combine schedule
        print("note: prepack unifies the combine — both rows ran the "
              "fused single-tree merge")
    print(f"paper-faithful vs fused-merge token agreement: {agree:.3f}")
    # Print the sample through the SERVE view of the head — the (table,
    # ln) decode actually sampled with.  With --prepack the fused head
    # bundle is what ran, not the train tree; head_table_np also
    # smoke-asserts the serve view aliases the train-layout head bytes
    # (reaching into params["train"] was the footgun this replaces).
    from repro.serving.prepack import head_table_np
    table = head_table_np(cfg, params)
    sample = outs[True][0][:12]
    assert (sample >= 0).all() and (sample < table.shape[0]).all(), sample
    norms = np.linalg.norm(table[sample], axis=-1)
    print("sample:", sample)
    print("serve-view head rows |e| of sample:", np.round(norms, 3))


if __name__ == "__main__":
    main()
