"""Quickstart: the ClusterFusion primitives and fused dataflow in 60 lines.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import primitives as prim
from repro.core import dataflow as df

# --- 1. the paper's collectives on an 8-chip "cluster" -------------------
mesh = jax.make_mesh((8,), ("cluster",),
                     axis_types=(jax.sharding.AxisType.Auto,))
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)

reduce8 = jax.jit(shard_map(
    lambda v: prim.cluster_reduce(v, "cluster", "sum"),
    mesh=mesh, in_specs=P("cluster", None), out_specs=P("cluster", None)))
print("ClusterReduce (Alg. 1, log2(8)=3 ppermute rounds):",
      np.asarray(reduce8(x))[0])

gather8 = jax.jit(shard_map(
    lambda v: prim.cluster_gather_tiled(v, "cluster", axis=1),
    mesh=mesh, in_specs=P("cluster", None), out_specs=P("cluster", None)))
print("ClusterGather (Alg. 2, doubling messages):",
      np.asarray(gather8(x))[0, :8], "...")

# --- 2. traffic model (paper §3.2) — why SplitToken wins at long S -------
for S in (1024, 16384):
    st = df.traffic_split_token(head_dim=128, model_dim=4096, n=4)
    sh = df.traffic_split_head(seq_len=S, model_dim=4096, n=4)
    print(f"S={S}: SplitToken traffic {st:.0f}B vs SplitHead {sh:.0f}B "
          f"({sh / st:.0f}× more)")

# --- 3. one fused decode step on a tiny model -----------------------------
from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import build_engine, generate

cfg = reduced(get_config("llama2-7b"))
mesh2 = make_test_mesh()                    # (data=2, model=4)
params, pf, dec, state, lay, _ = build_engine(cfg, mesh2, max_seq=64,
                                              batch_global=2)
prompts = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0,
                             cfg.vocab_size)
tokens, _ = generate(cfg, params, pf, dec, state, prompts, 8)
print(f"fused decode (heads_sub={lay.heads_sub} × cluster={lay.cluster}):",
      np.asarray(tokens)[0])
