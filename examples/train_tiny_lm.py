"""End-to-end training driver: a ~100M-parameter llama-style model for a
few hundred steps on the host mesh, with ZeRO-1, remat, checkpoints, and
the synthetic data pipeline.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""
import argparse
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


from repro.launch.train import run
from repro.training.optimizer import OptConfig
from repro.training.train_step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_tiny_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: 8 layers, d_model 512, llama-style
    from repro.configs.base import ModelConfig, _REGISTRY
    _REGISTRY["tiny-100m"] = lambda: ModelConfig(
        name="tiny-100m", family="dense", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=65536,
        ffn_act="silu", ffn_gated=True,
        source="[this repo; example]")
    print("params:",
          f"{_REGISTRY['tiny-100m']().param_count() / 1e6:.1f}M")

    losses = run("tiny-100m", steps=args.steps, use_reduced=False,
                 ckpt_dir=args.ckpt, batch_override=8, seq_override=128,
                 tcfg=TrainConfig(opt=OptConfig(lr=3e-4, name="adamw"),
                                  microbatches=2, zero1=True),
                 log_every=25)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
