"""Cluster-size / dataflow tuning (paper §4.1 Fig. 11 + App. B):
sweep the analytical model per architecture × context length, print the
chosen configuration — what ``serving_layout`` does automatically.

    PYTHONPATH=src python examples/dataflow_tuning.py
"""
from repro.configs import get_config, list_archs
from repro.core.autotune import tune_cluster


def main():
    print(f"{'arch':24s} {'S':>7s}  best  dataflow      est_ms   "
          "(mem/comp/ici ms)")
    for arch in list_archs():
        cfg = get_config(arch)
        if cfg.is_attention_free:
            print(f"{arch:24s} {'—':>7s}  inapplicable (attention-free; "
                  "DESIGN.md §4)")
            continue
        for S in (1024, 16384, 131072):
            best = tune_cluster(cfg, seq_len=S, batch=1, model_axis=16)
            t = best.terms
            print(f"{arch:24s} {S:7d}  N={best.cluster_size:<3d} "
                  f"{best.dataflow:12s} {best.est_seconds*1e3:8.3f}   "
                  f"({t['mem']*1e3:.3f}/{t['comp']*1e3:.3f}/"
                  f"{t['ici']*1e3:.3f})")


if __name__ == "__main__":
    main()
