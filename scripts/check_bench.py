#!/usr/bin/env python
"""CI perf-regression gate for ``BENCH_tpot.json``.

Diffs the DETERMINISTIC columns of a fresh bench report against the
committed baseline (``benchmarks/BENCH_baseline.json``): trace-time
launch/psum counts and the modeled ICI/HBM byte columns.  Wall-clock
columns (``tpot_us`` and friends) are machine-dependent noise on CI
runners and are never gated.

Per-column policy:

* **counters** (``pallas_launches_per_step``, ``psum_model_per_step``)
  must match the baseline EXACTLY in both directions — an unexpected
  drop is as suspicious as a rise (it usually means a dispatch stopped
  reaching the fused path at all).
* **byte columns** fail only when they INCREASE beyond the per-column
  relative tolerance; decreases are improvements, reported in the delta
  table and accepted (update the baseline in the same PR to lock them
  in).
* every (arch × variant × column) cell present in the baseline must be
  present in the current report — a vanished cell is a regression (a
  variant silently dropped out of the bench).  Cells only in the
  current report are listed as NEW and accepted.

Exit status 0 on pass, 1 on regression; the delta table always prints.

Usage::

    python scripts/check_bench.py BENCH_tpot.json benchmarks/BENCH_baseline.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Tuple

# column → (kind, relative tolerance).  kind "count" = exact both ways;
# kind "bytes" = one-sided (increase beyond tol fails).
GATED_COLUMNS: Dict[str, Tuple[str, float]] = {
    "pallas_launches_per_step": ("count", 0.0),
    "psum_model_per_step": ("count", 0.0),
    "ici_weight_gather_bytes_per_step": ("bytes", 0.01),
    "ffn_psum_ici_bytes_per_step": ("bytes", 0.01),
    "ffn_fused_reduce_ici_bytes_per_step": ("bytes", 0.01),
    "head_ici_bytes_per_step": ("bytes", 0.01),
    "head_hbm_logits_bytes_per_step": ("bytes", 0.01),
    # the fused tail's candidate width (sampling.CAND_K): the k in the
    # k-wide streaming top-k and its cross-shard merge.  Exact both
    # ways — a silent widening inflates the head ICI bytes, a silent
    # narrowing breaks the top-k/top-p exactness envelope.
    "head_sample_k": ("count", 0.0),
}

# Fleet-chaos columns (``report["router_chaos"]["faults"][<kind>]``,
# emitted under ``--trace``): detection latency, recovery ticks,
# availability and oracle-exactness of the fault-injected router runs.
# All four are deterministic tick arithmetic (benchmarks/bench_tpot.py),
# so they gate EXACTLY — a detection getting slower, a recovery taking
# extra ticks, or a recovered stream diverging from the oracle is a
# robustness regression even when no wall-clock moves.  Kept separate
# from GATED_COLUMNS: these live on fault cells, not arch/variant cells.
ROUTER_GATED_COLUMNS: Dict[str, Tuple[str, float]] = {
    "detect_steps": ("count", 0.0),
    "recovery_steps": ("count", 0.0),
    "availability_pct": ("count", 0.0),
    "oracle_exact_pct": ("count", 0.0),
}

# SDC-sweep columns (``report["sdc_sweep"]["cells"][<cell>]``, emitted
# under ``--trace``): single-bit fault detection coverage / latency /
# oracle exactness per (fault kind × bit position), plus the fault-free
# control row (false-positive signal count, stream byte-equality, and
# per-tick probe bytes).  Coverage and latency are deterministic tick
# arithmetic and gate exactly; probe bytes are exact shape arithmetic
# but carry the bytes policy (a DROP in probe coverage should show up
# as the coverage columns changing, not sneak through the byte gate).
SDC_GATED_COLUMNS: Dict[str, Tuple[str, float]] = {
    "detected_pct": ("count", 0.0),
    "detect_steps": ("count", 0.0),
    "oracle_exact_pct": ("count", 0.0),
    "false_positive_signals": ("count", 0.0),
    "streams_match": ("count", 0.0),
    "probe_bytes_per_tick": ("bytes", 0.05),
}

_ALL_COLUMNS = {**GATED_COLUMNS, **ROUTER_GATED_COLUMNS,
                **SDC_GATED_COLUMNS}

_ABS_EPS = 1e-9      # float-repr jitter floor for the bytes columns


def _cells(report: dict):
    """Yield ((arch, variant), column, value) for every gated column."""
    for arch, entry in sorted(report.get("archs", {}).items()):
        for variant, d in sorted(entry.get("variants", {}).items()):
            for col in GATED_COLUMNS:
                if col in d:
                    yield (arch, variant), col, float(d[col])
    chaos = report.get("router_chaos", {})
    for kind, d in sorted(chaos.get("faults", {}).items()):
        for col in ROUTER_GATED_COLUMNS:
            if col in d:
                yield ("router_chaos", kind), col, float(d[col])
    sdc = report.get("sdc_sweep", {})
    for cell, d in sorted(sdc.get("cells", {}).items()):
        for col in SDC_GATED_COLUMNS:
            if col in d:
                yield ("sdc_sweep", cell), col, float(d[col])


def diff_reports(current: dict, baseline: dict) -> List[dict]:
    """Row per (cell × column): status ok | improved | NEW | REGRESSION."""
    cur = {(cell, col): v for cell, col, v in _cells(current)}
    base = {(cell, col): v for cell, col, v in _cells(baseline)}
    rows = []
    for key in sorted(set(base) | set(cur)):
        (arch, variant), col = key
        kind, tol = _ALL_COLUMNS[col]
        b, c = base.get(key), cur.get(key)
        if b is None:
            status = "NEW"
        elif c is None:
            status = "REGRESSION (cell vanished)"
        elif kind == "count":
            status = "ok" if c == b else "REGRESSION (count changed)"
        else:
            if c > b * (1.0 + tol) + _ABS_EPS:
                status = "REGRESSION (bytes up)"
            elif c < b - _ABS_EPS:
                status = "improved"
            else:
                status = "ok"
        rows.append({"arch": arch, "variant": variant, "column": col,
                     "baseline": b, "current": c, "status": status})
    return rows


def format_table(rows: List[dict]) -> str:
    def fmt(v):
        if v is None:
            return "-"
        return f"{v:.0f}" if float(v) == int(v) else f"{v:.1f}"

    widths = [
        max([len("arch/variant")] + [len(f"{r['arch']}/{r['variant']}")
                                     for r in rows]),
        max([len("column")] + [len(r["column"]) for r in rows]),
        max([len("baseline")] + [len(fmt(r["baseline"])) for r in rows]),
        max([len("current")] + [len(fmt(r["current"])) for r in rows]),
    ]
    head = (f"{'arch/variant':<{widths[0]}}  {'column':<{widths[1]}}  "
            f"{'baseline':>{widths[2]}}  {'current':>{widths[3]}}  status")
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(
            f"{r['arch'] + '/' + r['variant']:<{widths[0]}}  "
            f"{r['column']:<{widths[1]}}  "
            f"{fmt(r['baseline']):>{widths[2]}}  "
            f"{fmt(r['current']):>{widths[3]}}  {r['status']}")
    return "\n".join(lines)


def check(current: dict, baseline: dict) -> Tuple[bool, str]:
    """(passed, delta table) — the gate used by CI and the tests."""
    rows = diff_reports(current, baseline)
    table = format_table(rows)
    n_reg = sum("REGRESSION" in r["status"] for r in rows)
    n_imp = sum(r["status"] == "improved" for r in rows)
    summary = (f"\n{len(rows)} gated cells: {n_reg} regressions, "
               f"{n_imp} improvements")
    if n_imp and not n_reg:
        summary += ("\nimprovements detected — refresh "
                    "benchmarks/BENCH_baseline.json to lock them in")
    return n_reg == 0, table + summary


def main(argv: List[str]) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        current = json.load(f)
    with open(argv[2]) as f:
        baseline = json.load(f)
    passed, report = check(current, baseline)
    print(report)
    print("\nbench gate:", "PASS" if passed else "FAIL")
    return 0 if passed else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv))
