"""Paper Fig. 20 / App. B analogue: SplitToken vs SplitHead dataflow —
measured µs on 8 host devices + the analytical traffic crossover.
"""
import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from benchmarks.common import row, time_fn
from repro.core import dataflow as df
from repro.core import primitives as prim


def main(seqs=(512, 2048, 8192, 32768)):
    n_dev = min(8, jax.device_count())
    H, N = 2, n_dev // 2
    heads_ax = prim.SubAxis("model", H, minor_size=N)
    clus_ax = prim.SubAxis("model", N, minor_size=1)
    mesh = jax.make_mesh((n_dev,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    B, D, hd, n_heads = 1, 256, 64, 4
    q_loc = n_heads // H
    key = jax.random.PRNGKey(0)
    rows = []
    for S in seqs:
        ks = jax.random.split(key, 8)
        x = jax.random.normal(ks[0], (B, D)) * 0.3
        hd_n = hd // N
        clen = jnp.int32(S - 2)
        spec = df.ClusterSpec(heads=heads_ax, cluster=clus_ax)

        # SplitToken: seq-sharded cache
        wq = jax.random.normal(ks[1], (n_dev, D, q_loc, hd_n)) * 0.05
        wk = jax.random.normal(ks[2], (n_dev, D, q_loc, hd_n)) * 0.05
        wv = jax.random.normal(ks[3], (n_dev, D, q_loc, hd_n)) * 0.05
        wo = jax.random.normal(ks[4], (n_dev, q_loc * hd, D // N)) * 0.05
        kc = jax.random.normal(ks[5], (n_dev, S // N, B * q_loc, hd)) * 0.3
        vc = jax.random.normal(ks[6], (n_dev, S // N, B * q_loc, hd)) * 0.3
        pos = jnp.tile(jnp.arange(S // N, dtype=jnp.int32)[None], (n_dev, 1))

        def st_fn(x_, wq_, wk_, wv_, wo_, kc_, vc_, pos_):
            w = df.SplitTokenWeights(wq=wq_[0], wk=wk_[0], wv=wv_[0],
                                     wo=wo_[0])
            cache = df.KVBlock(k=kc_[0], v=vc_[0], pos=pos_[0])
            o, _ = df.split_token_attention(spec, x_, w, cache, clen)
            return prim.cluster_gather_tiled(o, clus_ax, axis=1)[None]

        st_j = jax.jit(shard_map(st_fn, mesh=mesh,
                                 in_specs=(P(),) + (P("model"),) * 7,
                                 out_specs=P("model"), check_vma=False))
        t_st = time_fn(st_j, x, wq, wk, wv, wo, kc, vc, pos, iters=10)

        # SplitHead: head-dim-sharded cache over the FULL sequence
        woh = jax.random.normal(ks[4], (n_dev, q_loc * hd_n, D)) * 0.05
        kch = jax.random.normal(ks[5], (n_dev, S, B * q_loc, hd_n)) * 0.3
        vch = jax.random.normal(ks[6], (n_dev, S, B * q_loc, hd_n)) * 0.3
        posh = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None], (n_dev, 1))

        def sh_fn(x_, wq_, wk_, wv_, wo_, kc_, vc_, pos_):
            w = df.SplitHeadWeights(wq=wq_[0], wk=wk_[0], wv=wv_[0],
                                    wo=wo_[0])
            cache = df.KVBlock(k=kc_[0], v=vc_[0], pos=pos_[0])
            o, _ = df.split_head_attention(spec, x_, w, cache, clen)
            return o[None]

        sh_j = jax.jit(shard_map(sh_fn, mesh=mesh,
                                 in_specs=(P(),) + (P("model"),) * 7,
                                 out_specs=P("model"), check_vma=False))
        t_sh = time_fn(sh_j, x, wq, wk, wv, woh, kch, vch, posh, iters=10)

        tr_st = df.traffic_split_token(hd, D, N)
        tr_sh = df.traffic_split_head(S, D, N)
        rows.append(row(f"split_token_S{S}", t_st, f"traffic_B={tr_st:.0f}"))
        rows.append(row(f"split_head_S{S}", t_sh,
                        f"traffic_B={tr_sh:.0f},"
                        f"ratio={tr_sh / max(tr_st, 1):.1f}"))
    return rows


if __name__ == "__main__":
    main()
