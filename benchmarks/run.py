"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Benchmarks that need an 8-device
mesh respawn themselves in a subprocess with the device-count flag (the
main process keeps 1 device, per the assignment contract).
"""
import os
import subprocess
import sys


MULTI = ["bench_primitives", "bench_core_module", "bench_cluster_size",
         "bench_dataflows", "bench_tpot"]


def _spawn(mod: str) -> int:
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": os.environ.get("PYTHONPATH", "src")}
    return subprocess.call([sys.executable, "-m", f"benchmarks.{mod}"],
                           env=env)


def main() -> None:
    print("name,us_per_call,derived")
    rc = 0
    for mod in MULTI:
        print(f"# --- {mod} (paper-table analogue) ---")
        rc |= _spawn(mod)
    if rc:
        raise SystemExit(rc)


if __name__ == "__main__":
    main()
