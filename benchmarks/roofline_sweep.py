"""Run the scan-extrapolated roofline over all single-pod cells."""
import json

from repro.configs import SHAPES, get_config, list_archs, shapes_for
from benchmarks.roofline import scan_extrapolated_cell, to_markdown


def main():
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        ok = {s.name for s in shapes_for(cfg)}
        for shape in SHAPES.values():
            if shape.name not in ok:
                rows.append({"arch": arch, "shape": shape.name,
                             "skipped": True,
                             "reason": "unbounded full-attention KV at 500k"})
                continue
            try:
                r = scan_extrapolated_cell(arch, shape.name)
                rows.append(r)
                print(f"{arch} x {shape.name}: dominant={r['dominant']} "
                      f"useful={r['useful_flops_ratio']:.2f}", flush=True)
            except Exception as e:  # noqa: BLE001
                rows.append({"arch": arch, "shape": shape.name,
                             "error": repr(e)})
                print(f"{arch} x {shape.name}: ERROR {e!r}", flush=True)
    with open("/root/repo/roofline_all.json", "w") as f:
        json.dump(rows, f, indent=1, default=str)
    open("/root/repo/roofline_all.md", "w").write(to_markdown(rows))
    print("done")


if __name__ == "__main__":
    main()
