"""Paper Table 1 + Fig. 13 analogue: on-chip (tree over the cluster fabric)
ClusterReduce/ClusterGather vs the off-chip pattern (materialize all N
buffers, reduce locally), across transfer sizes.

Runs on an 8-host-device mesh; µs are CPU-relative, the derived column is
the fabric traffic from the paper's analytical model (§3.2).
"""
import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from benchmarks.common import row, time_fn
from repro.core import primitives as prim


def main():
    n = min(8, jax.device_count())
    mesh = jax.make_mesh((n,), ("c",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rows = []
    for kb in (32, 64, 128, 256):
        elems = kb * 1024 // 4
        x = jnp.arange(n * elems, dtype=jnp.float32).reshape(n, elems)

        def mk(fn):
            return jax.jit(shard_map(fn, mesh=mesh, in_specs=P("c", None),
                                     out_specs=P("c", None)))

        on_r = mk(lambda v: prim.cluster_reduce(v, "c", "sum"))
        off_r = mk(lambda v: prim.offchip_reduce(v[0], "c")[None])
        on_g = mk(lambda v: prim.cluster_gather_tiled(v, "c", axis=1))
        off_g = mk(lambda v: jax.lax.all_gather(v[0], "c", axis=0,
                                                tiled=True)[None])
        t_on_r = time_fn(on_r, x)
        t_off_r = time_fn(off_r, x)
        t_on_g = time_fn(on_g, x)
        t_off_g = time_fn(off_g, x)
        tr = prim.traffic_reduce(kb * 1024, n)
        tg = prim.traffic_gather(kb * 1024, n)
        rows.append(row(f"cluster_reduce_onchip_{kb}KB", t_on_r,
                        f"traffic_B={tr:.0f}"))
        rows.append(row(f"cluster_reduce_offchip_{kb}KB", t_off_r,
                        f"speedup={t_off_r / max(t_on_r, 1e-9):.2f}x"))
        rows.append(row(f"cluster_gather_onchip_{kb}KB", t_on_g,
                        f"traffic_B={tg:.0f}"))
        rows.append(row(f"cluster_gather_offchip_{kb}KB", t_off_g,
                        f"speedup={t_off_g / max(t_on_g, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    main()
