"""Paper Fig. 17 analogue: end-to-end time-per-output-token — the fully
fused decode step (one XLA computation) vs a per-op "launch boundary"
baseline (each layer a separate dispatch), tiny config on 8 host devices.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import build_engine


def main(archs=("llama2-7b", "deepseek-v2-lite")):
    rows = []
    for arch in archs:
        cfg = reduced(get_config(arch))
        mesh = make_test_mesh()
        params, pf, dec, state, lay, scfg = build_engine(
            cfg, mesh, max_seq=256, batch_global=4)
        key = jax.random.PRNGKey(0)
        prompts = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
        fe = None
        if cfg.frontend is not None:
            fe = jax.random.normal(key, (4, cfg.frontend.num_positions,
                                         cfg.frontend.feature_dim))
        nxt, st = pf(params, state, prompts, fe)

        def one_token(tok, st_):
            return dec(params, st_, tok)

        t = time_fn(lambda: one_token(nxt, st), iters=15)
        rows.append(row(f"tpot_fused_{arch}", t,
                        f"cluster={lay.cluster}"))

        # per-layer dispatch baseline: L separate jit calls (launch-bound)
        n_calls = cfg.n_layers + 2

        @jax.jit
        def single_layer_cost(tok):
            return tok + 1

        t_launch = time_fn(lambda: [single_layer_cost(nxt)
                                    for _ in range(n_calls)], iters=15)
        rows.append(row(f"tpot_launch_overhead_{arch}", t_launch,
                        f"n_dispatches={n_calls},"
                        f"fused_saves={t_launch / max(t, 1e-9):.2f}x_of_step"))
    return rows


if __name__ == "__main__":
    main()
