"""Paper Fig. 17 analogue: end-to-end time-per-output-token.

Three measurements per arch:

* ``tpot_fused_<arch>``    — the fully fused decode step (one XLA
  computation for embed + L layers + head + sampling) on the test mesh.
* ``tpot_unfused_<arch>``  — a REAL per-layer decode loop on one device:
  the same transformer blocks, but embed / each layer / head+sample are
  separate ``jit`` dispatches (the per-op launch-boundary regime the
  paper's baseline pays).  The fused/unfused ratio is the honest fusion
  speedup — same FLOPs, different dispatch granularity.
* ``tpot_cachelen_<arch>_<L>`` — cache-length sweep: decode-step time
  after prefilling L tokens.  With the block-bucketed dataflow
  (DESIGN.md §3) step time grows with the LIVE cache length instead of
  sitting flat at the allocated ``max_seq`` cost.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import build_engine
from repro.models import layout_for, single_device_ctx, unwrap_local
from repro.models.transformer import init_device_major
from repro.serving.engine import (ServeConfig, decode_block,
                                  init_decode_state)


def _unfused_decode_us(cfg, max_seq: int, batch: int, iters: int = 15):
    """(unfused_us, fused_us) per-token times on one device.

    Unfused: every layer is its own jit call (plus embed and
    head+sample), i.e. L+2 real dispatches of real work per token — the
    launch-bound baseline the paper compares against, not a stand-in.
    Fused: the identical work as ONE ``decode_step`` dispatch.  Each
    dispatch is a trivial 1×1 ``shard_map`` so the dataflow's axis names
    exist (all collectives degenerate to no-ops at size 1).
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    ctx = single_device_ctx()
    lay = layout_for(cfg, 1)
    params_dm = init_device_major(cfg, lay, jax.random.PRNGKey(0))
    params = unwrap_local(params_dm)
    scfg = ServeConfig(max_seq=max_seq, batch_local=batch)
    state = init_decode_state(cfg, scfg, ctx)
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period

    import math
    from repro.models.layers import (EmbedParams, embed_lookup,
                                     lm_head_logits, rms_norm, softcap)
    from repro.serving.engine import greedy_sample

    def _sm(fn, n_args):
        return jax.jit(shard_map(fn, mesh=mesh1, in_specs=(P(),) * n_args,
                                 out_specs=P(), check_vma=False))

    embed_step = _sm(lambda tok: embed_lookup(
        ctx, EmbedParams(params["embed"]), tok)
        * (jnp.asarray(math.sqrt(cfg.d_model), jnp.bfloat16)
           if cfg.tie_embeddings else 1), 1)

    def _mk_group(kind):
        # one dispatch = slice group gi, run the block, write the cache back
        def f(blks, gi, x, caches, cl):
            blk = jax.tree.map(lambda l: l[gi], blks)
            cache_i = jax.tree.map(lambda l: l[gi], caches)
            x, nc = decode_block(ctx, cfg, kind, blk, x, cache_i, cl, scfg)
            new = jax.tree.map(
                lambda full, upd: full.at[gi].set(upd.astype(full.dtype)),
                caches, nc)
            return x, new
        return _sm(f, 5)

    def _mk_tail(kind):
        def f(blk, x, cache, cl):
            return decode_block(ctx, cfg, kind, blk, x, cache, cl, scfg)
        return _sm(f, 4)

    _group = {k: _mk_group(k) for k in set(kinds)}
    _tail = {k: _mk_tail(k) for k in set(kinds[n_groups * period:])} \
        if cfg.n_layers > n_groups * period else {}

    def _head(x):
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = lm_head_logits(ctx, table, x)
        if cfg.logit_softcap:
            logits = softcap(logits, cfg.logit_softcap)
        return greedy_sample(ctx, logits)

    head_step = _sm(_head, 1)

    def one_token(tok, state):
        cache_len = state["cache_len"]
        x = embed_step(tok)
        for gi in range(n_groups):
            for p_i in range(period):
                x, state["layers"][p_i] = _group[kinds[p_i]](
                    params["blocks"][p_i], jnp.int32(gi), x,
                    state["layers"][p_i], cache_len)
        for t_i, blk in enumerate(params["tail"]):
            x, state["tail"][t_i] = _tail[kinds[n_groups * period + t_i]](
                blk, x, state["tail"][t_i], cache_len)
        return head_step(x), state

    tok = jnp.zeros((batch,), jnp.int32)
    st = {**state, "layers": list(state["layers"]),
          "tail": list(state["tail"])}
    t_unfused = time_fn(lambda: one_token(tok, st)[0], iters=iters)

    # apples-to-apples fused reference: the SAME single-device work as ONE
    # dispatch (full decode_step under a single jit)
    from repro.serving.engine import decode_step
    fused = _sm(lambda p, s, t: decode_step(ctx, cfg, scfg, p, s, t), 3)
    t_fused = time_fn(lambda: fused(params_dm, state, tok), iters=iters)
    return t_unfused, t_fused


def main(archs=("llama2-7b", "deepseek-v2-lite")):
    rows = []
    for arch in archs:
        cfg = reduced(get_config(arch))
        mesh = make_test_mesh()
        params, pf, dec, state, lay, scfg = build_engine(
            cfg, mesh, max_seq=256, batch_global=4)
        key = jax.random.PRNGKey(0)
        prompts = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
        fe = None
        if cfg.frontend is not None:
            fe = jax.random.normal(key, (4, cfg.frontend.num_positions,
                                         cfg.frontend.feature_dim))
        nxt, st = pf(params, state, prompts, fe)

        t = time_fn(lambda: dec(params, st, nxt), iters=15)
        rows.append(row(f"tpot_fused_{arch}", t, f"cluster={lay.cluster}"))

        # REAL per-layer dispatch baseline: L+2 jit calls of actual work,
        # vs the same single-device work fused into one dispatch.
        t_unfused, t_fused1 = _unfused_decode_us(cfg, max_seq=256, batch=4)
        rows.append(row(f"tpot_fused1_{arch}", t_fused1, "n_dispatches=1"))
        rows.append(row(
            f"tpot_unfused_{arch}", t_unfused,
            f"n_dispatches={cfg.n_layers + 2},"
            f"fusion_speedup={t_unfused / max(t_fused1, 1e-9):.2f}x"))

        # cache-length sweep: step cost should GROW with live tokens
        # (and sit below the full-cache cost at short lengths).
        sweep = {}
        for L in (16, 64, 192):
            pr = jax.random.randint(key, (4, L), 0, cfg.vocab_size)
            nxt_l, st_l = pf(params, state, pr, fe)
            t_l = time_fn(lambda: dec(params, st_l, nxt_l), iters=15)
            sweep[L] = t_l
            rows.append(row(f"tpot_cachelen_{arch}_{L}", t_l,
                            f"live={L}/256"))
        rows.append(row(
            f"tpot_cachelen_{arch}_ratio", sweep[192] / max(sweep[16], 1e-9),
            "short_cache_cheaper" if sweep[16] < sweep[192] else "flat"))
    return rows


if __name__ == "__main__":
    main()
