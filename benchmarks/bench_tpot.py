"""Paper Fig. 17 analogue: end-to-end time-per-output-token.

Measurements per arch:

* ``tpot_<variant>_<arch>`` — the fully fused decode step (one dispatch
  for embed + L layers + head + sampling) on the test mesh, per backend
  variant: ``xla``, ``pallas`` (PR-1 adapter path, per-step weight
  gathers) and ``pallas_prepack`` (serve-layout weights + in-kernel
  Output-Projection, serving/prepack.py).
* ``tpot_unfused_<arch>``  — a REAL per-layer decode loop on one device:
  the same transformer blocks, but embed / each layer / head+sample are
  separate ``jit`` dispatches (the per-op launch-boundary regime the
  paper's baseline pays).  The fused/unfused ratio is the honest fusion
  speedup — same FLOPs, different dispatch granularity.
* ``tpot_cachelen_<variant>_<arch>_<L>`` — cache-length sweep: decode
  step time after prefilling L tokens (cost ∝ live prefix, DESIGN.md §3).
* ``tpot_sampling_<s>_<variant>_<arch>`` — sampling-variant sweep
  (``greedy`` / ``topk8`` / ``topp0.9``): the SAME jitted decode step
  timed under different per-slot sampling-param state leaves
  (serving/sampling.py) — evidence temperature/top-k/top-p stay in the
  fused tail (no retrace, no extra dispatch).  The report also carries
  ``head_sample_k`` (the fused tail's candidate width, gated exactly)
  and the k-wide ``head_ici_bytes_per_step`` model.
* ``--trace`` — ragged-arrival trace mode: a random request trace runs
  through the continuous-batching scheduler (serving/scheduler.py) and
  the report gains a ``ragged_trace`` section with per-request TPOT,
  slot occupancy, decode-dispatch count and the per-slot attend-block
  work counters (DESIGN.md §6) — plus a ``router_chaos`` section: the
  multi-replica router (serving/router.py) driven through every fault
  kind (serving/faults.py), emitting deterministic detection-latency /
  recovery-steps / availability / oracle-exactness columns that
  scripts/check_bench.py gates exactly (DESIGN.md §9) — plus an
  ``sdc_sweep`` section: the single-bit silent-data-corruption
  coverage matrix (serving/sweep.py — detection coverage, latency,
  oracle exactness per (fault kind × bit), and the fault-free
  false-positive / probe-overhead control row).

Besides the CSV rows, the run emits a machine-readable ``BENCH_tpot.json``
(``--out``) carrying TPOT per (arch × variant × cache_len bucket) plus
the MODELED per-step ICI weight-gather bytes
(``repro.core.autotune.weight_gather_bytes_per_step``) — which must read
0 on the prepacked Pallas path — so the perf trajectory is tracked
across PRs.  ``--smoke`` runs a tiny single-arch sweep for CI (Pallas in
interpret mode on CPU).
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.configs import get_config, reduced
from repro.core import tracecount
from repro.core.autotune import (ffn_cluster_reduce_bytes_per_step,
                                 ffn_psum_bytes_per_step,
                                 head_hbm_logits_bytes_per_step,
                                 head_ici_bytes_per_step,
                                 weight_gather_bytes_per_step)
from repro.launch.mesh import make_test_mesh
from repro.launch.serve import build_engine
from repro.models import layout_for, single_device_ctx, unwrap_local
from repro.models.transformer import init_device_major
from repro.serving.engine import (ServeConfig, decode_block,
                                  init_decode_state)
from repro.serving.sampling import CAND_K


def _unfused_decode_us(cfg, max_seq: int, batch: int, iters: int = 15):
    """(unfused_us, fused_us) per-token times on one device.

    Unfused: every layer is its own jit call (plus embed and
    head+sample), i.e. L+2 real dispatches of real work per token — the
    launch-bound baseline the paper compares against, not a stand-in.
    Fused: the identical work as ONE ``decode_step`` dispatch.  Each
    dispatch is a trivial 1×1 ``shard_map`` so the dataflow's axis names
    exist (all collectives degenerate to no-ops at size 1).
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    ctx = single_device_ctx()
    lay = layout_for(cfg, 1)
    params_dm = init_device_major(cfg, lay, jax.random.PRNGKey(0))
    params = unwrap_local(params_dm)
    scfg = ServeConfig(max_seq=max_seq, batch_local=batch)
    state = init_decode_state(cfg, scfg, ctx)
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period

    import math
    from repro.models.layers import (EmbedParams, embed_lookup,
                                     lm_head_logits, rms_norm, softcap)
    from repro.serving.engine import greedy_sample

    def _sm(fn, n_args):
        return jax.jit(shard_map(fn, mesh=mesh1, in_specs=(P(),) * n_args,
                                 out_specs=P(), check_vma=False))

    embed_step = _sm(lambda tok: embed_lookup(
        ctx, EmbedParams(params["embed"]), tok)
        * (jnp.asarray(math.sqrt(cfg.d_model), jnp.bfloat16)
           if cfg.tie_embeddings else 1), 1)

    def _mk_group(kind):
        # one dispatch = slice group gi, run the block, write the cache back
        def f(blks, gi, x, caches, cl):
            blk = jax.tree.map(lambda l: l[gi], blks)
            cache_i = jax.tree.map(lambda l: l[gi], caches)
            x, nc = decode_block(ctx, cfg, kind, blk, x, cache_i, cl, scfg)
            new = jax.tree.map(
                lambda full, upd: full.at[gi].set(upd.astype(full.dtype)),
                caches, nc)
            return x, new
        return _sm(f, 5)

    def _mk_tail(kind):
        def f(blk, x, cache, cl):
            return decode_block(ctx, cfg, kind, blk, x, cache, cl, scfg)
        return _sm(f, 4)

    _group = {k: _mk_group(k) for k in set(kinds)}
    _tail = {k: _mk_tail(k) for k in set(kinds[n_groups * period:])} \
        if cfg.n_layers > n_groups * period else {}

    def _head(x):
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = lm_head_logits(ctx, table, x)
        if cfg.logit_softcap:
            logits = softcap(logits, cfg.logit_softcap)
        return greedy_sample(ctx, logits)

    head_step = _sm(_head, 1)

    def one_token(tok, state):
        cache_len = state["cache_lens"]
        x = embed_step(tok)
        for gi in range(n_groups):
            for p_i in range(period):
                x, state["layers"][p_i] = _group[kinds[p_i]](
                    params["blocks"][p_i], jnp.int32(gi), x,
                    state["layers"][p_i], cache_len)
        for t_i, blk in enumerate(params["tail"]):
            x, state["tail"][t_i] = _tail[kinds[n_groups * period + t_i]](
                blk, x, state["tail"][t_i], cache_len)
        return head_step(x), state

    tok = jnp.zeros((batch,), jnp.int32)
    st = {**state, "layers": list(state["layers"]),
          "tail": list(state["tail"])}
    t_unfused = time_fn(lambda: one_token(tok, st)[0], iters=iters)

    # apples-to-apples fused reference: the SAME single-device work as ONE
    # dispatch (full decode_step under a single jit)
    from repro.serving.engine import decode_step
    fused = _sm(lambda p, s, t: decode_step(ctx, cfg, scfg, p, s, t), 3)
    t_fused = time_fn(lambda: fused(params_dm, state, tok), iters=iters)
    return t_unfused, t_fused


# Per-slot sampling-param overrides for the sampling-variant TPOT
# sweep: the decode step's signature is sampling-independent (the
# params are state leaves — serving/sampling.py), so each variant is
# the SAME jitted program timed under different leaf values.  The
# greedy row must cost the same as the other two: any spread beyond
# noise means sampling left the fused tail.
_SAMPLING_VARIANTS = (
    ("greedy", {}),                                   # default leaves
    ("topk8", {"temp": 0.7, "topk": 8}),
    ("topp0.9", {"temp": 0.7, "topp": 0.9}),
)


_VARIANTS = (
    # (label, build_engine kwargs)
    ("xla", dict(backend="xla")),
    ("pallas", dict(backend="pallas", prepack="off")),      # PR-1 path
    ("pallas_prepack", dict(backend="pallas", prepack="on")),
    # forced cluster=2: the configuration where the PR-1 path actually
    # pays per-step weight-gather ICI (nonzero modeled column) and the
    # prepacked path reads 0
    ("pallas_c2", dict(backend="pallas", prepack="off", cluster=2)),
    ("pallas_prepack_c2", dict(backend="pallas", prepack="on", cluster=2)),
)


def _bench_variant(cfg, arch, label, kw, *, max_seq, batch, prompt_len,
                   cache_lens, iters, interpret, rows):
    mesh = make_test_mesh()
    params, pf, dec, state, lay, scfg = build_engine(
        cfg, mesh, max_seq=max_seq, batch_global=batch,
        interpret=interpret and kw.get("backend") != "xla", **kw)
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(key, (batch, cfg.frontend.num_positions,
                                     cfg.frontend.feature_dim))
    p_serve = params["serve"]
    # Trace-time structure counters — measured BEFORE the first dispatch
    # (a cached trace would skip the counting hooks): exact per-step
    # pallas_call launch and activation-psum counts of this variant.
    tok0 = jnp.zeros((batch,), jnp.int32)
    with tracecount.counting() as c:
        jax.eval_shape(dec, p_serve, state, tok0)
    launches = int(c.get("pallas_kernel", 0))
    psums = int(c.get("psum_model", 0))
    nxt, st = pf(params["train"], state, prompts, fe)
    t = time_fn(lambda: dec(p_serve, st, nxt), iters=iters)
    samp_us = {}
    for s_label, over in _SAMPLING_VARIANTS:
        st_s = dict(st)
        st_s["sampling"] = {
            name: (jnp.full_like(leaf, over[name]) if name in over
                   else leaf)
            for name, leaf in st["sampling"].items()}
        t_s = time_fn(lambda: dec(p_serve, st_s, nxt), iters=iters)
        samp_us[s_label] = t_s
        rows.append(row(f"tpot_sampling_{s_label}_{label}_{arch}", t_s,
                        f"k={CAND_K}," + (",".join(
                            f"{n}={v}" for n, v in over.items()) or
                            "greedy_defaults")))
    byte_kw = dict(model_axis=mesh.shape["model"], batch=scfg.batch_local,
                   backend=scfg.backend, prepack=scfg.prepack)
    gather_bytes = weight_gather_bytes_per_step(
        cfg, model_axis=mesh.shape["model"], cluster_size=lay.cluster,
        backend=scfg.backend, prepack=scfg.prepack)
    ffn_psum_bytes = ffn_psum_bytes_per_step(cfg, **byte_kw)
    ffn_reduce_bytes = ffn_cluster_reduce_bytes_per_step(cfg, **byte_kw)
    head_ici = head_ici_bytes_per_step(cfg, **byte_kw)
    head_hbm = head_hbm_logits_bytes_per_step(cfg, **byte_kw)
    rows.append(row(f"tpot_{label}_{arch}", t,
                    f"cluster={lay.cluster},prepack={scfg.prepack},"
                    f"ici_weight_gather_bytes={gather_bytes:.0f},"
                    f"ffn_psum_bytes={ffn_psum_bytes:.0f},"
                    f"head_hbm_logits_bytes={head_hbm:.0f},"
                    f"pallas_launches={launches},psum_model={psums}"))
    sweep = {}
    for L in cache_lens:
        pr = jax.random.randint(key, (batch, L), 0, cfg.vocab_size)
        nxt_l, st_l = pf(params["train"], state, pr, fe)
        t_l = time_fn(lambda: dec(p_serve, st_l, nxt_l), iters=iters)
        sweep[L] = t_l
        rows.append(row(f"tpot_cachelen_{label}_{arch}_{L}", t_l,
                        f"live={L}/{max_seq}"))
    return {
        "tpot_us": t,
        "cachelen_us": {str(L): sweep[L] for L in cache_lens},
        "cluster": lay.cluster,
        "backend": scfg.backend,
        "prepack": scfg.prepack,
        "ici_weight_gather_bytes_per_step": gather_bytes,
        # full-block fusion evidence (DESIGN.md §7): per-layer FFN psum
        # bytes eliminated by the fused ClusterReduce, its replacement's
        # tree-traffic, and the measured trace-time launch/psum counts
        "ffn_psum_ici_bytes_per_step": ffn_psum_bytes,
        "ffn_fused_reduce_ici_bytes_per_step": ffn_reduce_bytes,
        # LM-head/sampling-tail evidence (DESIGN.md §7 L5): the modeled
        # per-chip HBM bytes of the [B, V_loc] logits tensor the fused
        # head deletes (0 on the prepacked Pallas path) and the (value,
        # index) pair tree-reduce ICI traffic both tails pay
        "head_hbm_logits_bytes_per_step": head_hbm,
        "head_ici_bytes_per_step": head_ici,
        # candidate width of the fused tail's streaming top-k — gated
        # exactly (a width change moves the ICI model AND the sampling
        # exactness envelope, so it must never drift silently)
        "head_sample_k": CAND_K,
        # same jitted step under the three sampling-param settings:
        # wall-noise on CPU, but the spread is the evidence sampling
        # stays in-state (no per-variant retrace)
        "sampling_tpot_us": samp_us,
        "pallas_launches_per_step": launches,
        "psum_model_per_step": psums,
    }


def _bench_ragged_trace(arch, *, n_slots=3, prompt_cap=12, max_new_cap=10,
                        n_requests=8, backend="xla", interpret=False,
                        rows=None, seed=0):
    """Random arrival trace through the slot scheduler: per-request TPOT
    (wall time from admission to finish over tokens emitted) and slot
    occupancy.  CPU walls are relative indicators; the occupancy /
    dispatch-count / work-counter columns are exact."""
    import time as _time

    from repro.launch.mesh import make_test_mesh as _mk
    from repro.launch.serve import EngineOptions, build_engine_full
    from repro.serving.scheduler import Request, SlotScheduler

    cfg = reduced(get_config(arch))
    mesh = _mk(data=1, model=8)          # scheduler batch rides unsharded
    eng = build_engine_full(
        cfg, mesh, max_seq=prompt_cap + max_new_cap + 8,
        batch_global=n_slots,
        options=EngineOptions(
            backend=backend, interpret=interpret, track_work=True,
            plan_seq_len=prompt_cap + max_new_cap))  # bucket on max LIVE len
    sched = SlotScheduler(eng, prompt_cap=prompt_cap)
    rng = np.random.default_rng(seed)
    trace = []
    for rid in range(n_requests):
        arrival = int(rng.integers(0, max(1, n_requests // 2)))
        plen = int(rng.integers(2, prompt_cap + 1))
        n_new = int(rng.integers(2, max_new_cap + 1))
        trace.append((arrival, Request(
            rid, [int(t) for t in rng.integers(0, cfg.vocab_size, plen)],
            n_new)))
    pending = sorted(trace, key=lambda ar: ar[0])
    i, tick_wall = 0, []
    while (i < len(pending) or not sched.idle()) and sched.tick < 10_000:
        while i < len(pending) and pending[i][0] <= sched.tick:
            sched.submit(pending[i][1])
            i += 1
        t0 = _time.perf_counter()
        sched.step()
        tick_wall.append(_time.perf_counter() - t0)
    assert sched.idle(), "ragged trace did not drain"
    per_request = {}
    for rid, res in sched.results.items():
        span_us = sum(tick_wall[res.admit_tick:res.finish_tick + 1]) * 1e6
        per_request[str(rid)] = {
            "tpot_us": span_us / max(1, len(res.tokens)),
            "n_tokens": len(res.tokens),
            "slot": res.slot,
            "admit_tick": res.admit_tick,
            "finish_tick": res.finish_tick,
        }
    occ = float(np.mean(sched.occupancy)) if sched.occupancy else 0.0
    mean_tpot = float(np.mean([r["tpot_us"] for r in per_request.values()]))
    if rows is not None:
        rows.append(row(f"tpot_ragged_trace_{arch}", mean_tpot,
                        f"occupancy={occ:.2f},ticks={sched.tick},"
                        f"dispatches={sched.decode_calls}"))
    return {
        "arch": arch,
        "backend": eng.scfg.backend,
        "n_slots": n_slots,
        "n_requests": n_requests,
        "ticks": sched.tick,
        "decode_dispatches": sched.decode_calls,
        "mean_slot_occupancy": occ,
        "mean_tpot_us": mean_tpot,
        "per_request": per_request,
        "work_blocks_per_slot": [int(w) for w in sched.work_blocks()],
        "note": "wall-times are relative on CPU; occupancy, dispatch and "
                "work-block columns are exact",
    }


def _bench_router_chaos(arch, *, n_replicas=2, prompt_cap=8, max_new_cap=8,
                        n_requests=6, fault_step=2, rows=None, seed=0):
    """Fleet chaos sweep: a fixed arrival trace through the multi-replica
    router once fault-free (the oracle), then once per fault kind with a
    deterministic mid-trace injection (serving/faults.py).  Every
    emitted column is TICK ARITHMETIC — detection latency, recovery
    steps, availability and oracle-exactness are identical on every
    machine, so check_bench.py gates them exactly like the launch/psum
    counters."""
    from repro.launch.mesh import make_test_mesh as _mk
    from repro.launch.serve import EngineOptions, build_replicas
    from repro.serving.faults import FAULT_KINDS, FaultInjector, FaultSpec
    from repro.serving.router import Router
    from repro.serving.scheduler import Request

    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=None)
    mesh = _mk(data=1, model=1)
    engines = build_replicas(cfg, mesh, n_replicas=n_replicas,
                             max_seq=prompt_cap + max_new_cap + 8,
                             batch_global=2,
                             options=EngineOptions(
                                 backend="xla", check_finite=True,
                                 kv_fingerprint=True, shadow_head=True))
    rng = np.random.default_rng(seed)
    trace = []
    for rid in range(n_requests):
        plen = int(rng.integers(2, prompt_cap - 1))
        trace.append((int(rng.integers(0, 4)), Request(
            rid, [int(t) for t in rng.integers(1, cfg.vocab_size, plen)],
            int(rng.integers(3, max_new_cap - 1)))))

    def _run(injectors=None):
        r = Router(engines, prompt_cap=prompt_cap, max_new_cap=max_new_cap,
                   injectors=injectors)
        journal = r.run([(t, Request(q.rid, q.prompt, q.max_new))
                         for t, q in trace])
        return r, {rid: list(e.tokens) for rid, e in journal.items()}

    _, oracle = _run()
    faults = {}
    for kind in FAULT_KINDS:
        inj = FaultInjector(
            [FaultSpec(kind, step=fault_step, target=0, replica=0)])
        router, toks = _run({0: inj})
        lat = router.detection_latency(inj)
        exact = sum(toks[r] == oracle[r] for r in oracle)
        cell = {
            "detect_steps": max(lat) if lat else -1,
            "recovery_steps": router.recovery_steps(),
            "availability_pct": round(100.0 * router.availability(), 2),
            "oracle_exact_pct": round(100.0 * exact / len(oracle), 2),
            "ticks": router.tick,
        }
        faults[kind] = cell
        if rows is not None:
            rows.append(row(
                f"router_chaos_{kind}_{arch}", float(cell["ticks"]),
                f"detect_steps={cell['detect_steps']},"
                f"recovery_steps={cell['recovery_steps']},"
                f"availability={cell['availability_pct']:.1f}%,"
                f"oracle_exact={cell['oracle_exact_pct']:.0f}%"))
    return {
        "arch": arch,
        "n_replicas": n_replicas,
        "n_requests": n_requests,
        "fault_step": fault_step,
        "faults": faults,
        "note": "all columns are deterministic tick arithmetic — gated "
                "exactly by scripts/check_bench.py (ROUTER_GATED_COLUMNS)",
    }


def _bench_sdc_sweep(arch, *, n_replicas=2, prompt_cap=8, max_new=6,
                     n_requests=3, bits=(0, 7, 14), fault_step=2,
                     rows=None, seed=0):
    """Silent-data-corruption coverage sweep: single-bit KV and weight
    flips at representative bf16 positions (mantissa 0, exponent 7/14)
    through the systematic FaultSweep grid, plus the fault-free control
    row (zero false positives, streams byte-equal to the probes-off
    oracle, per-tick probe bytes).  Every coverage/latency column is
    deterministic tick arithmetic; the probe-bytes column is exact shape
    arithmetic — all gated by check_bench.py (SDC_GATED_COLUMNS).  The
    full 16-bit grid runs in the nightly sweep (tests + CI); the bench
    keeps the representative sub-grid so --trace stays fast."""
    from repro.launch.mesh import make_test_mesh as _mk
    from repro.launch.serve import EngineOptions, build_replicas
    from repro.serving.faults import FaultSweep
    from repro.serving.integrity import IntegrityConfig
    from repro.serving.sweep import run_sdc_sweep

    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=None)
    mesh = _mk(data=1, model=1)
    engines = build_replicas(cfg, mesh, n_replicas=n_replicas,
                             max_seq=prompt_cap + max_new + 8,
                             batch_global=2,
                             options=EngineOptions(
                                 backend="xla", check_finite=True,
                                 kv_fingerprint=True, shadow_head=True))
    rng = np.random.default_rng(seed)
    prompts = [[int(t) for t in rng.integers(1, cfg.vocab_size,
                                             int(rng.integers(2, 6)))]
               for _ in range(n_requests)]
    cells = run_sdc_sweep(
        engines, prompts=prompts, max_new=max_new, prompt_cap=prompt_cap,
        sweep=FaultSweep(bits=tuple(bits), steps=(fault_step,),
                         targets=(0,), seed=seed),
        icfg=IntegrityConfig(weight_leaves_per_tick=4))
    if rows is not None:
        ff = cells["fault_free"]
        rows.append(row(
            f"sdc_sweep_fault_free_{arch}", ff["probe_bytes_per_tick"],
            f"false_positives={ff['false_positive_signals']:.0f},"
            f"streams_match={ff['streams_match']:.0f}"))
        for key in sorted(k for k in cells if k != "fault_free"):
            c = cells[key]
            rows.append(row(
                f"sdc_sweep_{key}_{arch}", float(c["detect_steps"]),
                f"detected={c['detected_pct']:.0f}%,"
                f"oracle_exact={c['oracle_exact_pct']:.0f}%"))
    return {
        "arch": arch,
        "n_replicas": n_replicas,
        "n_requests": n_requests,
        "fault_step": fault_step,
        "bits": list(bits),
        "cells": cells,
        "note": "coverage/latency columns are deterministic tick "
                "arithmetic; probe_bytes_per_tick is exact shape "
                "arithmetic — gated by scripts/check_bench.py "
                "(SDC_GATED_COLUMNS)",
    }


def main(archs=("llama2-7b", "deepseek-v2-lite"), *, max_seq=256, batch=4,
         prompt_len=64, cache_lens=(16, 64, 192), iters=15,
         out_path="BENCH_tpot.json", fusion_baseline=True,
         ragged_trace=False):
    interpret = jax.default_backend() == "cpu"
    rows = []
    report = {
        "meta": {"device_backend": jax.default_backend(),
                 "pallas_interpret": interpret, "max_seq": max_seq,
                 "batch": batch, "iters": iters,
                 "note": "CPU wall-times are relative indicators "
                         "(interpret-mode Pallas); the modeled ICI bytes "
                         "column is exact"},
        "archs": {},
    }
    for arch in archs:
        cfg = reduced(get_config(arch))
        entry = {"variants": {}}
        for label, kw in _VARIANTS:
            entry["variants"][label] = _bench_variant(
                cfg, arch, label, kw, max_seq=max_seq, batch=batch,
                prompt_len=prompt_len, cache_lens=cache_lens, iters=iters,
                interpret=interpret, rows=rows)
        pp = entry["variants"]["pallas_prepack"]["cachelen_us"]
        p1 = entry["variants"]["pallas"]["cachelen_us"]
        entry["prepack_speedup_by_bucket"] = {
            k: p1[k] / max(pp[k], 1e-9) for k in pp}
        # Wall-clock comparison is meaningful only when the Pallas kernels
        # actually compile (TPU); interpret-mode CPU walls are evaluation
        # noise — there the exact modeled ICI column carries the claim.
        entry["prepack_le_pallas_all_buckets"] = (
            all(pp[k] <= p1[k] for k in pp) if not interpret else None)
        # (no assert on the modeled prepack bytes being 0 — that is true
        # by construction of the model; the MEASURED guarantee of zero
        # per-step weight movement lives in tests/test_prepack.py's
        # trace-time counters)

        if fusion_baseline:
            # REAL per-layer dispatch baseline: L+2 jit calls of actual
            # work, vs the same single-device work fused into one dispatch.
            t_unfused, t_fused1 = _unfused_decode_us(
                cfg, max_seq=max_seq, batch=batch, iters=iters)
            rows.append(row(f"tpot_fused1_{arch}", t_fused1,
                            "n_dispatches=1"))
            rows.append(row(
                f"tpot_unfused_{arch}", t_unfused,
                f"n_dispatches={cfg.n_layers + 2},"
                f"fusion_speedup={t_unfused / max(t_fused1, 1e-9):.2f}x"))
            entry["fusion"] = {"tpot_fused1_us": t_fused1,
                               "tpot_unfused_us": t_unfused}
        report["archs"][arch] = entry
    if ragged_trace:
        # the scheduler requires a dense-FFN decoder-only arch; fall back
        # to llama2 when the benched arch isn't one (e.g. MoE deepseek)
        trace_arch = archs[0]
        tc = reduced(get_config(trace_arch))
        if tc.moe is not None or tc.frontend is not None \
                or tc.encoder is not None:
            trace_arch = "llama2-7b"
        report["ragged_trace"] = _bench_ragged_trace(trace_arch, rows=rows)
        # fleet chaos sweep: deterministic detection/recovery/availability
        # columns per fault kind, gated by scripts/check_bench.py
        # (ROUTER_GATED_COLUMNS) against the committed baseline
        report["router_chaos"] = _bench_router_chaos(trace_arch, rows=rows)
        # SDC coverage sweep: single-bit flip detection/latency/false-
        # positive matrix (serving/sweep.py), gated by check_bench.py
        # (SDC_GATED_COLUMNS) against the committed baseline
        report["sdc_sweep"] = _bench_sdc_sweep(trace_arch, rows=rows)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"# wrote {out_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+",
                    default=["llama2-7b", "deepseek-v2-lite"])
    ap.add_argument("--out", default="BENCH_tpot.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny single-arch sweep for CI (interpret mode)")
    ap.add_argument("--trace", action="store_true",
                    help="add the ragged-arrival scheduler trace section")
    args = ap.parse_args()
    if args.smoke:
        main(archs=args.archs[:1], max_seq=64, prompt_len=16,
             cache_lens=(8, 48), iters=3, out_path=args.out,
             fusion_baseline=False, ragged_trace=args.trace)
    else:
        main(archs=tuple(args.archs), out_path=args.out,
             ragged_trace=args.trace)
