"""Paper Fig. 9/18 analogue: core QKV-Projection + Attention +
Output-Projection module — ClusterFusion fused dataflow (one computation)
vs the block-isolated baseline (three separate kernel launches with the
intermediates materialized between them).
"""
import math

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from benchmarks.common import row, time_fn
from repro.core import dataflow as df
from repro.core import primitives as prim


def main(seqs=(1024, 4096, 16384)):
    n_dev = min(8, jax.device_count())
    H, N = (2, 4) if n_dev == 8 else (1, 1)
    mesh = jax.make_mesh((n_dev,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    heads_ax = prim.SubAxis("model", H, minor_size=N)
    clus_ax = prim.SubAxis("model", N, minor_size=1)
    B, D, hd = 1, 512, 64
    n_heads, n_kv = 8, 8
    q_loc, kv_loc = n_heads // H, n_kv // H
    key = jax.random.PRNGKey(0)
    rows = []
    for S in seqs:
        s_blk = S // N
        ks = jax.random.split(key, 8)
        x = jax.random.normal(ks[0], (B, D), jnp.float32) * 0.3
        wq = jax.random.normal(ks[1], (n_dev, D, q_loc, hd // N)) * 0.05
        wk = jax.random.normal(ks[2], (n_dev, D, kv_loc, hd // N)) * 0.05
        wv = jax.random.normal(ks[3], (n_dev, D, kv_loc, hd // N)) * 0.05
        wo = jax.random.normal(ks[4], (n_dev, q_loc * hd, D // N)) * 0.05
        kc = jax.random.normal(ks[5], (n_dev, s_blk, B * kv_loc, hd)) * 0.3
        vc = jax.random.normal(ks[6], (n_dev, s_blk, B * kv_loc, hd)) * 0.3
        pos = jnp.tile(jnp.arange(s_blk, dtype=jnp.int32)[None], (n_dev, 1))
        clen = jnp.int32(S - 2)
        spec = df.ClusterSpec(heads=heads_ax, cluster=clus_ax)

        def fused(x_, wq_, wk_, wv_, wo_, kc_, vc_, pos_):
            w = df.SplitTokenWeights(wq=wq_[0], wk=wk_[0], wv=wv_[0],
                                     wo=wo_[0])
            cache = df.KVBlock(k=kc_[0], v=vc_[0], pos=pos_[0])
            o_seg, _ = df.split_token_attention(spec, x_, w, cache, clen)
            return prim.cluster_gather_tiled(o_seg, clus_ax, axis=1)[None]

        fused_j = jax.jit(shard_map(
            fused, mesh=mesh,
            in_specs=(P(),) + (P("model"),) * 7,
            out_specs=P("model"), check_vma=False))

        # block-isolated baseline: 3 separate jitted "kernels" with HBM
        # round-trips between them (the paper's Fig. 3 dataflow)
        wq_f = jax.random.normal(ks[1], (D, n_heads, hd)) * 0.05
        wk_f = jax.random.normal(ks[2], (D, n_kv, hd)) * 0.05
        wv_f = jax.random.normal(ks[3], (D, n_kv, hd)) * 0.05
        wo_f = jax.random.normal(ks[4], (n_heads * hd, D)) * 0.05
        kc_f = jax.random.normal(ks[5], (S, n_kv, hd)) * 0.3
        vc_f = jax.random.normal(ks[6], (S, n_kv, hd)) * 0.3

        @jax.jit
        def k_qkv(x_):
            return (jnp.einsum("bd,dqh->bqh", x_, wq_f),
                    jnp.einsum("bd,dkh->bkh", x_, wk_f),
                    jnp.einsum("bd,dkh->bkh", x_, wv_f))

        @jax.jit
        def k_attn(q):
            s = jnp.einsum("bkh,skh->bks", q.reshape(B, n_heads, hd),
                           kc_f) / math.sqrt(hd)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bks,skh->bkh", p, vc_f)

        @jax.jit
        def k_out(a):
            return a.reshape(B, n_heads * hd) @ wo_f

        def baseline(x_):
            q, k, v = k_qkv(x_)
            a = k_attn(q)
            return k_out(a)

        t_f = time_fn(fused_j, x, wq, wk, wv, wo, kc, vc, pos)
        t_b = time_fn(baseline, x)
        rows.append(row(f"core_module_fused_S{S}", t_f,
                        f"traffic_B={df.traffic_split_token(hd, D, N):.0f}"))
        rows.append(row(f"core_module_baseline_S{S}", t_b,
                        f"speedup={t_b / max(t_f, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    main()
