"""Benchmark utilities: timing + the 8-host-device subprocess pattern.

All benchmarks print ``name,us_per_call,derived`` CSV rows (one per paper
table/figure cell).  CPU wall-times are *relative* indicators (the roofline
analysis in EXPERIMENTS.md carries the absolute performance story); the
derived column carries the analytic quantity the paper's table reports
(traffic bytes, speedup ratio, …).
"""
import time

import jax
import numpy as np


def time_fn(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in µs (blocks on results)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def row(name: str, us: float, derived) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line
