"""Paper Fig. 11 analogue: core-module latency vs cluster size N (and the
analytical v5e latency model that the autotuner uses).

The paper finds N=4 optimal for 32–64 heads on H100; our analytical model
reproduces the same *shape* (optimum at small-moderate N, degradation at
16) with ICI constants — see EXPERIMENTS.md §Paper-validation.
"""
import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from benchmarks.common import row, time_fn
from repro.configs import get_config
from repro.core import dataflow as df
from repro.core import primitives as prim
from repro.core.autotune import sweep


def main():
    n_dev = min(8, jax.device_count())
    rows = []
    # measured: tiny decode attention at N ∈ {1,2,4,8} on 8 host devices
    B, D, hd, n_heads = 1, 256, 64, 8
    S = 8192
    key = jax.random.PRNGKey(0)
    for N in (1, 2, 4, 8):
        if N > n_dev:
            continue
        H = n_dev // N
        q_loc = n_heads // H
        heads_ax = prim.SubAxis("model", H, minor_size=N)
        clus_ax = prim.SubAxis("model", N, minor_size=1)
        mesh = jax.make_mesh((n_dev,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        ks = jax.random.split(key, 8)
        s_blk = S // N
        x = jax.random.normal(ks[0], (B, D)) * 0.3
        wq = jax.random.normal(ks[1], (n_dev, D, q_loc, hd // N)) * 0.05
        wk = jax.random.normal(ks[2], (n_dev, D, q_loc, hd // N)) * 0.05
        wv = jax.random.normal(ks[3], (n_dev, D, q_loc, hd // N)) * 0.05
        wo = jax.random.normal(ks[4], (n_dev, q_loc * hd, D // N)) * 0.05
        kc = jax.random.normal(ks[5], (n_dev, s_blk, B * q_loc, hd)) * 0.3
        vc = jax.random.normal(ks[6], (n_dev, s_blk, B * q_loc, hd)) * 0.3
        pos = jnp.tile(jnp.arange(s_blk, dtype=jnp.int32)[None], (n_dev, 1))
        spec = df.ClusterSpec(heads=heads_ax, cluster=clus_ax)

        def fn(x_, wq_, wk_, wv_, wo_, kc_, vc_, pos_):
            w = df.SplitTokenWeights(wq=wq_[0], wk=wk_[0], wv=wv_[0],
                                     wo=wo_[0])
            cache = df.KVBlock(k=kc_[0], v=vc_[0], pos=pos_[0])
            o_seg, _ = df.split_token_attention(spec, x_, w, cache,
                                                jnp.int32(S - 2))
            return prim.cluster_gather_tiled(o_seg, clus_ax, axis=1)[None]

        j = jax.jit(shard_map(fn, mesh=mesh,
                              in_specs=(P(),) + (P("model"),) * 7,
                              out_specs=P("model"), check_vma=False))
        t = time_fn(j, x, wq, wk, wv, wo, kc, vc, pos)
        tr = df.traffic_split_token(hd, D, N)
        rows.append(row(f"cluster_size_N{N}_S{S}", t, f"traffic_B={tr:.0f}"))

    # analytic sweep at production scale for two real archs (Fig. 11 shape)
    for arch in ("llama2-7b", "qwen2-72b"):
        cfg = get_config(arch)
        for pt in sweep(cfg, seq_len=16384, batch=1, model_axis=16):
            if pt.dataflow != "split_token":
                continue
            rows.append(row(
                f"analytic_{arch}_N{pt.cluster_size}",
                pt.est_seconds * 1e6,
                f"ici_s={pt.terms['ici']:.2e}"))
    return rows


if __name__ == "__main__":
    main()
