"""Roofline aggregation: dryrun JSON → the EXPERIMENTS.md §Roofline table.

Implements the two-point scan extrapolation: XLA's ``cost_analysis`` counts
a ``while`` (scan) body ONCE, so a full-model compile undercounts FLOPs by
~n_groups×.  We therefore lower reduced-depth variants (1 and 2 layer
groups), solve  cost = E + G·n_groups  for the embed/head term E and the
per-group term G, and report  E + G·n_groups_full  — all derived from
compiled artifacts, no analytic FLOP counting.
"""
import dataclasses
import json
from typing import Dict, Optional

from repro.configs import get_config
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS, run_cell


def scan_extrapolated_cell(arch: str, shape_name: str, *,
                           multi_pod: bool = False,
                           tcfg_kw: Optional[dict] = None) -> Dict:
    """Two-point extrapolation of per-device flops/bytes/collective bytes."""
    cfg = get_config(arch)
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    n_tail = cfg.n_layers - n_groups * period

    def with_layers(n_layers):
        return dataclasses.replace(cfg, n_layers=n_layers)

    # monkey-patch the registry entry for the reduced-depth lowers
    from repro.configs.base import _REGISTRY
    orig = _REGISTRY[arch]
    results = {}
    try:
        for tag, nl in (("g1", period + n_tail), ("g2", 2 * period + n_tail)):
            _REGISTRY[arch] = lambda nl=nl: with_layers(nl)
            results[tag] = run_cell(arch, shape_name, multi_pod=multi_pod,
                                    verbose=False, tcfg_kw=tcfg_kw)
    finally:
        _REGISTRY[arch] = orig
    full = run_cell(arch, shape_name, multi_pod=multi_pod, verbose=False,
                    tcfg_kw=tcfg_kw)
    if results["g1"].get("skipped") or "error" in results["g1"]:
        return full

    out = dict(full)
    for key in ("flops_per_device", "bytes_per_device",
                "collective_bytes_per_device"):
        g = results["g2"][key] - results["g1"][key]     # per-group cost
        e = results["g1"][key] - g                      # embed/head cost
        out[key + "_extrap"] = max(e + g * n_groups, full[key])
    out["t_compute_s"] = out.get("flops_per_device_extrap",
                                 out["flops_per_device"]) / PEAK_FLOPS
    out["t_memory_s"] = out.get("bytes_per_device_extrap",
                                out["bytes_per_device"]) / HBM_BW
    out["t_collective_s"] = out.get(
        "collective_bytes_per_device_extrap",
        out["collective_bytes_per_device"]) / ICI_BW
    out["dominant"] = max(
        (("compute", out["t_compute_s"]), ("memory", out["t_memory_s"]),
         ("collective", out["t_collective_s"])), key=lambda kv: kv[1])[0]
    n_dev = out["n_devices"]
    out["useful_flops_ratio"] = out["model_flops_total"] / max(
        out.get("flops_per_device_extrap", out["flops_per_device"]) * n_dev,
        1.0)
    # roofline fraction: how close the dominant-term-bound step time is to
    # the pure-compute bound
    t_bound = max(out["t_compute_s"], out["t_memory_s"],
                  out["t_collective_s"])
    out["roofline_fraction"] = out["t_compute_s"] / max(t_bound, 1e-30)
    return out


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | heads×cluster | t_comp (ms) | t_mem (ms)"
           " | t_coll (ms) | dominant | useful FLOPs | roofline frac |"
           " peak GiB/dev |\n|" + "---|" * 11)
    lines = [hdr]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| SKIP ({r['reason'][:40]}…) | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| ERROR | — | — | — |")
            continue
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} "
            f"| {r.get('heads_sub','?')}×{r.get('cluster','?')} "
            f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | {r['dominant']} "
            f"| {r.get('useful_flops_ratio', 0):.2f} "
            f"| {r.get('roofline_fraction', 0):.2f} "
            f"| {r.get('peak_device_bytes', 0)/2**30:.1f} |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = json.load(open(args.json))
    md = to_markdown(rows)
    if args.out:
        open(args.out, "w").write(md)
    print(md)


if __name__ == "__main__":
    main()
