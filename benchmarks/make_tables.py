"""Generate the EXPERIMENTS.md roofline/dry-run tables from dryrun JSON."""
import json
import sys


def table(rows, multi_pod):
    hdr = ("| arch | shape | heads×cluster | flops/dev | bytes/dev | "
           "coll/dev | t_comp ms | t_mem ms | t_coll ms | dominant | "
           "useful | peak GiB |\n" + "|---" * 12 + "|")
    lines = [hdr]
    for r in rows:
        if r.get("multi_pod") != multi_pod:
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | — | SKIP (long-ctx, full-attn) | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | — | ERROR | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['heads_sub']}×{r['cluster']} "
            f"| {r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} "
            f"| {r['collective_bytes_per_device']:.2e} "
            f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['peak_device_bytes']/2**30:.1f} |")
    return "\n".join(lines)


def main():
    rows = json.load(open(sys.argv[1]))
    ok = [r for r in rows if "t_compute_s" in r]
    skip = [r for r in rows if r.get("skipped")]
    err = [r for r in rows if "error" in r]
    out = []
    out.append(f"Cells: {len(rows)} total — {len(ok)} compiled, "
               f"{len(skip)} recorded skips, {len(err)} errors.\n")
    out.append("### Single-pod 16×16 (256 chips) — baseline roofline table\n")
    out.append(table(rows, False))
    out.append("\n### Multi-pod 2×16×16 (512 chips) — pod-axis shard proof\n")
    out.append(table(rows, True))
    print("\n".join(out))


if __name__ == "__main__":
    main()
