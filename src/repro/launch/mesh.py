"""Production meshes.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 4):
    """Small host-device mesh for integration tests (8 devices)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size_of(mesh) -> int:
    s = 1
    for a in dp_axes_of(mesh):
        s *= mesh.shape[a]
    return s
