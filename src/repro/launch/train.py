"""Training driver: mesh setup, init (or resume), step loop with
checkpointing, exact-resume data, and straggler monitoring.

On this CPU container it runs reduced configs end-to-end (see
examples/train_tiny_lm.py); on real hardware the same driver scales — the
mesh and specs are identical to the dry-run's.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM, frontend_embeds_at
from repro.launch.mesh import dp_axes_of, dp_size_of, make_test_mesh
from repro.launch.specs import (abstract_opt_state, ctx_for,
                                state_spec_tree, train_layout)
from repro.models.transformer import (grad_sync_tree, init_device_major,
                                      param_specs)
from repro.training.optimizer import OptConfig
from repro.training.train_step import (TrainConfig, init_train_state,
                                       make_train_step)


class StragglerMonitor:
    """Flags steps (hosts, in multi-host runs) slower than p99 × 1.5.

    On real clusters per-host step barriers are timed via
    ``jax.experimental.multihost_utils``; here we keep the per-step record
    and the detection logic (exercised in tests)."""

    def __init__(self, window: int = 100, factor: float = 1.5):
        self.times: list = []
        self.window = window
        self.factor = factor

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) < 10:
            return False
        p50 = float(np.percentile(hist[:-1], 50))
        return dt > p50 * self.factor

    def summary(self):
        h = np.asarray(self.times)
        return {"p50": float(np.percentile(h, 50)),
                "p99": float(np.percentile(h, 99)),
                "max": float(h.max()), "steps": len(h)}


def run(arch: str, *, steps: int = 20, use_reduced: bool = True,
        ckpt_dir: Optional[str] = None, mesh=None, batch_override=None,
        seq_override=None, tcfg: Optional[TrainConfig] = None,
        log_every: int = 10):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduced(cfg)
    mesh = mesh or make_test_mesh()
    ms = mesh.shape["model"]
    dp_axes = dp_axes_of(mesh)
    dp = dp_size_of(mesh)
    tcfg = tcfg or TrainConfig(opt=OptConfig(lr=1e-3))
    lay = train_layout(cfg, ms)
    ctx = ctx_for(mesh, lay)
    B = batch_override or 8
    S = seq_override or 64
    step_fn = make_train_step(
        ctx, cfg, tcfg, dp_axes, dp,
        sync_tree=None)  # sync tree built below with real params

    # ---- init (sharded via out_shardings; RNG is partition-consistent) --
    p_specs_holder = {}

    def init_all():
        params = init_device_major(cfg, lay, jax.random.PRNGKey(0))
        return params

    params_abs = jax.eval_shape(init_all)
    p_specs = param_specs(cfg, params_abs)
    sync = grad_sync_tree(cfg, lay, params_abs)
    step_fn = make_train_step(ctx, cfg, tcfg, dp_axes, dp, sync_tree=sync)
    out_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    params = jax.jit(init_all, out_shardings=out_shardings)()

    def init_state():
        rank = jax.lax.axis_index(dp_axes)
        opt, ef = init_train_state(cfg, tcfg, params_abs_local(), dp, rank)
        from repro.launch.specs import _wrap2
        return _wrap2(opt), (_wrap2(ef) if ef is not None else None)

    def params_abs_local():
        return jax.tree.map(lambda l: l[0:1] if hasattr(l, "shape") else l,
                            params)

    # opt init inside shard_map so ZeRO slices are rank-correct
    def init_state_body(params_in):
        rank = jax.lax.axis_index(dp_axes)
        opt, ef = init_train_state(cfg, tcfg, params_in, dp, rank)
        from repro.launch.specs import _wrap2
        return _wrap2(opt), (_wrap2(ef) if ef is not None else None)

    opt_abs, ef_abs = abstract_opt_state(cfg, tcfg, params_abs, dp, ms)
    o_specs = state_spec_tree(opt_abs, dp_axes)
    e_specs = state_spec_tree(ef_abs, dp_axes) if ef_abs is not None else None
    opt_state, ef_state = jax.jit(shard_map(
        init_state_body, mesh=mesh, in_specs=(p_specs,),
        out_specs=(o_specs, e_specs), check_vma=False))(params)

    # ---- wrap the step --------------------------------------------------
    from repro.launch.specs import _unwrap2, _wrap2

    def body(params, opt, ef, batch):
        opt_l = _unwrap2(opt)
        ef_l = _unwrap2(ef) if ef is not None else None
        new_p, new_o, new_e, metrics = step_fn(params, opt_l, ef_l, batch)
        metrics = {k: v[None] for k, v in metrics.items()}
        return (new_p, _wrap2(new_o),
                _wrap2(new_e) if new_e is not None else None, metrics)

    b_specs = {"tokens": P(dp_axes, None), "targets": P(dp_axes, None)}
    if cfg.frontend is not None:
        b_specs["frontend_embeds"] = P(dp_axes, None, None)
    m_spec = {k: P(None) for k in ("loss", "grad_norm", "tokens")}
    train = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(p_specs, o_specs, e_specs, b_specs),
        out_specs=(p_specs, o_specs, e_specs, m_spec), check_vma=False))

    # ---- data + checkpoint + loop ---------------------------------------
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=S,
                                  batch_per_shard=B))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        (params_h, opt_h, ef_h), extra = mgr.restore(
            (params, opt_state, ef_state))
        put = lambda tree, sp: jax.tree.map(
            lambda l, s: jax.device_put(jnp.asarray(l),
                                        NamedSharding(mesh, s)), tree, sp)
        params = put(params_h, p_specs)
        opt_state = put(opt_h, o_specs)
        ef_state = put(ef_h, e_specs) if ef_h is not None else None
        start = extra.get("step", mgr.latest_step())
        print(f"resumed from step {start}")
    mon = StragglerMonitor()
    losses = []
    for step in range(start, start + steps):
        b = data.batch_at(step)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "targets": jnp.asarray(b["targets"])}
        if cfg.frontend is not None:
            batch["frontend_embeds"] = jnp.asarray(frontend_embeds_at(
                step, 0, B, cfg.frontend.num_positions,
                cfg.frontend.feature_dim))
        t0 = time.time()
        params, opt_state, ef_state, metrics = train(
            params, opt_state, ef_state, batch)
        loss = float(metrics["loss"][0])
        slow = mon.record(time.time() - t0)
        losses.append(loss)
        if step % log_every == 0 or slow:
            print(f"step {step} loss {loss:.4f} gnorm "
                  f"{float(metrics['grad_norm'][0]):.3f}"
                  + (" [STRAGGLER]" if slow else ""))
        if mgr is not None and (step + 1) % 10 == 0:
            mgr.save(step + 1, (params, opt_state, ef_state),
                     extra={"step": step + 1})
    if mgr is not None:
        mgr.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--full", action="store_true",
                    help="full config (real hardware only)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    run(args.arch, steps=args.steps, use_reduced=not args.full,
        ckpt_dir=args.ckpt)


if __name__ == "__main__":
    main()
