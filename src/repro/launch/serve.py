"""Serving driver: prefill a batch of prompts, then decode with the
ClusterFusion dataflow.  Reduced configs run end-to-end on CPU
(examples/serve_decode.py); full configs use the same code path on real
hardware.

Two serving modes share the engine:

* :func:`generate` — lockstep batch completion (all prompts together).
* :mod:`repro.serving.scheduler` — continuous batching over the ragged
  decode engine: :func:`build_engine_full` additionally jits the
  targeted prefill-insert (``admit``) and the slot-release (``retire``)
  steps the scheduler drives (DESIGN.md §6).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core.autotune import tune_serving
from repro.launch.mesh import dp_axes_of, dp_size_of, make_test_mesh
from repro.launch.specs import _unwrap2, _wrap2, ctx_for, serving_layout
from repro.configs.base import ShapeConfig
from repro.models.transformer import init_device_major, param_specs
from repro.serving.engine import (EngineOptions, ServeConfig, decode_step,
                                  init_decode_state)
from repro.serving.prefill import prefill
from repro.serving.sampling import (SAMPLING_LEAVES, host_sampling_rows,
                                    reset_sampling_state)


class EngineHandle(NamedTuple):
    """Everything a serving loop needs.  ``params`` is the
    ``{"train", "serve"}`` layout pair; ``prefill_fn``/``decode_fn`` are
    the classic lockstep steps; ``admit_fn``/``retire_fn`` drive
    continuous batching (serving/scheduler.py):

    * ``admit_fn(params["train"], state, tokens [B, S_cap],
      lengths [B], samp=None)`` — targeted prefill-insert: slots with
      ``lengths[b] > 0`` get the padded prompt row ``b`` prefilled into
      their cache at offset 0, take their per-request sampling rows
      (``samp``: the ``state["sampling"]`` leaf layout,
      serving/sampling.py; ``None`` = greedy defaults — the legacy
      4-argument call keeps working) and sample their first token;
      every other slot's state rides through untouched.
    * ``retire_fn(state, mask [B])`` — frees the masked slots
      (``cache_lens ← −1``: no KV writes, zero attend work, sampling
      params back to the greedy defaults).
    """
    params: Any
    prefill_fn: Callable
    decode_fn: Callable
    admit_fn: Callable
    retire_fn: Callable
    state: Any
    lay: Any
    scfg: ServeConfig
    cfg: Any
    mesh: Any
    batch_global: int
    # re-materialize the serve layout from the train view — the
    # weight-SDC healing path (serving/integrity.py) calls this after a
    # fingerprint mismatch, then re-verifies before the replica rejoins
    repack_fn: Optional[Callable] = None


def build_engine(cfg, mesh, *, max_seq: int, batch_global: int,
                 fused_combine: bool = False, cluster: Optional[int] = None,
                 backend: str = "xla", interpret: bool = False,
                 block_s: Optional[int] = None, prepack="auto",
                 autotune_table: Optional[str] = None):
    """Returns (params, jitted prefill fn, jitted decode fn, state, lay,
    scfg) — the classic 6-tuple; see :func:`build_engine_full` for the
    scheduler-ready handle with the admit/retire steps."""
    h = build_engine_full(
        cfg, mesh, max_seq=max_seq, batch_global=batch_global,
        options=EngineOptions(
            fused_combine=fused_combine, cluster=cluster, backend=backend,
            interpret=interpret, block_s=block_s, prepack=prepack,
            autotune_table=autotune_table))
    return h.params, h.prefill_fn, h.decode_fn, h.state, h.lay, h.scfg


_LEGACY_KWARGS_WARNED = False


def _resolve_options(options: Optional[EngineOptions],
                     legacy: dict) -> EngineOptions:
    """Deprecation shim: fold ``build_engine_full``'s pre-options keyword
    arguments into an :class:`EngineOptions`, warning ONCE per process.
    Unknown names raise immediately (same contract as a real keyword
    mismatch) instead of silently building a differently-shaped engine."""
    if not legacy:
        return options or EngineOptions()
    unknown = set(legacy) - set(EngineOptions.__dataclass_fields__)
    if unknown:
        raise TypeError(
            f"build_engine_full() got unexpected keyword arguments "
            f"{sorted(unknown)}")
    global _LEGACY_KWARGS_WARNED
    if not _LEGACY_KWARGS_WARNED:
        _LEGACY_KWARGS_WARNED = True
        warnings.warn(
            "passing engine construction knobs as individual keyword "
            "arguments to build_engine_full is deprecated — pass "
            "options=EngineOptions(...) instead (the legacy kwargs keep "
            "working through this shim)",
            DeprecationWarning, stacklevel=3)
    return dataclasses.replace(options or EngineOptions(), **legacy)


def build_engine_full(cfg, mesh, *, max_seq: int, batch_global: int,
                      options: Optional[EngineOptions] = None,
                      **legacy_kwargs) -> EngineHandle:
    """Build every jitted serving step for (cfg × mesh).

    All construction knobs live on ONE object:
    ``options=EngineOptions(...)`` (serving/engine.py) — backend /
    interpret / block sizes / prepack / the state-leaf flags
    (track_work, check_finite, kv_fingerprint, shadow_head) /
    fused_combine / cluster / autotune_table / fuse_head /
    plan_seq_len.  The pre-options surface (the same names as
    individual keyword arguments) still works through a deprecation
    shim that warns once per process and folds them into ``options``.

    ``options.backend``: "xla" | "pallas" | "auto" — local-stage compute
    for the decode dataflow (DESIGN.md §2); ``interpret`` runs the
    Pallas kernels in interpret mode (CPU tests); ``block_s/f/v``
    override the autotuned tiles; ``autotune_table`` persists plans
    across launches.

    ``options.prepack``: "auto" | "on" | "off" — serve-layout weight
    prepack (serving/prepack.py); auto enables it whenever the Pallas
    backend is selected.  ``params`` is returned as
    ``{"train": …, "serve": …}``: the training-layout tree (prefill /
    checkpoints) and the decode-plan tree, materialized ONCE at load
    with ``out_shardings`` (identical to "train" when prepack is off).
    ``generate`` routes each to its step.

    ``options.track_work`` adds the per-slot attend-step counters
    (``state["work_blocks"]``, core/tracecount.py) the scheduler tests
    read.  ``check_finite`` adds the per-slot integrity sentinel
    (``state["nonfinite"]``) the fleet router's health probes poll
    (serving/router.py, DESIGN.md §9); off by default so the bench path
    traces an identical step.  ``kv_fingerprint`` adds the incremental
    per-slot/per-layer KV checksum leaves and ``shadow_head`` the
    committed-token (residual, head_val, token) stash the SDC monitor
    verifies on probe (serving/integrity.py) — both off by default for
    the same reason.  ``fuse_head=False`` skips the LM-head/sampling
    tail bundle on the prepacked path (ablation/parity knob: same fused
    layers, loose XLA head tail — tests prove the two sample
    token-identically).  ``plan_seq_len`` keys the autotune bucket on
    the EXPECTED MAX LIVE length rather than the allocated ``max_seq``
    — ragged serving allocates slack capacity that no slot's live span
    ever reaches, and the plan (block_s, cluster) should follow the
    live spans (DESIGN.md §6).
    """
    opt = _resolve_options(options, legacy_kwargs)
    fused_combine, cluster = opt.fused_combine, opt.cluster
    backend, interpret = opt.backend, opt.interpret
    block_s, block_f, block_v = opt.block_s, opt.block_f, opt.block_v
    prepack, autotune_table = opt.prepack, opt.autotune_table
    track_work, fuse_head = opt.track_work, opt.fuse_head
    check_finite = opt.check_finite
    kv_fingerprint, shadow_head = opt.kv_fingerprint, opt.shadow_head
    plan_seq_len = opt.plan_seq_len
    ms = mesh.shape["model"]
    dp_axes = dp_axes_of(mesh)
    dp = dp_size_of(mesh)
    shape = ShapeConfig("serve", max_seq, batch_global, "decode")
    lay = serving_layout(cfg, shape, ms)
    if cluster is not None:
        from repro.models.transformer import Layout
        lay = Layout(ms, heads_sub=ms // cluster)
    ctx = ctx_for(mesh, lay, fused_combine=fused_combine)
    b_loc = batch_global // dp if batch_global % dp == 0 else batch_global
    b_shard = batch_global % dp == 0 and batch_global >= dp
    # tune with the PER-DEVICE batch — the kernel VMEM tiles and per-chip
    # byte model see b_loc, not the global batch
    plan = tune_serving(cfg, seq_len=plan_seq_len or max_seq, batch=b_loc,
                        model_axis=ms, backend=backend, prepack=prepack,
                        table_path=autotune_table)
    scfg = ServeConfig(max_seq=max_seq, batch_local=b_loc,
                       backend=plan.backend, interpret=interpret,
                       block_s=block_s or plan.block_s,
                       block_f=block_f or plan.block_f,
                       block_v=block_v or plan.block_v,
                       prepack=plan.prepack, track_work=track_work,
                       check_finite=check_finite,
                       kv_fingerprint=kv_fingerprint,
                       shadow_head=shadow_head)
    params_abs = jax.eval_shape(
        lambda: init_device_major(cfg, lay, jax.random.PRNGKey(0)))
    p_specs = param_specs(cfg, params_abs)
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    params = jax.jit(lambda: init_device_major(cfg, lay,
                                               jax.random.PRNGKey(0)),
                     out_shardings=out_sh)()

    # Serve-layout prepack: ONE jitted re-layout at load time; the decode
    # step then performs zero weight gathers / slices (DESIGN.md §2).
    # Only the attention subtree goes through the pack — every other
    # leaf of the serve tree aliases the training tree's buffers, so the
    # extra residency is just the packed attention tensors (DESIGN.md §5).
    if scfg.prepack:
        from functools import partial as _partial
        from repro.serving.prepack import (attn_subtree, bundle_ffn,
                                           bundle_head, merge_packed,
                                           prepack_for_serving)
        pp_fn = _partial(prepack_for_serving, cfg, lay,
                         backend=scfg.backend)
        sub_abs = jax.eval_shape(pp_fn, attn_subtree(params_abs))
        sub_specs = param_specs(cfg, sub_abs)
        sub_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sub_specs)
        jit_pack = jax.jit(pp_fn, out_shardings=sub_sh)
        packed_attn = jit_pack(attn_subtree(params))
        # dense-FFN and LM-head bundles are pure aliasing (no jit, no
        # copy): the Megatron layout already IS the fused-FFN serve
        # layout, and the head bundle binds the tied-embed/lm_head table
        # + final_norm scale for the fused sampling tail
        def _bundles(tree):
            tree = bundle_ffn(cfg, tree, backend=scfg.backend)
            if fuse_head:
                tree = bundle_head(cfg, tree, backend=scfg.backend)
            return tree
        params_serve = _bundles(merge_packed(params, packed_attn))
        sv_specs = _bundles(merge_packed(p_specs, sub_specs))

        def repack_fn(train_tree):
            # the healing re-materialization runs the SAME jitted pack +
            # alias bundles the load path ran, so a healed serve tree is
            # bit-identical to the original (fingerprints re-verify)
            return _bundles(merge_packed(
                train_tree, jit_pack(attn_subtree(train_tree))))
    else:
        params_serve, sv_specs = params, p_specs

        def repack_fn(train_tree):
            return train_tree     # prepack off: serve tree IS train tree
    params = {"train": params, "serve": params_serve}

    from repro.launch.specs import state_spec_tree
    s_abs_local = jax.eval_shape(lambda: init_decode_state(cfg, scfg, ctx))
    s_specs = state_spec_tree(
        jax.tree.map(lambda l: jax.ShapeDtypeStruct((dp, ms) + tuple(l.shape),
                                                    l.dtype), s_abs_local),
        dp_axes)

    def init_body():
        return _wrap2(init_decode_state(cfg, scfg, ctx))

    state = jax.jit(shard_map(init_body, mesh=mesh, in_specs=(),
                              out_specs=s_specs, check_vma=False))()

    tok1 = P(dp_axes) if b_shard else P()

    def pf_body(params, state, tokens, fe, lengths, sampling=None):
        st = _unwrap2(state)
        nxt, new = prefill(ctx, cfg, scfg, params, st, tokens, fe,
                           lengths=lengths, sampling=sampling)
        return nxt, _wrap2(new)

    def dec_body(params, state, tokens):
        st = _unwrap2(state)
        nxt, new = decode_step(ctx, cfg, scfg, params, st, tokens)
        return nxt, _wrap2(new)

    def rt_body(state, mask):
        st = dict(_unwrap2(state))
        st["cache_lens"] = jnp.where(mask > 0, jnp.int32(-1),
                                     st["cache_lens"])
        st["sampling"] = reset_sampling_state(st["sampling"], mask > 0)
        if "nonfinite" in st:        # retired slot: clear its sentinel
            st["nonfinite"] = jnp.where(mask > 0, jnp.int32(0),
                                        st["nonfinite"])
        return _wrap2(st)

    fe_spec = P(*tok1, None, None) if cfg.frontend is not None else P()
    pf = jax.jit(shard_map(
        lambda p, s, t, fe: pf_body(p, s, t, fe, None), mesh=mesh,
        in_specs=(p_specs, s_specs, P(*tok1, None), fe_spec),
        out_specs=(tok1, s_specs), check_vma=False))
    samp_specs = {name: tok1 for name in SAMPLING_LEAVES}
    admit_jit = jax.jit(shard_map(
        lambda p, s, t, ln, sp: pf_body(p, s, t, None, ln, sp), mesh=mesh,
        in_specs=(p_specs, s_specs, P(*tok1, None), tok1, samp_specs),
        out_specs=(tok1, s_specs), check_vma=False))

    def admit(params, state, tokens, lengths, samp=None):
        # host wrapper: the legacy 4-argument admit keeps working — a
        # missing ``samp`` fills every row with the greedy defaults, so
        # admitted slots land exactly where the pre-sampling engine put
        # them (bit-identical first token)
        if samp is None:
            samp = host_sampling_rows(batch_global)
        return admit_jit(params, state, tokens, lengths, samp)
    dec = jax.jit(shard_map(dec_body, mesh=mesh,
                            in_specs=(sv_specs, s_specs, tok1),
                            out_specs=(tok1, s_specs), check_vma=False))
    retire = jax.jit(shard_map(rt_body, mesh=mesh,
                               in_specs=(s_specs, tok1),
                               out_specs=s_specs, check_vma=False))
    return EngineHandle(params, pf, dec, admit, retire, state, lay, scfg,
                        cfg, mesh, batch_global, repack_fn)


def build_replicas(cfg, mesh, *, n_replicas: int, max_seq: int,
                   batch_global: int,
                   options: Optional[EngineOptions] = None, **kw):
    """N engine replicas for the fleet router (serving/router.py).

    Each replica is an independent :class:`EngineHandle` on ``mesh``
    (in production each would own its own mesh slice; tests run N
    single-mesh engines), initialized from the SAME PRNG seed — so any
    replica produces the identical stream for a given (prefix, sampling
    params, emit offset), which is the invariant reconstructive recovery
    relies on: a request re-queued onto a survivor continues
    token-for-token where the dead replica's journal left off — sampled
    requests included, via the journaled seed + emit offset
    (DESIGN.md §9).

    ``check_finite``/``kv_fingerprint``/``shadow_head`` default ON here
    (unlike ``build_engine_full``): the router's health probes read the
    per-slot non-finite sentinel and the SDC monitor's fingerprint /
    shadow leaves (serving/integrity.py).  Pass
    ``options=EngineOptions(...)`` to override; bare keyword arguments
    still route through ``build_engine_full``'s deprecation shim.
    """
    if options is None:
        options = EngineOptions(check_finite=True, kv_fingerprint=True,
                                shadow_head=True)
    return [build_engine_full(cfg, mesh, max_seq=max_seq,
                              batch_global=batch_global,
                              options=options, **kw)
            for _ in range(n_replicas)]


def generate(cfg, params, pf, dec, state, prompts: jnp.ndarray,
             n_new: int, fe=None):
    """prompts: [B, S_prompt] → tokens [B, n_new] (greedy).

    ``params`` is build_engine's ``{"train", "serve"}`` pair: prefill
    consumes the training layout, the decode loop the serve layout.
    """
    p_train, p_serve = params["train"], params["serve"]
    nxt, state = pf(p_train, state, prompts, fe)
    out = [nxt]
    for _ in range(n_new - 1):
        nxt, state = dec(p_serve, state, nxt)
        out.append(nxt)
    return jnp.stack(out, axis=-1), state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "auto"))
    ap.add_argument("--interpret", action="store_true",
                    help="Pallas interpret mode (CPU)")
    ap.add_argument("--prepack", default="auto",
                    choices=("auto", "on", "off"),
                    help="serve-layout weight prepack (auto: on whenever "
                         "the Pallas backend is selected)")
    args = ap.parse_args()
    cfg = reduced(get_config(args.arch))
    mesh = make_test_mesh()
    params, pf, dec, state, lay, scfg = build_engine(
        cfg, mesh, max_seq=args.prompt_len + args.tokens + 8,
        batch_global=args.batch, backend=args.backend,
        interpret=args.interpret, prepack=args.prepack)
    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        fe = jax.random.normal(key, (args.batch, cfg.frontend.num_positions,
                                     cfg.frontend.feature_dim))
    t0 = time.time()
    toks, _ = generate(cfg, params, pf, dec, state, prompts, args.tokens, fe)
    dt = time.time() - t0
    print(f"generated {args.tokens} tokens × {args.batch} seqs in {dt:.2f}s "
          f"(cluster={lay.cluster})")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
