import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes, extract memory/cost/collective statistics,
and emit the roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

MUST be imported before any other jax-touching module — the two lines
above run before any other import so jax sees 512 host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape decode_32k [--multi-pod] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
from typing import Dict, Optional

import jax

from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (build_decode_step, build_prefill_step,
                                build_train_step)
from repro.training.optimizer import OptConfig
from repro.training.train_step import TrainConfig

# v5e roofline constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*= \(?([a-z0-9_]+)\[([0-9,]*)\]")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-operand bytes of every collective op in the (SPMD,
    per-device) HLO.  Keyed by op kind; 'total' included."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r".*= \(?([a-z0-9_]+)\[([0-9,]*)\][^)]*\)? "
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)", line)
        if not m:
            continue
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[kind] = out.get(kind, 0.0) + nbytes
        out["total"] = out.get("total", 0.0) + nbytes
    return out


def collective_counts(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for kind in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                 "collective-permute"):
        out[kind] = len(re.findall(rf"\b{kind}\b", hlo_text))
    return out


def analyse(compiled, lowered=None) -> Dict[str, float]:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # pre-0.5 JAX: one dict per device
        cost = cost[0] if cost else {}
    txt = compiled.as_text()
    coll = collective_bytes_scaled(txt)   # while-trip-count aware
    counts = collective_counts(txt)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    cbytes = coll.get("total", 0.0)
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_acc / HBM_BW
    t_coll = cbytes / ICI_BW
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": cbytes,
        "collective_counts": counts,
        "collective_bytes_by_kind": coll,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "peak_device_bytes": (mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              + mem.output_size_in_bytes),
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             opt_name: Optional[str] = None, verbose: bool = True,
             tcfg_kw: Optional[dict] = None) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape not in shapes_for(cfg):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long-context cell skipped for unbounded "
                          "full-attention KV (DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if shape.mode == "train":
        # production defaults: FSDP (ZeRO-3) + bf16 grad accumulation;
        # 1T-class MoE additionally needs factored optimizer state to fit
        opt = opt_name or ("adafactor"
                           if cfg.param_count() > 3e11 else "adamw")
        kw = dict(fsdp=True, microbatches=4, grad_dtype="bf16")
        kw.update(tcfg_kw or {})
        tcfg = TrainConfig(opt=OptConfig(name=opt), **kw)
        fn, abstract, lay = build_train_step(cfg, mesh, tcfg, shape)
        args = abstract
    elif shape.mode == "prefill":
        fn, abstract, lay, _ = build_prefill_step(cfg, mesh, shape)
        args = abstract
    else:
        fn, abstract, lay, _ = build_decode_step(cfg, mesh, shape)
        args = abstract
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    stats = analyse(compiled, lowered)
    # MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D per step (train);
    # 2·N_active per decoded token (decode); 2·N_active·D (prefill).
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode"
                                   else 1)
    if shape.mode == "train":
        model_flops = 6.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * tokens
    n_dev = 512 if multi_pod else 256
    stats.update({
        "arch": arch, "shape": shape_name, "mode": shape.mode,
        "multi_pod": multi_pod, "n_devices": n_dev,
        "heads_sub": lay.heads_sub, "cluster": lay.cluster,
        "model_flops_total": model_flops,
        "useful_flops_ratio": model_flops / max(
            stats["flops_per_device"] * n_dev, 1.0),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    })
    if verbose:
        print(f"[{arch} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'}] "
              f"heads_sub={lay.heads_sub} cluster={lay.cluster} "
              f"compile={t_compile:.1f}s")
        print(f"  flops/dev={stats['flops_per_device']:.3e} "
              f"bytes/dev={stats['bytes_per_device']:.3e} "
              f"coll/dev={stats['collective_bytes_per_device']:.3e}")
        print(f"  t_comp={stats['t_compute_s']*1e3:.3f}ms "
              f"t_mem={stats['t_memory_s']*1e3:.3f}ms "
              f"t_coll={stats['t_collective_s']*1e3:.3f}ms "
              f"dominant={stats['dominant']}")
        print(f"  peak_dev_bytes={stats['peak_device_bytes']/2**30:.2f}GiB "
              f"(args {stats['argument_bytes']/2**30:.2f} + temp "
              f"{stats['temp_bytes']/2**30:.2f}) "
              f"useful_flops_ratio={stats['useful_flops_ratio']:.3f}")
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in list_archs():
            cfg = get_config(arch)
            for shape in SHAPES.values():
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                results.append(run_cell(arch, shape, multi_pod=mp))
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                print(f"[{arch} × {shape} × mp={mp}] FAILED: {e!r}",
                      file=sys.stderr)
                results.append({"arch": arch, "shape": shape,
                                "multi_pod": mp, "error": repr(e)})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"wrote {args.json}")
    print(f"{len(results)} cells, {failures} failures")
    return 1 if failures else 0




# ---------------------------------------------------------------------------
# Trip-count-aware collective accounting: XLA cost_analysis and a naive HLO
# text walk count a `while` body ONCE; scans over layers / KV chunks /
# microbatches hide their per-iteration collectives.  This walker assigns
# each op to its enclosing computation, recovers while trip counts from the
# canonical jax lowering (condition `compare(iter, constant(N))`), and
# multiplies through the (possibly nested) call graph.
# ---------------------------------------------------------------------------
def _hlo_computations(txt: str):
    comps, cur, name = {}, [], None
    for line in txt.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{",
                     line)
        if m:
            name = m.group(1)
            cur = []
            comps[name] = cur
            continue
        if name is not None:
            if line.strip().startswith("}"):
                name = None
            elif line.strip():
                cur.append(line)
    return comps


def _trip_count(cond_lines) -> int:
    consts = {}
    for ln in cond_lines:
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*[a-z0-9]+\[\]\s*constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        m = re.search(r"compare\(([^)]*)\)", ln)
        if m:
            for arg in m.group(1).split(","):
                arg = arg.strip().lstrip("%")
                if arg in consts:
                    return consts[arg]
    if len(consts) == 1:
        return next(iter(consts.values()))
    return 1


def collective_bytes_scaled(txt: str):
    """Collective bytes with while-trip-count multipliers applied."""
    comps = _hlo_computations(txt)
    # computation -> multiplier (product of enclosing while trip counts)
    mult = {name: 1 for name in comps}
    # find while ops: body/condition computation references
    edges = []       # (parent_comp, child_comp, factor)
    for name, lines in comps.items():
        for ln in lines:
            wm = re.search(r"while\(.*?\).*condition=%?([\w.\-]+).*"
                           r"body=%?([\w.\-]+)", ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                n = _trip_count(comps.get(cond, []))
                edges.append((name, body, n))
            cm = re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)", ln)
            for child in cm:
                edges.append((name, child, 1))
            fm = re.search(r"fusion\(.*?\).*calls=%?([\w.\-]+)", ln)
            if fm:
                edges.append((name, fm.group(1), 1))
    # propagate multipliers (few levels; iterate to fixpoint)
    for _ in range(8):
        changed = False
        for parent, child, n in edges:
            want = mult.get(parent, 1) * n
            if child in mult and mult[child] < want:
                mult[child] = want
                changed = True
        if not changed:
            break
    out = {}
    for name, lines in comps.items():
        f = mult.get(name, 1)
        for ln in lines:
            m = re.match(r".*= \(?([a-z0-9_]+)\[([0-9,]*)\][^)]*\)? "
                         r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                         r"collective-permute)", ln.strip())
            if not m:
                continue
            dt, dims, kind = m.group(1), m.group(2), m.group(3)
            nbytes = _DTYPE_BYTES.get(dt, 4)
            for d in dims.split(","):
                if d:
                    nbytes *= int(d)
            out[kind] = out.get(kind, 0.0) + nbytes * f
            out["total"] = out.get("total", 0.0) + nbytes * f
    return out

if __name__ == "__main__":
    sys.exit(main())
