"""Abstract specs + step builders for every (arch × shape × mesh) cell.

Everything here works on ``jax.ShapeDtypeStruct``s — the dry-run lowers
and compiles with zero allocation (the same pattern real launches use,
then materialize with ``out_shardings``).

Spec conventions (device-major storage, DESIGN.md §5):
* params:        [model, *local]                P("model", …)
* opt/EF state:  [dp, model, *local]            P(dp_axes, "model", …)
* decode state:  [dp, model, *local]            P(dp_axes, "model", …)
* batch:         [B_global, …]                  P(dp_axes, …)  (replicated
                 when B_global < dp — the long_500k single-stream case)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.autotune import tune_cluster, tune_serving
from repro.models.ctx import ParallelCtx, make_train_ctx
from repro.models.transformer import (Layout, fsdp_axes,
                                      fsdp_param_specs, fsdp_shard_abstract,
                                      grad_sync_tree, init_device_major,
                                      layout_for, param_specs)
from repro.launch.mesh import dp_axes_of, dp_size_of
from repro.serving.engine import ServeConfig, decode_step, init_decode_state
from repro.serving.prefill import prefill
from repro.training.train_step import (TrainConfig, init_train_state,
                                       make_train_step)

PyTree = Any


# ---------------------------------------------------------------------------
# Layout selection
# ---------------------------------------------------------------------------
def _cluster_ok(cfg: ModelConfig, ms: int, n: int) -> bool:
    """Divisibility constraints for a serve cluster of size n."""
    hs = ms // n
    if hs < 1 or cfg.n_heads % hs:
        return False
    hd = cfg.resolved_head_dim
    if hd % n or cfg.d_model % n:
        return False
    if cfg.mla is not None:
        m = cfg.mla
        if ((m.kv_lora_rank + m.rope_head_dim) % n
                or m.kv_lora_rank % n
                or (m.nope_head_dim + m.rope_head_dim) % n):
            return False
    if cfg.sliding_window % n:
        return False
    return True


def serving_layout(cfg: ModelConfig, shape: ShapeConfig, ms: int) -> Layout:
    """Cluster size from the paper's tuning model (§4.1), constrained to
    divisible configurations.  Attention-free archs fall back to the
    training factoring (the technique is inapplicable — DESIGN.md §4)."""
    if cfg.is_attention_free:
        return layout_for(cfg, ms)
    best = tune_cluster(cfg, seq_len=shape.seq_len,
                        batch=max(1, shape.global_batch), model_axis=ms)
    n = best.cluster_size
    while n > 1 and not _cluster_ok(cfg, ms, n):
        n //= 2
    if not _cluster_ok(cfg, ms, n):
        return layout_for(cfg, ms)
    return Layout(ms, heads_sub=ms // n)


def train_layout(cfg: ModelConfig, ms: int) -> Layout:
    return layout_for(cfg, ms)


def ctx_for(mesh, lay: Layout, **kw) -> ParallelCtx:
    return make_train_ctx("model", heads_sub=lay.heads_sub,
                          model_size=lay.model_size,
                          data=dp_axes_of(mesh), **kw)


# ---------------------------------------------------------------------------
# Abstract trees
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig, lay: Layout) -> PyTree:
    return jax.eval_shape(
        lambda: init_device_major(cfg, lay, jax.random.PRNGKey(0)))


def _local_view(shape: Tuple[int, ...]) -> Tuple[int, ...]:
    return (1,) + tuple(shape[1:])


def abstract_opt_state(cfg: ModelConfig, tcfg: TrainConfig, params_abs,
                       dp: int, ms: int, fsdp_ax=None
                       ) -> Tuple[PyTree, Optional[PyTree]]:
    """(opt_state_abs, ef_abs) with [dp, model] leading device dims."""
    local = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(_local_view(l.shape), l.dtype),
        params_abs)

    def init(p):
        rank = jnp.zeros((), jnp.int32)
        return init_train_state(cfg, tcfg, p, dp, rank, fsdp_ax=fsdp_ax)

    opt_abs, ef_abs = jax.eval_shape(init, local)

    def lift(l):
        return jax.ShapeDtypeStruct((dp, ms) + tuple(l.shape), l.dtype)

    opt_abs = jax.tree.map(lift, opt_abs)
    ef_abs = jax.tree.map(lift, ef_abs) if ef_abs is not None else None
    return opt_abs, ef_abs


def state_spec_tree(tree: PyTree, dp_axes) -> PyTree:
    """P(dp_axes, "model", None, …) for [dp, model, *local] leaves."""
    return jax.tree.map(
        lambda l: P(dp_axes, "model", *([None] * (l.ndim - 2))), tree)


def abstract_decode_state(cfg: ModelConfig, scfg: ServeConfig,
                          ctx: ParallelCtx, dp: int) -> PyTree:
    local = jax.eval_shape(lambda: init_decode_state(cfg, scfg, ctx))
    ms = ctx.model_size

    def lift(l):
        return jax.ShapeDtypeStruct((dp, ms) + tuple(l.shape), l.dtype)

    return jax.tree.map(lift, local)


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(ShapeDtypeStructs, PartitionSpecs) for the step's data inputs."""
    dp_axes = dp_axes_of(mesh)
    dp = dp_size_of(mesh)
    B = shape.global_batch
    bspec = P(dp_axes) if B % dp == 0 and B >= dp else P()
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    out: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    if shape.mode == "train":
        S = shape.seq_len
        out["tokens"] = sds((B, S), i32)
        out["targets"] = sds((B, S), i32)
        specs["tokens"] = P(*bspec, None)
        specs["targets"] = P(*bspec, None)
        if cfg.frontend is not None:
            fr = cfg.frontend
            out["frontend_embeds"] = sds((B, fr.num_positions,
                                          fr.feature_dim), f32)
            specs["frontend_embeds"] = P(*bspec, None, None)
            if cfg.encoder is None:            # vlm: mask patch positions
                out["valid"] = sds((B, S), f32)
                specs["valid"] = P(*bspec, None)
    elif shape.mode == "prefill":
        out["tokens"] = sds((B, shape.seq_len), i32)
        specs["tokens"] = P(*bspec, None)
        if cfg.frontend is not None:
            fr = cfg.frontend
            out["frontend_embeds"] = sds((B, fr.num_positions,
                                          fr.feature_dim), f32)
            specs["frontend_embeds"] = P(*bspec, None, None)
    else:                                       # decode
        out["tokens"] = sds((B,), i32)
        specs["tokens"] = bspec
    return out, specs


# ---------------------------------------------------------------------------
# Step builders (shard_map-wrapped, jit-ready)
# ---------------------------------------------------------------------------
def _unwrap2(tree):
    return jax.tree.map(lambda l: l[0, 0], tree)


def _wrap2(tree):
    return jax.tree.map(lambda l: l[None, None], tree)


def build_train_step(cfg: ModelConfig, mesh, tcfg: TrainConfig,
                     shape: ShapeConfig, lay: Optional[Layout] = None):
    """Returns (fn, abstract_args, lay) — fn(params, opt, ef, batch)."""
    ms = mesh.shape["model"]
    lay = lay or train_layout(cfg, ms)
    dp_axes = dp_axes_of(mesh)
    dp = dp_size_of(mesh)
    ctx = ctx_for(mesh, lay)
    params_abs = abstract_params(cfg, lay)      # GLOBAL (unsliced) shapes
    sync = grad_sync_tree(cfg, lay, params_abs)
    ax_tree = None
    if tcfg.fsdp and dp > 1:
        ax_tree = fsdp_axes(params_abs, dp)
        # the in_specs add the dp slicing; global args stay full-shaped
        p_specs = fsdp_param_specs(cfg, params_abs, ax_tree, dp_axes)
        params_for_opt = fsdp_shard_abstract(params_abs, ax_tree, dp)
    else:
        p_specs = param_specs(cfg, params_abs)
        params_for_opt = params_abs
    step = make_train_step(ctx, cfg, tcfg, dp_axes, dp, sync_tree=sync,
                           fsdp_ax=ax_tree)
    batch_abs, b_specs = input_specs(cfg, shape, mesh)

    opt_abs, ef_abs = abstract_opt_state(cfg, tcfg, params_for_opt, dp, ms,
                                         fsdp_ax=ax_tree)
    o_specs = state_spec_tree(opt_abs, dp_axes)
    e_specs = state_spec_tree(ef_abs, dp_axes) if ef_abs is not None else None

    def body(params, opt, ef, batch):
        opt_l = _unwrap2(opt)
        ef_l = _unwrap2(ef) if ef is not None else None
        new_p, new_opt, new_ef, metrics = step(params, opt_l, ef_l, batch)
        metrics = {k: v[None] for k, v in metrics.items()}
        return (new_p, _wrap2(new_opt),
                _wrap2(new_ef) if new_ef is not None else None, metrics)

    m_spec = {k: P(None) for k in ("loss", "grad_norm", "tokens")}
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(p_specs, o_specs, e_specs, b_specs),
        out_specs=(p_specs, o_specs, e_specs, m_spec),
        check_vma=False)
    return fn, (params_abs, opt_abs, ef_abs, batch_abs), lay


def _needs_weight_spread(cfg: ModelConfig, ms: int) -> bool:
    """Weights > ~10 GiB/device under model-axis sharding alone."""
    return cfg.param_count() * 2 / ms > 10 * 2**30


def _dff_override_specs(p_specs, params_abs):
    """Add 'data' to the d_ff dim of MoE expert (+dense residual) leaves."""
    from repro.models.moe import MoEParams as MP

    def fix_moe(spec_tree, abs_tree):
        def ent(l, last):
            e = [None] * l.ndim
            e[0] = "model"
            e[l.ndim - (1 if last else 2)] = "data"
            return P(*e)

        return MP(
            router=spec_tree.router,
            w_in=ent(abs_tree.w_in, last=True),
            w_out=ent(abs_tree.w_out, last=False),
            w_gate=None if abs_tree.w_gate is None
            else ent(abs_tree.w_gate, last=True),
            dense=None if abs_tree.dense is None else type(abs_tree.dense)(
                w_in=ent(abs_tree.dense.w_in, last=True),
                w_out=ent(abs_tree.dense.w_out, last=False),
                w_gate=None if abs_tree.dense.w_gate is None
                else ent(abs_tree.dense.w_gate, last=True)),
        )

    out = dict(p_specs)
    out["blocks"] = []
    for sp, ab in zip(p_specs["blocks"], params_abs["blocks"]):
        blk = dict(sp)
        if isinstance(ab.get("ffn"), MP):
            blk["ffn"] = fix_moe(sp["ffn"], ab["ffn"])
        out["blocks"].append(blk)
    return out


def build_decode_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                      scfg_extra: Optional[dict] = None,
                      backend: str = "xla", interpret: bool = False,
                      block_s: Optional[int] = None, prepack="auto"):
    ms = mesh.shape["model"]
    lay = serving_layout(cfg, shape, ms)
    dp_axes = dp_axes_of(mesh)
    dp = dp_size_of(mesh)
    ctx = ctx_for(mesh, lay, **(scfg_extra or {}))
    B = shape.global_batch
    b_shard = B % dp == 0 and B >= dp
    b_loc = B // dp if b_shard else B
    dff = (_needs_weight_spread(cfg, ms) and cfg.moe is not None
           and cfg.moe.expert_d_ff % mesh.shape["data"] == 0)
    plan = tune_serving(cfg, seq_len=shape.seq_len, batch=max(1, b_loc),
                        model_axis=ms, backend=backend, prepack=prepack)
    scfg = ServeConfig(max_seq=shape.seq_len, batch_local=b_loc,
                       dff_shard=dff, backend=plan.backend,
                       interpret=interpret,
                       block_s=block_s or plan.block_s,
                       block_f=plan.block_f,
                       prepack=plan.prepack)
    params_abs = abstract_params(cfg, lay)
    if scfg.prepack:
        # the decode step consumes the serve layout (derived once from
        # the training layout at load — serving/prepack.py)
        from repro.serving.prepack import prepack_abstract
        params_abs = prepack_abstract(cfg, lay, params_abs,
                                      backend=scfg.backend)
    p_specs = param_specs(cfg, params_abs)
    if dff:
        p_specs = _dff_override_specs(p_specs, params_abs)
    state_abs = abstract_decode_state(cfg, scfg, ctx, dp)
    s_specs = state_spec_tree(state_abs, dp_axes)
    tok_spec = P(dp_axes) if b_shard else P()

    def body(params, state, tokens):
        st = _unwrap2(state)
        nxt, new_st = decode_step(ctx, cfg, scfg, params, st, tokens)
        return nxt, _wrap2(new_st)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(p_specs, s_specs, tok_spec),
                   out_specs=(tok_spec, s_specs),
                   check_vma=False)
    batch_abs, _ = input_specs(cfg, shape, mesh)
    return fn, (params_abs, state_abs, batch_abs["tokens"]), lay, scfg


def build_prefill_step(cfg: ModelConfig, mesh, shape: ShapeConfig):
    ms = mesh.shape["model"]
    lay = serving_layout(cfg, shape, ms)
    dp_axes = dp_axes_of(mesh)
    dp = dp_size_of(mesh)
    ctx = ctx_for(mesh, lay)
    B = shape.global_batch
    b_shard = B % dp == 0 and B >= dp
    b_loc = B // dp if b_shard else B
    scfg = ServeConfig(max_seq=shape.seq_len, batch_local=b_loc)
    params_abs = abstract_params(cfg, lay)
    # giant models: FSDP-slice the prefill weights over dp, gather per group
    fsdp_info = None
    if _needs_weight_spread(cfg, ms) and dp > 1:
        ax_tree = fsdp_axes(params_abs, dp)
        p_specs = fsdp_param_specs(cfg, params_abs, ax_tree, dp_axes)
        fsdp_info = (ax_tree, dp_axes)
    else:
        p_specs = param_specs(cfg, params_abs)
    state_abs = abstract_decode_state(cfg, scfg, ctx, dp)
    s_specs = state_spec_tree(state_abs, dp_axes)
    batch_abs, b_specs = input_specs(cfg, shape, mesh)
    tok_spec = b_specs["tokens"]
    fe_spec = b_specs.get("frontend_embeds", P())

    def body(params, state, tokens, fe):
        st = _unwrap2(state)
        nxt, new_st = prefill(ctx, cfg, scfg, params, st, tokens, fe,
                              fsdp=fsdp_info)
        return nxt, _wrap2(new_st)

    nxt_spec = P(dp_axes) if b_shard else P()
    fn = shard_map(body, mesh=mesh,
                   in_specs=(p_specs, s_specs, tok_spec, fe_spec),
                   out_specs=(nxt_spec, s_specs),
                   check_vma=False)
    fe_abs = batch_abs.get("frontend_embeds")
    return fn, (params_abs, state_abs, batch_abs["tokens"], fe_abs), lay, scfg
