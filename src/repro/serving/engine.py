"""Decode engine: ClusterFusion serving path.

``decode_step`` is the paper's product: per attention layer it runs the
cluster-centric fused dataflow (Alg. 3 SplitToken / Alg. 4 MLA) over the
``heads × cluster`` factoring of the model axis, with all intermediates
inside the shard_map body (one XLA computation per step, collectives =
exactly the ClusterGather/ClusterReduce schedule).  Attention-free blocks
(RG-LRU / RWKV-6) keep O(1) state — the paper's technique is inapplicable
there (DESIGN.md §4) and they use their own fused steps.

Cache layout (SplitToken): per attention layer, per device —
``k/v [S_blk, B_loc·kv_loc, hd]`` with the *sequence* sharded over the
cluster sub-axis (paper's KV-sequence partition) and kv-heads over the
heads sub-axis; ``pos [S_blk, B_loc]`` stores PER-SLOT global positions
(ring semantics for sliding-window layers).  Batch is sharded over the
data axes; decode is RAGGED — ``state["cache_lens"] [B_loc]`` lets every
sequence advance independently, and ``serving/scheduler.py`` runs
continuous batching over the slots (admit into free slots via targeted
prefill inserts, retire on EOS/max-len; DESIGN.md §6).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV6,
                                ModelConfig)
from repro.core import dataflow as df
from repro.core import primitives as prim
from repro.core import tracecount
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.attention import AttnParams, MLAAttnParams
from repro.models.ctx import ParallelCtx
from repro.models.layers import (EmbedParams, embed_lookup, ffn_apply,
                                 lm_head_logits, rms_norm, softcap)
from repro.models.moe import MoEParams, moe_apply
from repro.models.transformer import unwrap_local
from repro.serving.sampling import (CAND_K, _greedy_pair_merge,
                                    advance_sampling_step,
                                    finalize_candidates, greedy_sample,
                                    greedy_sample_pair, head_candidates,
                                    init_sampling_state, topk_pair_merge)

__all_reexports__ = (_greedy_pair_merge, greedy_sample, greedy_sample_pair)
# ^ the greedy helpers live in serving/sampling.py now (the stochastic
#   finalize shares their merge discipline); re-exported here because
#   PR-5-era call sites import them from the engine.

PyTree = Any


@dataclass(frozen=True)
class ServeConfig:
    max_seq: int                   # cache capacity (global positions)
    batch_local: int               # per-device batch
    fused_combine: bool = False    # beyond-paper single-tree flash merge
    dataflow: str = "split_token"  # split_token | split_head (bench only)
    # giant-MoE weight spreading: expert d_ff additionally sliced over the
    # "data" axis (kimi-1T / arctic-480B decode; DESIGN.md §5)
    dff_shard: bool = False
    # kernel backend for the per-layer local compute stage (DESIGN.md §2):
    # "xla" = block-bucketed XLA dataflow; "pallas" = fused decode kernels
    backend: str = "xla"
    interpret: bool = False        # Pallas interpret mode (CPU/tests)
    block_s: int = 256             # KV block granularity (autotunable)
    block_f: int = 512             # d_ff tile of the fused-FFN megakernel
                                   # (autotunable; fitted to F_loc per call)
    block_v: int = 1024            # vocab tile of the fused LM-head/sampling
                                   # kernel (autotunable; fitted to V_loc)
    # serve-layout weight prepack (serving/prepack.py): params arrive
    # already packed per rank — no per-step weight gathers or slices
    prepack: bool = False
    # ragged-decode work accounting: accumulate per-slot attend-step
    # (KV-block) counts into state["work_blocks"] every decode step
    # (core/tracecount.live_attend_blocks) — evidence that retired
    # scheduler slots pay zero attention work.  Off by default (adds a
    # [B]-int32 state leaf + a few integer ops per layer).
    track_work: bool = False
    # per-step integrity sentinel (fleet router health probes,
    # DESIGN.md §9): accumulate per-slot violation counts into
    # state["nonfinite"] — non-finite residual row, non-finite head
    # (value, index) max, or a sampled token outside [0, vocab) on an
    # ACTIVE slot.  Pure where-mask arithmetic + a counter leaf: no
    # jax.debug, no checkify, no host sync — the router reads the leaf
    # on its own schedule.  Off by default so the bench path traces an
    # identical program.
    check_finite: bool = False
    # SDC detection (serving/integrity.py, DESIGN.md §9): per-entry
    # per-slot int32 bit-pattern checksums of the KV caches
    # (state["kv_fp"] / state["kv_fp_tail"]), updated incrementally on
    # append/ring-wrap inside the fused step and recomputed for
    # admitted slots by the prefill insert; the router's probes
    # host-verify them.  Off by default (bench path unchanged).
    kv_fingerprint: bool = False
    # shadow-recompute stash (serving/integrity.py): each step writes
    # the per-slot pre-head residual + winning logit + sampled token
    # (state["head_resid"/"head_val"/"head_tok"]) so a host probe can
    # re-derive the committed token's logit against a pristine head
    # copy.  Off by default.
    shadow_head: bool = False


@dataclass(frozen=True)
class EngineOptions:
    """Construction-time options for ``build_engine_full`` — the single
    object that replaced its 14 mirrored keyword arguments (the legacy
    kwargs still work through a once-warning deprecation shim).

    Everything here is either resolved into the :class:`ServeConfig`
    the jitted steps close over (``backend`` / ``interpret`` /
    ``block_*`` / ``prepack`` / ``track_work`` / ``check_finite`` /
    ``kv_fingerprint`` / ``shadow_head``) or consumed by the build
    itself (``fused_combine`` / ``cluster`` / ``autotune_table`` /
    ``fuse_head`` / ``plan_seq_len``).  ``None`` block sizes defer to
    the autotuned plan; ``plan_seq_len`` keys the autotune bucket on
    the expected max LIVE length rather than the allocated capacity
    (DESIGN.md §6)."""
    fused_combine: bool = False
    cluster: Optional[int] = None
    backend: str = "xla"
    interpret: bool = False
    block_s: Optional[int] = None
    block_f: Optional[int] = None
    block_v: Optional[int] = None
    prepack: Any = "auto"
    autotune_table: Optional[str] = None
    track_work: bool = False
    fuse_head: bool = True
    check_finite: bool = False
    kv_fingerprint: bool = False
    shadow_head: bool = False
    plan_seq_len: Optional[int] = None


# ---------------------------------------------------------------------------
# Cache init (per device)
# ---------------------------------------------------------------------------
def _attn_cache(cfg: ModelConfig, scfg: ServeConfig, ctx: ParallelCtx,
                kind: str, dtype=jnp.bfloat16) -> df.KVBlock:
    n = ctx.cluster_size
    hs = ctx.heads_size
    kv_loc = max(1, cfg.n_kv_heads // hs)
    hd = cfg.resolved_head_dim
    B = scfg.batch_local
    # pos is PER-SLOT ([S_blk, B]): ragged decode gives every sequence
    # its own positions (ring wrap points differ once slots decouple)
    if cfg.mla is not None:
        lr = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
        s_blk = scfg.max_seq // n
        return df.KVBlock(k=jnp.zeros((s_blk, B, lr), dtype),
                          v=jnp.zeros((s_blk, B, 1), dtype),
                          pos=jnp.full((s_blk, B), -1, jnp.int32))
    span = cfg.sliding_window if kind == ATTN_LOCAL else scfg.max_seq
    span = min(span, scfg.max_seq)
    s_blk = max(1, span // n)
    return df.KVBlock(k=jnp.zeros((s_blk, B * kv_loc, hd), dtype),
                      v=jnp.zeros((s_blk, B * kv_loc, hd), dtype),
                      pos=jnp.full((s_blk, B), -1, jnp.int32))


def init_decode_state(cfg: ModelConfig, scfg: ServeConfig, ctx: ParallelCtx
                      ) -> Dict[str, Any]:
    """Per-device decode state: stacked caches per pattern position +
    recurrent states + per-slot ``cache_lens [B]`` (+ encoder KV slots
    for enc-dec).  ``cache_lens[b]``: number of cached tokens for slot
    ``b``; −1 marks a FREE slot (continuous-batching scheduler — no KV
    writes, no attention work, position counter frozen).  All-zeros is
    a fresh lockstep batch."""
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    B = scfg.batch_local
    hs = ctx.heads_size
    ms = max(ctx.model_size, 1)

    def stack(fn, n):
        items = [fn() for _ in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *items)

    state: Dict[str, Any] = {"cache_lens": jnp.zeros((B,), jnp.int32),
                             # per-slot sampling params + emit offset
                             # (greedy defaults), riding the state like
                             # cache_lens does — serving/sampling.py
                             "sampling": init_sampling_state(B)}
    if scfg.track_work:
        state["work_blocks"] = jnp.zeros((B,), jnp.int32)
    if scfg.check_finite:
        state["nonfinite"] = jnp.zeros((B,), jnp.int32)
    per_pos: List[Any] = []
    for p, kind in enumerate(cfg.block_pattern):
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            per_pos.append(stack(
                lambda k=kind: _attn_cache(cfg, scfg, ctx, k), n_groups))
        elif kind == RECURRENT:
            ds_loc = (cfg.rglru_d_state or cfg.d_model) // ms
            per_pos.append(stack(
                lambda: rglru_mod.rglru_state_init(B, ds_loc,
                                                   cfg.conv1d_width),
                n_groups))
        elif kind == RWKV6:
            nh_loc = (cfg.d_model // cfg.rwkv_head_dim) // hs
            per_pos.append(stack(
                lambda: rwkv_mod.rwkv6_state_init(B, nh_loc,
                                                  cfg.rwkv_head_dim,
                                                  cfg.d_model), n_groups))
    state["layers"] = per_pos
    n_tail = cfg.n_layers - n_groups * period
    state["tail"] = [
        _attn_cache(cfg, scfg, ctx, kinds[n_groups * period + t])
        if kinds[n_groups * period + t] in (ATTN_GLOBAL, ATTN_LOCAL)
        else (rglru_mod.rglru_state_init(
            B, (cfg.rglru_d_state or cfg.d_model) // ms, cfg.conv1d_width)
            if kinds[n_groups * period + t] == RECURRENT
            else rwkv_mod.rwkv6_state_init(
                B, (cfg.d_model // cfg.rwkv_head_dim) // hs,
                cfg.rwkv_head_dim, cfg.d_model))
        for t in range(n_tail)]
    if scfg.kv_fingerprint:
        # one int32 [B] checksum vector per cache entry (zeros for the
        # attention-free kinds — they ride through untouched); the lists
        # stay parallel to state["layers"] / state["tail"]
        state["kv_fp"] = [jnp.zeros((max(n_groups, 1), B), jnp.int32)
                          for _ in cfg.block_pattern]
        state["kv_fp_tail"] = [jnp.zeros((B,), jnp.int32)
                               for _ in range(n_tail)]
    if scfg.shadow_head:
        state["head_resid"] = jnp.zeros((B, cfg.d_model), jnp.bfloat16)
        state["head_val"] = jnp.zeros((B,), jnp.float32)
        state["head_tok"] = jnp.zeros((B,), jnp.int32)
    if cfg.encoder is not None:
        kv_loc = max(1, cfg.n_kv_heads // hs)
        hd = cfg.resolved_head_dim
        P = cfg.frontend.num_positions
        state["enc_kv"] = {
            "k": jnp.zeros((cfg.n_layers, P, B * kv_loc, hd), jnp.bfloat16),
            "v": jnp.zeros((cfg.n_layers, P, B * kv_loc, hd), jnp.bfloat16),
        }
    return state


# ---------------------------------------------------------------------------
# Weight adapters: train layout (AttnParams) → dataflow weight shards
# ---------------------------------------------------------------------------
def _split_token_weights(ctx: ParallelCtx, p: AttnParams, *,
                         _count: str = "weight_slice"
                         ) -> df.SplitTokenWeights:
    """Train layout already shards heads over `heads` and head_dim over
    `cluster` for wq/wk/wv; wo is [q_loc*hd, D] replicated over cluster —
    the dataflow needs the cluster's D-column slice, taken dynamically.

    Axes are ndim-relative, so the same code serves per-layer leaves and
    stacked ``[n_groups, …]`` scan leaves.  Per-layer use is the legacy
    adapter (direct ``decode_block`` callers — bench baselines);
    ``decode_step`` hoists the slicing out of the layer scan
    (:func:`hoist_serve_weights`), and the prepacked serve layout removes
    it entirely (serving/prepack.py).
    """
    tracecount.bump(_count)
    n = ctx.cluster_size
    c = ctx.cluster_index()
    d_n = p.wo.shape[-1] // n
    wo_seg = lax.dynamic_slice_in_dim(p.wo, c * d_n, d_n,
                                      axis=p.wo.ndim - 1)
    return df.SplitTokenWeights(wq=p.wq, wk=p.wk, wv=p.wv, wo=wo_seg,
                                bq=p.bq, bk=p.bk, bv=p.bv)


def _mla_weights(ctx: ParallelCtx, p: MLAAttnParams, cfg: ModelConfig, *,
                 _count: str = "weight_slice") -> df.MLAWeights:
    tracecount.bump(_count, 3)
    n = ctx.cluster_size
    c = ctx.cluster_index()
    m = cfg.mla
    d_n = p.wo.shape[-1] // n
    l_n = m.kv_lora_rank // n
    return df.MLAWeights(
        wq=p.wq,
        wdkv=p.wdkv,
        wuk=lax.dynamic_slice_in_dim(p.wuk, c * l_n, l_n,
                                     axis=p.wuk.ndim - 1),
        wuv=lax.dynamic_slice_in_dim(p.wuv, c * l_n, l_n,
                                     axis=p.wuv.ndim - 2),
        wo=lax.dynamic_slice_in_dim(p.wo, c * d_n, d_n,
                                    axis=p.wo.ndim - 1),
    )


def _hoist_attn(ctx: ParallelCtx, cfg: ModelConfig, p):
    """One block's rank-slice adapter, run ONCE per decode step outside
    the layer-group scan — the step-invariant ``dynamic_slice`` no
    longer re-executes per layer-group iteration."""
    if isinstance(p, MLAAttnParams):
        return _mla_weights(ctx, p, cfg, _count="weight_slice_hoisted")
    return _split_token_weights(ctx, p, _count="weight_slice_hoisted")


def hoist_serve_weights(ctx: ParallelCtx, cfg: ModelConfig,
                        params: PyTree, scfg: ServeConfig) -> PyTree:
    """Per-step weight adapters, hoisted out of the layer scan.

    Prepacked params (serving/prepack.py) are already in serve layout —
    pass through.  Otherwise every self-attention block's train-layout
    ``attn`` entry is rank-sliced here, once per step, so the scan body
    consumes ready dataflow weights (satellite of DESIGN.md §2's
    prepack: the non-prepacked path stops paying the per-layer-iteration
    ``dynamic_slice`` too)."""
    if scfg.prepack:
        return params
    from repro.serving.prepack import map_blocks

    def adapt(blk, stacked):
        a = blk.get("attn")
        if not isinstance(a, (AttnParams, MLAAttnParams)):
            return blk
        return dict(blk, attn=_hoist_attn(ctx, cfg, a))

    return map_blocks(adapt, params)


# ---------------------------------------------------------------------------
# Per-block decode
# ---------------------------------------------------------------------------
def _spec(ctx: ParallelCtx, scfg: ServeConfig) -> df.ClusterSpec:
    return df.ClusterSpec(heads=ctx.heads or "model",
                          cluster=ctx.cluster or "model",
                          fused_combine=ctx.fused_combine,
                          use_xla=ctx.use_xla_collectives,
                          backend=scfg.backend,
                          interpret=scfg.interpret,
                          block_s=scfg.block_s)


def _fused_ffn_tail(ctx: ParallelCtx, cfg: ModelConfig, scfg: ServeConfig,
                    blk: Dict[str, Any], x: jax.Array, a: jax.Array,
                    w: df.PackedFFNWeights) -> jax.Array:
    """Fused block tail (DESIGN.md §7): post-attention norm + both
    residual adds + pre-FFN norm + gate/up/act/down in ONE Pallas kernel
    per rank, with the per-layer FFN activation ``psum_model`` replaced
    by ONE fused ClusterReduce over the full-width down-projection
    partials (the residual folds into exactly one rank's partial, so the
    reduce completes the layer output directly).

    Post-norm models (``post_ln2``) normalize the SUMMED FFN output, so
    there the second residual add runs after the combine on the
    kernel-emitted ``r``.
    """
    from repro.kernels.fused_ffn.fused_ffn import fused_ffn_block
    eps = cfg.norm_eps
    has_post2 = "post_ln2" in blk
    if has_post2:
        add_r = jnp.float32(0.0)
    else:
        add_r = (ctx.model_index() == 0).astype(jnp.float32)
    bf = df._fit_block_s(w.w_in.shape[-1], scfg.block_f)
    o_part, r = fused_ffn_block(
        x, a, w.w_in, w.w_gate, w.w_out, w.ln2, w.post_ln1, add_r,
        act=cfg.ffn_act, eps=eps, block_f=bf, interpret=scfg.interpret)
    n = ctx.model_size
    if ctx.model is None:
        f = o_part
    elif n & (n - 1):              # non-pow2 axis: tree schedule invalid
        f = ctx.psum_model(o_part)
    else:
        tracecount.bump("ffn_cluster_reduce")
        f = prim.cluster_reduce(o_part, ctx.model, "sum")
    if has_post2:
        return r + rms_norm(f, blk["post_ln2"], eps)
    return f


def decode_block(ctx: ParallelCtx, cfg: ModelConfig, kind: str,
                 blk: Dict[str, Any], x: jax.Array, cache, cache_len,
                 scfg: ServeConfig, enc_kv=None):
    """x: [B, D] → ([B, D], new cache).  Attention via the paper dataflow."""
    eps = cfg.norm_eps
    if kind == RWKV6:
        p = blk["rwkv"]
        a, _, cache = rwkv_mod.rwkv6_step(
            ctx, p, rms_norm(x, blk["ln1"], eps), cfg.rwkv_head_dim, cache)
        x = x + a
        c, cache = rwkv_mod.rwkv6_channel_step(
            ctx, p, rms_norm(x, blk["ln2"], eps), cache)
        return x + c, cache
    if kind == RECURRENT:
        a, cache = rglru_mod.rglru_block_step(
            ctx, blk["rglru"], rms_norm(x, blk["ln1"], eps), cache)
    elif cfg.mla is not None:
        spec = _spec(ctx, scfg)
        w = blk["attn"]
        if isinstance(w, MLAAttnParams):       # train layout: adapt per layer
            w = _mla_weights(ctx, w, cfg)
        # serve layout with a fused ln1: the RAW residual stream goes in,
        # the kernel normalizes in VMEM (DESIGN.md §7)
        fused_ln1 = isinstance(w, df.PackedMLAWeights) and w.ln1 is not None
        x_in = x if fused_ln1 else rms_norm(x, blk["ln1"], eps)
        o_seg, cache = df.mla_attention(
            spec, x_in, w, cache, cache_len,
            nope_dim=cfg.mla.nope_head_dim, rope_dim=cfg.mla.rope_head_dim,
            rope_theta=cfg.rope_theta, norm_eps=eps)
        # prepacked serve layout emits the full [B, D] output directly
        a = o_seg if isinstance(w, df.PackedMLAWeights) \
            else ctx.gather_cluster(o_seg, axis=1)
    else:
        spec = _spec(ctx, scfg)
        w = blk["attn"]
        if isinstance(w, AttnParams):          # train layout: adapt per layer
            w = _split_token_weights(ctx, w)
        window = cfg.sliding_window if kind == ATTN_LOCAL else 0
        fused_ln1 = (isinstance(w, df.PackedSplitTokenWeights)
                     and w.ln1 is not None)
        x_in = x if fused_ln1 else rms_norm(x, blk["ln1"], eps)
        o_seg, cache = df.split_token_attention(
            spec, x_in, w, cache, cache_len,
            window=window, attn_softcap=cfg.attn_softcap,
            rope_theta=cfg.rope_theta, norm_eps=eps)
        a = o_seg if isinstance(w, df.PackedSplitTokenWeights) \
            else ctx.gather_cluster(o_seg, axis=1)
    # Fused block tail: dense-FFN attention blocks on the prepacked Pallas
    # path run post_ln1 + both residual adds + ln2 + the whole FFN as the
    # layer's SECOND (and last) kernel launch; the activation psum_model
    # is replaced by one fused ClusterReduce (DESIGN.md §7).
    if isinstance(blk.get("ffn"), df.PackedFFNWeights) and enc_kv is None:
        return _fused_ffn_tail(ctx, cfg, scfg, blk, x, a, blk["ffn"]), cache
    if "post_ln1" in blk:
        a = rms_norm(a, blk["post_ln1"], eps)
    x = x + a
    if enc_kv is not None:
        ca = _cross_decode(ctx, blk["cross"], x, enc_kv, cfg)
        x = x + ca
    h = rms_norm(x, blk["ln2"], eps)
    if isinstance(blk["ffn"], MoEParams):
        if scfg.dff_shard:
            from repro.models.moe import moe_apply_dff
            h_all = lax.all_gather(h, "data", axis=0, tiled=True)
            y_all = moe_apply_dff(ctx, blk["ffn"], h_all, cfg.ffn_act,
                                  cfg.moe, dff_axes="data")
            rank = lax.axis_index("data")
            f = lax.dynamic_slice_in_dim(y_all, rank * h.shape[0],
                                         h.shape[0], axis=0)
        else:
            f = moe_apply(ctx, blk["ffn"], h[:, None, :], cfg.ffn_act,
                          cfg.moe)[:, 0]
    else:
        f = ffn_apply(ctx, blk["ffn"], h, cfg.ffn_act)
    if "post_ln2" in blk:
        f = rms_norm(f, blk["post_ln2"], eps)
    return x + f, cache


def _cross_decode(ctx, cross_blk, x, enc_kv, cfg: ModelConfig):
    """Decoder cross-attention against static encoder K/V."""
    p: AttnParams = cross_blk["attn"]
    B, D = x.shape
    n = ctx.cluster_size
    q_loc, hd_seg = p.wq.shape[1], p.wq.shape[2]
    hd = hd_seg * n
    h = rms_norm(x, cross_blk["ln"], cfg.norm_eps)
    q_seg = jnp.einsum("bd,dqh->bqh", h, p.wq)
    q = ctx.gather_cluster(q_seg, axis=2)            # [B, q_loc, hd]
    k, v = enc_kv                                    # [P, B*kv_loc, hd]
    P = k.shape[0]
    kv_loc = k.shape[1] // B
    qpk = q_loc // kv_loc
    qg = q.reshape(B, kv_loc, qpk, hd).astype(jnp.float32)
    kc = k.reshape(P, B, kv_loc, hd).astype(jnp.float32)
    vc = v.reshape(P, B, kv_loc, hd).astype(jnp.float32)
    s = jnp.einsum("bkqh,pbkh->bkqp", qg, kc) / math.sqrt(hd)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkqp,pbkh->bkqh", pr, vc).reshape(B, q_loc * hd)
    y = (o.astype(x.dtype) @ p.wo)
    return ctx.psum_heads(y)


# ---------------------------------------------------------------------------
# Full decode step
# ---------------------------------------------------------------------------
def _finite_violations(cfg: ModelConfig, resid: jax.Array, head_val,
                       nxt: jax.Array, active: jax.Array) -> jax.Array:
    """Per-slot integrity sentinel (``ServeConfig.check_finite``): int32
    [B], 1 where an ACTIVE slot's step output is corrupt — non-finite
    residual row, non-finite head max-logit, or a sampled index outside
    ``[0, vocab)``.  Pure where-mask arithmetic: the guard is a handful
    of elementwise ops folded into the step, never a host assert."""
    tracecount.bump("finite_guard")
    bad = ~jnp.isfinite(resid.astype(jnp.float32)).all(axis=-1)
    bad = bad | ~jnp.isfinite(jnp.asarray(head_val, jnp.float32))
    bad = bad | (nxt < 0) | (nxt >= cfg.vocab_size)
    return (bad & active).astype(jnp.int32)


def _fused_head_tail(ctx: ParallelCtx, cfg: ModelConfig, scfg: ServeConfig,
                     w: df.PackedHeadWeights, x: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Fused LM-head/sampling tail (DESIGN.md §7): final RMSNorm + vocab-
    tiled logits + softcap + streaming top-k partials in ONE Pallas
    kernel per vocab shard, then ONE tree ClusterReduce on the sorted
    ``[B, CAND_K]`` (value, global index) candidate sets — ``[B, V]``
    logits never touch HBM, and the merge is the commutative
    :func:`~repro.kernels.fused_head.topk.topk_pair_merge` (the
    ``_greedy_pair_merge`` discipline at width k), so the candidates
    are bit-exact against the unfused full-logits selection
    (:func:`~repro.serving.sampling.head_candidates`).

    Ragged decode needs no gating: the head is slot-local, so free
    slots flow through (their token is ignored by the scheduler),
    exactly as on the XLA path.

    Returns the merged ``(values [B, K], global_indices [B, K])``; the
    caller finalizes per-slot sampling on them
    (:func:`~repro.serving.sampling.finalize_candidates`).
    """
    from repro.kernels.fused_head.fused_head import fused_head_block
    v_loc = w.table.shape[0]
    # largest divisor of V_loc ≤ block_v, WITHOUT _fit_block_s's
    # fall-back-to-full-size: that fallback trades bucket overhead for
    # skipped work on KV buckets, but here the tile is a VMEM-resident
    # [bv, D] weight block — falling back to V_loc would blow the VMEM
    # budget pick_block_v was sized against on awkward shard sizes
    # (small divisors just mean more grid steps, still correct)
    bv = min(scfg.block_v, v_loc)
    while v_loc % bv:
        bv -= 1
    mx, ix = fused_head_block(
        x, w.table, w.ln, eps=cfg.norm_eps,
        logit_softcap=float(cfg.logit_softcap or 0.0), block_v=bv,
        k=CAND_K, interpret=scfg.interpret)
    idx = ix + ctx.model_index().astype(jnp.int32) * v_loc
    if ctx.model is None:
        return mx, idx
    tracecount.bump("head_cluster_reduce")
    mx, idx = prim.cluster_reduce_pairs((mx, idx), ctx.model,
                                        topk_pair_merge)
    return mx, idx


def _check_not_param_pair(params_dm: PyTree, want: str) -> None:
    """PR-2 footgun guard: ``build_engine`` returns ``params`` as the
    ``{"train", "serve"}`` layout pair — stepping with the whole pair
    silently used to trace the wrong tree.  Fail loudly, naming the
    fix."""
    if isinstance(params_dm, dict) and {"train", "serve"} <= params_dm.keys():
        raise ValueError(
            "got the full {'train', 'serve'} param pair from build_engine; "
            f"pass params[{want!r}] — decode_step consumes the serve "
            "layout, prefill the training layout (see launch/serve.py "
            "generate() and the bench_tpot.py call sites)")


def decode_step(ctx: ParallelCtx, cfg: ModelConfig, scfg: ServeConfig,
                params_dm: PyTree, state: Dict[str, Any],
                tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step: tokens [B_loc] → (next_tokens [B_loc], new state).

    Everything (embedding, L layers of fused attention dataflow, FFN,
    head, sampling) is one computation — the TPU analogue of the paper's
    single-CUDA-graph decode step, with kernel-launch overhead replaced by
    a single XLA dispatch.

    Decode is RAGGED: ``state["cache_lens"]`` is a per-slot [B] vector,
    so every sequence advances independently (per-slot RoPE position,
    append slot, live-span cull — DESIGN.md §6).  Slots at −1 are FREE
    (continuous batching): they write no KV, run zero attend steps, and
    their position counter stays frozen; their sampled token is
    meaningless and ignored by the scheduler.
    """
    _check_not_param_pair(params_dm, "serve")
    params = unwrap_local(params_dm)
    # Step-invariant rank slicing of attention weights happens HERE, once
    # per step, not per layer-group iteration (no-op when the params are
    # prepacked in serve layout — serving/prepack.py).
    params = hoist_serve_weights(ctx, cfg, params, scfg)
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    cache_len = state["cache_lens"]

    def _blk_work(kind: str, cache_i) -> jax.Array:
        """Per-slot attend-step count for one attention layer (runtime
        work counters — core/tracecount.py)."""
        if not scfg.track_work or kind not in (ATTN_GLOBAL, ATTN_LOCAL):
            return jnp.zeros_like(cache_len)
        window = cfg.sliding_window if (kind == ATTN_LOCAL
                                        and cfg.mla is None) else 0
        s_blk = cache_i.k.shape[0]
        return tracecount.live_attend_blocks(
            cache_len, s_blk=s_blk,
            block_s=df._fit_block_s(s_blk, scfg.block_s),
            rank=ctx.cluster_index(), window=window, ring=window > 0)

    x = embed_lookup(ctx, EmbedParams(params["embed"]), tokens)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

    if cfg.encoder is not None:
        enc_kv_all = state["enc_kv"]

    # Caches ride in the scan CARRY and are updated with a dynamic-index
    # slice write — XLA performs the update in place (the carry buffer is
    # dead after the write), instead of staging a full per-layer copy
    # through scan ys (§Perf iter 3: ~3× decode HBM-byte reduction).
    n_groups_t = jnp.arange(max(n_groups, 1))
    work0 = jnp.zeros_like(cache_len)

    def group_body(carry, inp):
        x, caches, work = carry
        if cfg.encoder is not None:
            blks, gi, ca, ek, ev = inp
        else:
            blks, gi = inp
            ca = ek = ev = None
        new_caches = []
        for p_i in range(period):
            cache_i = jax.tree.map(lambda l: l[gi], caches[p_i])
            blk = blks[p_i]
            enc = None
            if ca is not None:
                blk = dict(blk)
                blk["cross"] = ca
                enc = (ek, ev)
            work = work + _blk_work(kinds[p_i], cache_i)
            x, nc = decode_block(ctx, cfg, kinds[p_i], blk, x,
                                 cache_i, cache_len, scfg, enc)
            new_caches.append(jax.tree.map(
                lambda full, upd: lax.dynamic_update_index_in_dim(
                    full, upd.astype(full.dtype), gi, axis=0),
                caches[p_i], nc))
        return (x, tuple(new_caches), work), None

    xs = ((tuple(params["blocks"]), n_groups_t, params["cross_attn"],
           enc_kv_all["k"], enc_kv_all["v"]) if cfg.encoder is not None
          else (tuple(params["blocks"]), n_groups_t))
    (x, new_caches, work), _ = lax.scan(
        group_body, (x, tuple(state["layers"]), work0), xs)

    new_state = dict(state)
    new_state["layers"] = list(new_caches)
    new_tail = []
    for t_i, blk in enumerate(params["tail"]):
        kind_t = kinds[n_groups * period + t_i]
        work = work + _blk_work(kind_t, state["tail"][t_i])
        x, nc = decode_block(ctx, cfg, kind_t, blk,
                             x, state["tail"][t_i], cache_len, scfg)
        new_tail.append(nc)
    new_state["tail"] = new_tail
    if scfg.track_work:
        new_state["work_blocks"] = state["work_blocks"] + work
    # LM-head/sampling tail: the prepacked Pallas path carries the
    # aliasing PackedHeadWeights bundle and runs the fused head kernel
    # (final norm + vocab-tiled logits + softcap + streaming top-k
    # partials, one tree k-merge reduce — no [B, V] logits in HBM);
    # otherwise the loose XLA tail builds the SAME sorted candidate set
    # from full logits (DESIGN.md §7).  Per-slot temperature / top-k /
    # top-p / PRNG finalize on the merged candidates, params riding
    # state["sampling"] (serving/sampling.py; greedy default = bit-
    # identical to the PR-5 (max, argmax) pair).
    samp = state["sampling"]
    head = params.get("head")
    if isinstance(head, df.PackedHeadWeights):
        cand_v, cand_i = _fused_head_tail(ctx, cfg, scfg, head, x)
    else:
        xh = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = lm_head_logits(ctx, table, xh)
        if cfg.logit_softcap:
            logits = softcap(logits, cfg.logit_softcap)
        cand_v, cand_i = head_candidates(ctx, logits)
    nxt, head_val = finalize_candidates(cand_v, cand_i, samp)
    new_state["sampling"] = advance_sampling_step(samp, cache_len >= 0)
    if scfg.check_finite:
        new_state["nonfinite"] = state["nonfinite"] + _finite_violations(
            cfg, x, head_val, nxt, cache_len >= 0)
    if scfg.kv_fingerprint:
        # incremental SDC checksums (serving/integrity.py): positions
        # whose per-row ``pos`` moved this step (append / ring wrap)
        # contribute their old→new bit-sum delta — the accumulator
        # tracks exactly what THIS program wrote, so a later host
        # mismatch is corruption, never drift
        from repro.serving.integrity import kv_fp_delta
        tracecount.bump("kv_fp_update")
        new_state["kv_fp"] = [
            kv_fp_delta(old, new, fp) if hasattr(old, "k") else fp
            for old, new, fp in zip(state["layers"], new_state["layers"],
                                    state["kv_fp"])]
        new_state["kv_fp_tail"] = [
            kv_fp_delta(old, new, fp) if hasattr(old, "k") else fp
            for old, new, fp in zip(state["tail"], new_state["tail"],
                                    state["kv_fp_tail"])]
    if scfg.shadow_head:
        # atomic (residual, winning logit, token) triple per slot — the
        # host shadow probe re-derives the logit from the residual with
        # a pristine head copy (serving/integrity.py)
        new_state["head_resid"] = x.astype(jnp.bfloat16)
        new_state["head_val"] = jnp.asarray(head_val, jnp.float32)
        new_state["head_tok"] = nxt.astype(jnp.int32)
    # only ACTIVE slots advance; free slots (−1) stay frozen until the
    # scheduler re-admits them via a prefill insert
    new_state["cache_lens"] = jnp.where(cache_len >= 0, cache_len + 1,
                                        cache_len)
    return nxt, new_state
