"""Prefill: run the prompt through the train-path forward, collect per-layer
KV (or recurrent states), and scatter them into the decode cache layout
(sequence blocks over the cluster sub-axis, ring layout for sliding-window
layers).  Returns the first generated token.

Supports per-slot ``lengths`` for the continuous-batching scheduler
(serving/scheduler.py): tokens arrive padded to one capacity, each slot
declares its true prompt length, and ``lengths[b] == 0`` means "do NOT
touch slot b" — its caches, recurrent state and cache_len ride through
unchanged.  That makes prefill a targeted *insert*: admitting requests
into free slots of a live decode state while the other slots' sequences
keep their KV (causal masking guarantees the padded tail never leaks
into positions < length).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (ATTN_LOCAL, RECURRENT, RWKV6,
                                ModelConfig)
from repro.core import dataflow as df
from repro.core import tracecount
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.ctx import ParallelCtx
from repro.models.layers import (EmbedParams, embed_lookup, ffn_apply,
                                 lm_head_logits, rms_norm, softcap)
from repro.models.moe import MoEParams, moe_apply
from repro.models.transformer import apply_block, encode, unwrap_local
from repro.serving.engine import (ServeConfig, _check_not_param_pair,
                                  _finite_violations)
from repro.serving.sampling import (admit_sampling_state,
                                    finalize_candidates, head_candidates)

PyTree = Any


def _merge_admitted(cache: df.KVBlock, k_new, v_new, pos_new,
                    adm: jax.Array) -> df.KVBlock:
    """Keep non-admitted slots' cache untouched (targeted insert).

    ``k_new``/``v_new``: [s_blk, B, R] flat per-slot rows; ``pos_new``
    [s_blk, B]; ``adm`` [B] bool."""
    s_blk, B = pos_new.shape
    old_k = cache.k.reshape(s_blk, B, -1)
    old_v = cache.v.reshape(s_blk, B, -1)
    m = adm[None, :, None]
    return df.KVBlock(
        k=jnp.where(m, k_new, old_k).reshape(cache.k.shape)
        .astype(cache.k.dtype),
        v=jnp.where(m, v_new, old_v).reshape(cache.v.shape)
        .astype(cache.v.dtype),
        pos=jnp.where(adm[None, :], pos_new, cache.pos).astype(jnp.int32))


def _fill_global(cache: df.KVBlock, kv: jax.Array, c_rank,
                 lens: jax.Array) -> df.KVBlock:
    """kv: [S, rows, hd] full-sequence values → this rank's seq block,
    per slot: slot b keeps positions < lens[b] (lens [B]; 0 ⇒ slot
    untouched)."""
    s_blk = cache.k.shape[0]
    B = lens.shape[0]
    idx = c_rank * s_blk + jnp.arange(s_blk)            # [s_blk]
    valid = idx[:, None] < lens[None, :]                # [s_blk, B]
    take = jnp.clip(idx, 0, kv[0].shape[0] - 1)
    k3 = kv[0].reshape(kv[0].shape[0], B, -1)[take]     # [s_blk, B, R]
    v3 = kv[1].reshape(kv[1].shape[0], B, -1)[take]
    pos = jnp.where(valid, idx[:, None], -1).astype(jnp.int32)
    return _merge_admitted(cache, jnp.where(valid[:, :, None], k3, 0),
                           jnp.where(valid[:, :, None], v3, 0), pos,
                           lens > 0)


def _fill_ring(cache: df.KVBlock, kv: jax.Array, c_rank,
               lens: jax.Array, window: int) -> df.KVBlock:
    """Sliding-window ring, per slot: ring slot s of batch slot b holds
    the largest p < lens[b] with p ≡ s (mod window)."""
    s_blk = cache.k.shape[0]
    B = lens.shape[0]
    base = c_rank * s_blk + jnp.arange(s_blk)           # global ring slot
    have = base[:, None] < lens[None, :]                # [s_blk, B]
    kwrap = jnp.maximum(lens[None, :] - 1 - base[:, None], 0) // window
    p = base[:, None] + kwrap * window                  # [s_blk, B]
    take = jnp.clip(p, 0, kv[0].shape[0] - 1)
    b_ix = jnp.arange(B)[None, :]
    k3 = kv[0].reshape(kv[0].shape[0], B, -1)[take, b_ix]  # [s_blk, B, R]
    v3 = kv[1].reshape(kv[1].shape[0], B, -1)[take, b_ix]
    pos = jnp.where(have, p, -1).astype(jnp.int32)
    return _merge_admitted(cache, jnp.where(have[:, :, None], k3, 0),
                           jnp.where(have[:, :, None], v3, 0), pos,
                           lens > 0)


def _merge_state(new_st, old_st, adm: jax.Array):
    """Per-slot merge of recurrent-state trees (batch at axis 0)."""
    def mb(n, o):
        m = adm.reshape((adm.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m, n.astype(o.dtype), o)
    return jax.tree.map(mb, new_st, old_st)


def _prefill_block(ctx: ParallelCtx, cfg: ModelConfig, kind: str,
                   blk: Dict[str, Any], x: jax.Array, cache, c_rank,
                   scfg: ServeConfig, lens: jax.Array,
                   enc_out=None, cross_blk=None):
    """Prefill one layer; returns (x, decode-ready cache).  ``lens [B]``
    is each slot's true prompt length (0 ⇒ keep the slot's cache)."""
    B, S, D = x.shape
    eps = cfg.norm_eps
    adm = lens > 0
    if kind == RWKV6:
        p = blk["rwkv"]
        h1 = rms_norm(x, blk["ln1"], eps)
        a, s_fin = rwkv_mod.rwkv6_time_mix(ctx, p, h1, cfg.rwkv_head_dim)
        x = x + a
        h2 = rms_norm(x, blk["ln2"], eps)
        c = rwkv_mod.rwkv6_channel_mix(ctx, p, h2)
        x = x + c
        st = cache._replace(s=s_fin.astype(cache.s.dtype),
                            x_prev_t=h1[:, -1], x_prev_c=h2[:, -1])
        return x, _merge_state(st, cache, adm)
    if kind == RECURRENT:
        p = blk["rglru"]
        h1 = rms_norm(x, blk["ln1"], eps)
        u = h1 @ p.w_x
        u_c = rglru_mod._causal_conv(p, u)
        h_seq = rglru_mod.rglru_scan(p, u_c)
        gate = jax.nn.gelu(h1 @ p.w_gate, approximate=True)
        a = ctx.psum_model((h_seq * gate) @ p.w_out)
        x = x + a
        width = p.conv_w.shape[0]
        st = cache._replace(h=h_seq[:, -1].astype(cache.h.dtype),
                            conv=u[:, S - width + 1:].astype(cache.conv.dtype))
        h2 = rms_norm(x, blk["ln2"], eps)
        f = (moe_apply(ctx, blk["ffn"], h2, cfg.ffn_act, cfg.moe)
             if isinstance(blk["ffn"], MoEParams)
             else ffn_apply(ctx, blk["ffn"], h2, cfg.ffn_act))
        return x + f, _merge_state(st, cache, adm)
    # attention layers: reuse the train block with KV collection
    x, kv = apply_block(ctx, cfg, kind, blk, x, return_kv=True,
                        enc_kv=enc_out, cross_blk=cross_blk)
    if cfg.mla is not None:
        c_seq = kv                                   # [B, S, l+rope]
        ckv = jnp.moveaxis(c_seq, 1, 0)              # [S, B, l+rope]
        newc = _fill_global(cache, (ckv, ckv[..., :1]), c_rank, lens)
        return x, newc
    k, v = kv                                        # [B, S, kv_loc, hd]
    rows = k.shape[0] * k.shape[2]
    ks = jnp.moveaxis(k, 1, 0).reshape(S, rows, k.shape[3])
    vs = jnp.moveaxis(v, 1, 0).reshape(S, rows, v.shape[3])
    if kind == ATTN_LOCAL:
        newc = _fill_ring(cache, (ks, vs), c_rank, lens,
                          cfg.sliding_window)
    else:
        newc = _fill_global(cache, (ks, vs), c_rank, lens)
    return x, newc


def prefill(ctx: ParallelCtx, cfg: ModelConfig, scfg: ServeConfig,
            params_dm: PyTree, state: Dict[str, Any], tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None, fsdp=None,
            lengths: Optional[jax.Array] = None,
            sampling: Optional[Dict[str, jax.Array]] = None
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens [B_loc, S_prompt] → (first generated token [B_loc], state).

    ``fsdp=(ax_tree, dp_axes)``: params arrive dp-sliced; non-stacked
    leaves gather here, scanned groups gather per group in the scan.

    ``lengths [B_loc]``: per-slot true prompt lengths for the targeted
    prefill-INSERT (continuous batching).  Slots with length 0 keep
    their existing caches, recurrent state and cache_len; admitted
    slots sample their first token from position ``length − 1``.
    Default (None) = every slot uses the full ``S_prompt``.  Partial
    admission is attention-only: recurrent (RG-LRU / RWKV-6) scans and
    encoder K/V would fold the padded tail into their final state.

    ``sampling``: per-slot [B] sampling-param rows (the
    ``state["sampling"]`` leaf layout — serving/sampling.py), written
    adm-masked into the state BEFORE the first token samples, so a
    request's very first emission already uses its own temperature /
    top-k / top-p / seed at emit offset 0.  Default (None) keeps the
    state's current leaves (greedy defaults ⇒ bit-identical to the
    PR-5 greedy prefill).
    """
    _check_not_param_pair(params_dm, "train")
    params = unwrap_local(params_dm)
    if fsdp is not None:
        from repro.models.transformer import fsdp_gather, fsdp_gather_top
        params = fsdp_gather_top(params, *fsdp)
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    B, S = tokens.shape
    c_rank = ctx.cluster_index()
    partial = lengths is not None
    if partial:
        lengths = jnp.asarray(lengths, jnp.int32)
        assert cfg.encoder is None and not any(
            k in (RECURRENT, RWKV6) for k in kinds), \
            "per-slot prefill insert supports attention-only models"
    else:
        lengths = jnp.full((B,), S, jnp.int32)

    x = embed_lookup(ctx, EmbedParams(params["embed"]), tokens)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.frontend is not None and cfg.encoder is None:
        fe = frontend_embeds.astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([fe, x[:, fe.shape[1]:]], axis=1)

    enc_out = None
    new_state = dict(state)
    if cfg.encoder is not None:
        enc_out = encode(ctx, cfg, params, frontend_embeds, remat=False)
        # project per-layer cross K/V once; store for decode
        ca = params["cross_attn"]

        def proj_kv(ca_l):
            p = ca_l["attn"]
            k = jnp.einsum("bpd,dkh->bpkh", enc_out, p.wk)
            v = jnp.einsum("bpd,dkh->bpkh", enc_out, p.wv)
            k = ctx.gather_cluster(k, axis=3)
            v = ctx.gather_cluster(v, axis=3)
            P = k.shape[1]
            return (jnp.moveaxis(k, 1, 0).reshape(P, -1, k.shape[3]),
                    jnp.moveaxis(v, 1, 0).reshape(P, -1, v.shape[3]))

        eks, evs = jax.vmap(proj_kv)(ca)
        new_state["enc_kv"] = {"k": eks.astype(jnp.bfloat16),
                               "v": evs.astype(jnp.bfloat16)}

    def group_body(x, inp):
        if cfg.encoder is not None:
            blks, caches, ca_l = inp
        else:
            blks, caches = inp
            ca_l = None
        if fsdp is not None:
            from repro.models.transformer import fsdp_gather
            ax, dpa = fsdp
            blks = tuple(fsdp_gather(b, a, dpa, in_scan=True)
                         for b, a in zip(blks, ax["blocks"]))
            if ca_l is not None:
                ca_l = fsdp_gather(ca_l, ax["cross_attn"], dpa, in_scan=True)
        new_caches = []
        for p_i in range(period):
            x, nc = _prefill_block(ctx, cfg, kinds[p_i], blks[p_i], x,
                                   caches[p_i], c_rank, scfg, lengths,
                                   enc_out=enc_out, cross_blk=ca_l)
            new_caches.append(nc)
        return x, tuple(new_caches)

    xs = ((tuple(params["blocks"]), tuple(state["layers"]),
           params["cross_attn"]) if cfg.encoder is not None
          else (tuple(params["blocks"]), tuple(state["layers"])))
    x, new_caches = lax.scan(group_body, x, xs)
    new_state["layers"] = list(new_caches)
    new_tail = []
    for t_i, blk in enumerate(params["tail"]):
        x, nc = _prefill_block(ctx, cfg, kinds[n_groups * period + t_i],
                               blk, x, state["tail"][t_i], c_rank, scfg,
                               lengths)
        new_tail.append(nc)
    new_state["tail"] = new_tail

    # each slot samples from its own last REAL position (length − 1);
    # the raw (pre-norm) residual row is kept for the shadow-recompute
    # stash — RMSNorm is rowwise, so select-then-norm is bit-identical
    # to norm-then-select
    last_raw = x[jnp.arange(B), jnp.clip(lengths, 1, S) - 1]
    last = rms_norm(last_raw, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = lm_head_logits(ctx, table, last)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    adm = lengths > 0
    # per-slot sampling params land BEFORE the first emission: the admit
    # rows arrive with emit offset 0, so the first token's PRNG key is
    # fold_in(PRNGKey(seed), 0) — the offset journal replay re-derives
    samp = state["sampling"]
    if sampling is not None:
        samp = admit_sampling_state(samp, sampling, adm)
    cand_v, cand_i = head_candidates(ctx, logits)
    nxt, head_val = finalize_candidates(cand_v, cand_i, samp)
    # admitted slots advance to emit offset 1; untouched slots keep
    # their offset (they did not emit this call)
    new_state["sampling"] = dict(samp, step=jnp.where(
        adm, jnp.int32(1), samp["step"]))
    new_state["cache_lens"] = jnp.where(adm, lengths,
                                        state["cache_lens"])
    if "work_blocks" in state:       # admitted slots start a fresh count
        new_state["work_blocks"] = jnp.where(
            adm, 0, state["work_blocks"]).astype(jnp.int32)
    if scfg.check_finite and "nonfinite" in state:
        # guard the ADMIT path too: a one-token request can admit and
        # retire in the same tick with no decode step in between, so a
        # poisoned first token must trip the sentinel here.  Admitted
        # slots restart their violation count.
        new_state["nonfinite"] = jnp.where(
            adm, _finite_violations(cfg, last, head_val, nxt, adm),
            state["nonfinite"]).astype(jnp.int32)
    if scfg.kv_fingerprint and "kv_fp" in state:
        # admitted slots' checksums recompute FROM SCRATCH: a re-admit
        # into a previously-used slot can rewrite rows without moving
        # their ``pos`` entries (same positions, different prompt), so
        # the decode path's pos-masked delta cannot see it — the full
        # per-slot sum here re-anchors the accumulator exactly
        from repro.serving.integrity import kv_entry_fp
        tracecount.bump("kv_fp_update")

        def _refp(cache, fp):
            if not hasattr(cache, "k"):
                return fp
            return jnp.where(adm, kv_entry_fp(cache, B),
                             fp).astype(jnp.int32)

        new_state["kv_fp"] = [
            _refp(c, f) for c, f in zip(new_state["layers"],
                                        state["kv_fp"])]
        new_state["kv_fp_tail"] = [
            _refp(c, f) for c, f in zip(new_state["tail"],
                                        state["kv_fp_tail"])]
    if scfg.shadow_head and "head_resid" in state:
        new_state["head_resid"] = jnp.where(
            adm[:, None], last_raw.astype(jnp.bfloat16),
            state["head_resid"])
        new_state["head_val"] = jnp.where(
            adm, jnp.asarray(head_val, jnp.float32), state["head_val"])
        new_state["head_tok"] = jnp.where(adm, nxt.astype(jnp.int32),
                                          state["head_tok"])
    return nxt, new_state
