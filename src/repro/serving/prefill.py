"""Prefill: run the prompt through the train-path forward, collect per-layer
KV (or recurrent states), and scatter them into the decode cache layout
(sequence blocks over the cluster sub-axis, ring layout for sliding-window
layers).  Returns the first generated token.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV6,
                                ModelConfig)
from repro.core import dataflow as df
from repro.models import attention as attn_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.ctx import ParallelCtx
from repro.models.layers import (EmbedParams, embed_lookup, ffn_apply,
                                 lm_head_logits, rms_norm, softcap)
from repro.models.moe import MoEParams, moe_apply
from repro.models.transformer import (apply_block, cross_attention, encode,
                                      unwrap_local)
from repro.serving.engine import ServeConfig, greedy_sample

PyTree = Any


def _fill_global(cache: df.KVBlock, kv: jax.Array, c_rank, s_prompt: int
                 ) -> df.KVBlock:
    """kv: [S, rows, hd] full-sequence values → this rank's seq block."""
    s_blk = cache.k.shape[0]
    idx = c_rank * s_blk + jnp.arange(s_blk)
    valid = idx < s_prompt
    take = jnp.clip(idx, 0, s_prompt - 1)
    pos = jnp.where(valid, idx, -1).astype(jnp.int32)
    return df.KVBlock(
        k=jnp.where(valid[:, None, None], kv[0][take], 0).astype(cache.k.dtype),
        v=jnp.where(valid[:, None, None], kv[1][take], 0).astype(cache.v.dtype),
        pos=pos)


def _fill_ring(cache: df.KVBlock, kv: jax.Array, c_rank, s_prompt: int,
               window: int) -> df.KVBlock:
    """Sliding-window ring: slot s holds the largest p < s_prompt with
    p ≡ s (mod window)."""
    s_blk = cache.k.shape[0]
    base = c_rank * s_blk + jnp.arange(s_blk)          # global slot index
    have = base < s_prompt
    kwrap = jnp.maximum(s_prompt - 1 - base, 0) // window
    p = base + kwrap * window
    take = jnp.clip(p, 0, s_prompt - 1)
    pos = jnp.where(have, p, -1).astype(jnp.int32)
    return df.KVBlock(
        k=jnp.where(have[:, None, None], kv[0][take], 0).astype(cache.k.dtype),
        v=jnp.where(have[:, None, None], kv[1][take], 0).astype(cache.v.dtype),
        pos=pos)


def _prefill_block(ctx: ParallelCtx, cfg: ModelConfig, kind: str,
                   blk: Dict[str, Any], x: jax.Array, cache, c_rank,
                   scfg: ServeConfig, enc_out=None, cross_blk=None):
    """Prefill one layer; returns (x, decode-ready cache)."""
    B, S, D = x.shape
    eps = cfg.norm_eps
    if kind == RWKV6:
        p = blk["rwkv"]
        h1 = rms_norm(x, blk["ln1"], eps)
        a, s_fin = rwkv_mod.rwkv6_time_mix(ctx, p, h1, cfg.rwkv_head_dim)
        x = x + a
        h2 = rms_norm(x, blk["ln2"], eps)
        c = rwkv_mod.rwkv6_channel_mix(ctx, p, h2)
        x = x + c
        st = cache._replace(s=s_fin.astype(cache.s.dtype),
                            x_prev_t=h1[:, -1], x_prev_c=h2[:, -1])
        return x, st
    if kind == RECURRENT:
        p = blk["rglru"]
        h1 = rms_norm(x, blk["ln1"], eps)
        u = h1 @ p.w_x
        u_c = rglru_mod._causal_conv(p, u)
        h_seq = rglru_mod.rglru_scan(p, u_c)
        gate = jax.nn.gelu(h1 @ p.w_gate, approximate=True)
        a = ctx.psum_model((h_seq * gate) @ p.w_out)
        x = x + a
        width = p.conv_w.shape[0]
        st = cache._replace(h=h_seq[:, -1].astype(cache.h.dtype),
                            conv=u[:, S - width + 1:].astype(cache.conv.dtype))
        h2 = rms_norm(x, blk["ln2"], eps)
        f = (moe_apply(ctx, blk["ffn"], h2, cfg.ffn_act, cfg.moe)
             if isinstance(blk["ffn"], MoEParams)
             else ffn_apply(ctx, blk["ffn"], h2, cfg.ffn_act))
        return x + f, st
    # attention layers: reuse the train block with KV collection
    x, kv = apply_block(ctx, cfg, kind, blk, x, return_kv=True,
                        enc_kv=enc_out, cross_blk=cross_blk)
    if cfg.mla is not None:
        c_seq = kv                                   # [B, S, l+rope]
        ckv = jnp.moveaxis(c_seq, 1, 0)              # [S, B, l+rope]
        newc = _fill_global(cache, (ckv, ckv[..., :1]), c_rank, S)
        return x, newc
    k, v = kv                                        # [B, S, kv_loc, hd]
    rows = k.shape[0] * k.shape[2]
    ks = jnp.moveaxis(k, 1, 0).reshape(S, rows, k.shape[3])
    vs = jnp.moveaxis(v, 1, 0).reshape(S, rows, v.shape[3])
    if kind == ATTN_LOCAL:
        newc = _fill_ring(cache, (ks, vs), c_rank, S, cfg.sliding_window)
    else:
        newc = _fill_global(cache, (ks, vs), c_rank, S)
    return x, newc


def prefill(ctx: ParallelCtx, cfg: ModelConfig, scfg: ServeConfig,
            params_dm: PyTree, state: Dict[str, Any], tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None, fsdp=None
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens [B_loc, S_prompt] → (first generated token [B_loc], state).

    ``fsdp=(ax_tree, dp_axes)``: params arrive dp-sliced; non-stacked
    leaves gather here, scanned groups gather per group in the scan."""
    params = unwrap_local(params_dm)
    if fsdp is not None:
        from repro.models.transformer import fsdp_gather, fsdp_gather_top
        params = fsdp_gather_top(params, *fsdp)
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    B, S = tokens.shape
    c_rank = ctx.cluster_index()

    x = embed_lookup(ctx, EmbedParams(params["embed"]), tokens)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.frontend is not None and cfg.encoder is None:
        fe = frontend_embeds.astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([fe, x[:, fe.shape[1]:]], axis=1)

    enc_out = None
    new_state = dict(state)
    if cfg.encoder is not None:
        enc_out = encode(ctx, cfg, params, frontend_embeds, remat=False)
        # project per-layer cross K/V once; store for decode
        ca = params["cross_attn"]

        def proj_kv(ca_l):
            p = ca_l["attn"]
            k = jnp.einsum("bpd,dkh->bpkh", enc_out, p.wk)
            v = jnp.einsum("bpd,dkh->bpkh", enc_out, p.wv)
            k = ctx.gather_cluster(k, axis=3)
            v = ctx.gather_cluster(v, axis=3)
            P = k.shape[1]
            return (jnp.moveaxis(k, 1, 0).reshape(P, -1, k.shape[3]),
                    jnp.moveaxis(v, 1, 0).reshape(P, -1, v.shape[3]))

        eks, evs = jax.vmap(proj_kv)(ca)
        new_state["enc_kv"] = {"k": eks.astype(jnp.bfloat16),
                               "v": evs.astype(jnp.bfloat16)}

    def group_body(x, inp):
        if cfg.encoder is not None:
            blks, caches, ca_l = inp
        else:
            blks, caches = inp
            ca_l = None
        if fsdp is not None:
            from repro.models.transformer import fsdp_gather
            ax, dpa = fsdp
            blks = tuple(fsdp_gather(b, a, dpa, in_scan=True)
                         for b, a in zip(blks, ax["blocks"]))
            if ca_l is not None:
                ca_l = fsdp_gather(ca_l, ax["cross_attn"], dpa, in_scan=True)
        new_caches = []
        for p_i in range(period):
            x, nc = _prefill_block(ctx, cfg, kinds[p_i], blks[p_i], x,
                                   caches[p_i], c_rank, scfg,
                                   enc_out=enc_out, cross_blk=ca_l)
            new_caches.append(nc)
        return x, tuple(new_caches)

    xs = ((tuple(params["blocks"]), tuple(state["layers"]),
           params["cross_attn"]) if cfg.encoder is not None
          else (tuple(params["blocks"]), tuple(state["layers"])))
    x, new_caches = lax.scan(group_body, x, xs)
    new_state["layers"] = list(new_caches)
    new_tail = []
    for t_i, blk in enumerate(params["tail"]):
        x, nc = _prefill_block(ctx, cfg, kinds[n_groups * period + t_i],
                               blk, x, state["tail"][t_i], c_rank, scfg)
        new_tail.append(nc)
    new_state["tail"] = new_tail

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1]
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = lm_head_logits(ctx, table, last)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    nxt = greedy_sample(ctx, logits)
    new_state["cache_len"] = jnp.asarray(S, jnp.int32)
    return nxt, new_state
