"""Systematic SDC fault-load sweeps (DAVOS-style) over the fleet router.

One :class:`~repro.serving.faults.FaultSweep` grid enumerates
single-bit (kind × target × bit × step × replica) fault specs; this
harness runs each spec in its OWN router run against the same engine
fleet and reduces the outcomes into a coverage matrix:

* ``fault_free`` — the control row: every probe enabled, zero faults.
  Gated to ZERO detection signals (no false positives), token streams
  byte-equal to the probes-off oracle, and the per-tick probe overhead
  in bytes (the tracecount probe counters divided by probe ticks).
* ``{kind}_bit{b}`` — one row per (fault kind, bit position):
  ``detected_pct`` (did any probe fire), ``detect_steps`` (worst
  injection→detection latency in router ticks over the row's grid
  points) and ``oracle_exact_pct`` (after recovery, are ALL journaled
  streams byte-equal to the fault-free oracle — the zero-corruption
  invariant under SDC).

Engines are restored between runs: a fresh :class:`Router` rebuilds
every scheduler (which resets device state from ``eng.state``), and the
persistent ``flip_weight_bit`` corruption is undone by re-materializing
the serve layout from the train view (``EngineHandle.repack_fn`` — the
same path the router's heal uses).

The matrix feeds ``bench_tpot.py --trace`` (the ``sdc_sweep`` cell
namespace, gated by scripts/check_bench.py) and
``examples/serve_requests.py --sweep`` (:func:`format_coverage`).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import tracecount
from repro.serving.faults import FaultInjector, FaultSweep
from repro.serving.integrity import IntegrityConfig
from repro.serving.router import Router
from repro.serving.scheduler import Request


def _streams(journal) -> Dict[int, Tuple[int, ...]]:
    return {rid: tuple(e.tokens) for rid, e in journal.items()}


def run_sdc_sweep(engines, *, prompts: Sequence[Sequence[int]],
                  max_new: int, prompt_cap: int,
                  sweep: Optional[FaultSweep] = None,
                  icfg: Optional[IntegrityConfig] = None,
                  max_requeues: Optional[int] = None,
                  max_ticks: int = 10_000) -> Dict[str, Dict[str, float]]:
    """Run the grid; returns the coverage matrix as ``{row: {column:
    value}}`` (see the module docstring for the rows/columns).

    ``prompts`` seeds one request per prompt, all arriving at tick 0 —
    the SAME trace for the oracle, the control and every fault run, so
    stream comparisons are byte-for-byte meaningful.
    """
    sweep = sweep if sweep is not None else FaultSweep()
    icfg = icfg if icfg is not None else IntegrityConfig()

    def trace() -> List[Tuple[int, Request]]:
        return [(0, Request(i, list(p), max_new))
                for i, p in enumerate(prompts)]

    def restore() -> None:
        for eng in engines:
            if eng.repack_fn is not None:
                eng.params["serve"] = eng.repack_fn(eng.params["train"])

    # 1. the oracle: no probes, no faults — ground-truth streams
    oracle = _streams(Router(engines, prompt_cap=prompt_cap,
                             max_new_cap=max_new).run(trace(),
                                                      max_ticks=max_ticks))

    # 2. the control: every probe on, no faults — the false-positive
    #    and probe-overhead row
    tracecount.reset_signals()
    tracecount.reset_probes()
    ctl = _streams(Router(engines, prompt_cap=prompt_cap,
                          max_new_cap=max_new, integrity=icfg)
                   .run(trace(), max_ticks=max_ticks))
    sig = sum(tracecount.signal_totals().values())
    pt = tracecount.probe_totals()
    per_tick = (pt["probe_bytes_kv"] + pt["probe_bytes_weights"]
                + pt["probe_bytes_shadow"]) / max(pt["probe_ticks"], 1)
    cells: Dict[str, Dict[str, float]] = {"fault_free": {
        "false_positive_signals": float(sig),
        "streams_match": float(ctl == oracle),
        "probe_bytes_per_tick": float(per_tick),
    }}

    # 3. the grid: one spec per run, engines restored in between
    agg: Dict[str, List[Tuple[bool, int, bool]]] = {}
    for spec in sweep.specs():
        inj = FaultInjector([spec])
        tracecount.reset_signals()
        router = Router(engines, prompt_cap=prompt_cap,
                        max_new_cap=max_new, integrity=icfg,
                        max_requeues=max_requeues,
                        injectors={spec.replica: inj})
        journal = router.run(trace(), max_ticks=max_ticks)
        lat = router.detection_latency(inj)
        detected = bool(lat) and lat[0] >= 0
        exact = _streams(journal) == oracle
        agg.setdefault(f"{spec.kind}_bit{spec.bit}", []).append(
            (detected, lat[0] if detected else -1, exact))
        restore()

    for key, rows in agg.items():
        lats = [l for d, l, _ in rows if d]
        cells[key] = {
            "detected_pct": 100.0 * sum(d for d, _, _ in rows) / len(rows),
            "detect_steps": float(max(lats)) if lats else -1.0,
            "oracle_exact_pct":
                100.0 * sum(e for _, _, e in rows) / len(rows),
        }
    return cells


def format_coverage(cells: Dict[str, Dict[str, float]]) -> str:
    """Human-readable coverage table (examples/serve_requests.py
    --sweep and the nightly CI artifact)."""
    lines = [f"{'cell':<28} {'detected%':>9} {'latency(ticks)':>14} "
             f"{'oracle-exact%':>13}"]
    for key in sorted(k for k in cells if k != "fault_free"):
        c = cells[key]
        lines.append(f"{key:<28} {c['detected_pct']:>9.1f} "
                     f"{c['detect_steps']:>14.0f} "
                     f"{c['oracle_exact_pct']:>13.1f}")
    ff = cells.get("fault_free")
    if ff is not None:
        lines.append(
            f"{'fault_free':<28} signals={ff['false_positive_signals']:.0f} "
            f"streams_match={ff['streams_match']:.0f} "
            f"probe_bytes/tick={ff['probe_bytes_per_tick']:.0f}")
    return "\n".join(lines)
