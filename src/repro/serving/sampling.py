"""Per-request sampling over the fused head's merged top-k candidates.

The fused LM-head tail streams each vocab shard into a ``[B, CAND_K]``
(value, global index) candidate set and merges shards with ONE
commutative k-merge ClusterReduce (``kernels.fused_head.topk``) — the
``[B, V]`` logits never exist.  Everything stochastic happens HERE, on
those k merged candidates, per slot:

* :class:`SamplingParams` is the frozen per-request surface
  (``temperature`` / ``top_k`` / ``top_p`` / ``seed``); the greedy
  default makes every pre-existing token-exact test pass unchanged.
* The per-slot params ride the decode state as ``state["sampling"]``
  — five ``[B]`` leaves, exactly like ``cache_lens`` rides the batched
  scalar-prefetch operand — so one ragged batch serves heterogeneous
  sampling configs and the jitted decode signature never changes.
* The PRNG stream is *positional*: slot ``b``'s key for its ``n``-th
  emitted token is ``fold_in(PRNGKey(seed_b), n)`` — a pure function of
  (seed, emit index), NOT of device history.  Fleet recovery replays a
  journaled stream on a survivor replica with the same seed and the
  same emit offsets, so the reconstructed stochastic stream is
  bit-exact (DESIGN.md §9; the router journals ``sampling`` per
  request).
* ``finalize_candidates`` applies temperature → top-k (a rank mask —
  candidates arrive sorted value-descending) → top-p (keep while the
  cumulative probability BEFORE a candidate is < p; rank 0 always
  kept) → Gumbel-max categorical.  ``temperature == 0`` bypasses the
  PRNG entirely and takes candidate 0 — bit-identical to the PR-5
  greedy tail.

Exactness contract (DESIGN.md §8 pt 0, extended to k pairs): the fused
and unfused paths build the SAME sorted candidate set (`select_topk`
is one definition shared by the Pallas kernel, the jnp oracle and the
shard merge), and the finalize is common code — so fused
temperature/top-k/top-p decode is token-exact against a
``fuse_head=False`` oracle under a forced PRNG stream, for any top_k ≤
``CAND_K`` and top-p restricted to the ``CAND_K`` candidates.

The greedy helpers (``greedy_sample`` / ``greedy_sample_pair`` and the
pair-merge operator) moved here from ``serving.engine`` (PR-5);
``engine`` re-exports them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import primitives as prim
from repro.kernels.fused_head.topk import select_topk, topk_pair_merge
from repro.models.ctx import ParallelCtx

# Width of the streaming candidate partials — every fused-head launch
# selects this many (value, index) pairs per slot regardless of the
# per-slot params (the merge operator and the ICI byte model are sized
# by it; autotune's block_v VMEM model carries the matching k term).
# top_k > CAND_K is rejected at submit: the fused tail only ever holds
# CAND_K candidates, and silently truncating would break the
# fused-vs-oracle exactness contract.
CAND_K = 8


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling surface (``Request.sampling``).

    ``temperature == 0`` is greedy (candidate 0; the PRNG is bypassed).
    ``top_k`` restricts sampling to the best ``top_k`` of the fused
    head's ``CAND_K`` candidates (1 ≤ top_k ≤ CAND_K; the default keeps
    all of them).  ``top_p`` is nucleus sampling over those candidates
    (the best candidate is always kept).  ``seed`` anchors the
    positional PRNG stream — journaled by the fleet router so recovery
    reconstructs sampled streams bit-exactly."""
    temperature: float = 0.0
    top_k: int = CAND_K
    top_p: float = 1.0
    seed: int = 0


GREEDY = SamplingParams()


def validate_sampling(rid: int, sp: SamplingParams) -> None:
    """Reject out-of-range params, naming the offending field (the PR-7
    ``submit()`` validation style — shared by the scheduler and the
    fleet router)."""
    if sp.temperature < 0:
        raise ValueError(
            f"request {rid}: temperature must be ≥ 0 "
            f"(got {sp.temperature})")
    if sp.top_k < 1:
        raise ValueError(
            f"request {rid}: top_k must be ≥ 1 (got {sp.top_k})")
    if sp.top_k > CAND_K:
        raise ValueError(
            f"request {rid}: top_k must be ≤ the fused head's candidate "
            f"width CAND_K={CAND_K} (got {sp.top_k})")
    if not 0.0 < sp.top_p <= 1.0:
        raise ValueError(
            f"request {rid}: top_p must be in (0, 1] (got {sp.top_p})")


# ---------------------------------------------------------------------------
# The per-slot params as decode-state leaves ([B] each, like cache_lens)
# ---------------------------------------------------------------------------
SAMPLING_LEAVES = ("temp", "topk", "topp", "seed", "step")
_LEAF_DTYPES = {"temp": jnp.float32, "topk": jnp.int32,
                "topp": jnp.float32, "seed": jnp.uint32,
                "step": jnp.int32}
_LEAF_DEFAULTS = {"temp": 0.0, "topk": CAND_K, "topp": 1.0,
                  "seed": 0, "step": 0}


def init_sampling_state(batch: int) -> Dict[str, jax.Array]:
    """Greedy-default ``state["sampling"]`` leaves.  ``step`` counts
    emitted tokens per slot — the emit offset the positional PRNG folds
    in (0 = the admit emission)."""
    return {name: jnp.full((batch,), _LEAF_DEFAULTS[name],
                           _LEAF_DTYPES[name])
            for name in SAMPLING_LEAVES}


def reset_sampling_state(samp: Dict[str, jax.Array], mask: jax.Array
                         ) -> Dict[str, jax.Array]:
    """Retire: masked slots return to the greedy defaults."""
    return {name: jnp.where(mask, jnp.asarray(_LEAF_DEFAULTS[name],
                                              v.dtype), v)
            for name, v in samp.items()}


def admit_sampling_state(samp: Dict[str, jax.Array],
                         incoming: Dict[str, jax.Array],
                         adm: jax.Array) -> Dict[str, jax.Array]:
    """Targeted insert: admitted slots take the incoming per-request
    params (host-built arrays, ``step`` 0); others ride through."""
    return {name: jnp.where(adm, incoming[name].astype(v.dtype), v)
            for name, v in samp.items()}


def host_sampling_rows(batch: int) -> Dict[str, np.ndarray]:
    """Host-side greedy-default admit rows; the scheduler overwrites the
    admitted slots' entries from each request's ``SamplingParams``."""
    return {name: np.full((batch,), _LEAF_DEFAULTS[name],
                          np.dtype(_LEAF_DTYPES[name]))
            for name in SAMPLING_LEAVES}


def fill_sampling_row(rows: Dict[str, np.ndarray], b: int,
                      sp: SamplingParams) -> None:
    rows["temp"][b] = sp.temperature
    rows["topk"][b] = sp.top_k
    rows["topp"][b] = sp.top_p
    rows["seed"][b] = np.uint32(sp.seed)
    rows["step"][b] = 0


# ---------------------------------------------------------------------------
# Candidate construction (the unfused oracle half) and the shard merge
# ---------------------------------------------------------------------------
def head_candidates(ctx: ParallelCtx, logits_loc: jax.Array,
                    k: int = CAND_K) -> Tuple[jax.Array, jax.Array]:
    """Unfused tail: top-k over vocab-sharded FULL logits → the same
    sorted ``(values [B, k], global_indices [B, k])`` candidate set the
    fused kernel streams — local ``select_topk``, lift to global vocab
    (``+ shard · V_loc``), ONE tree ClusterReduce with the commutative
    k-merge.  Shared selection + shared merge ⇒ fused ≡ unfused
    candidates bit-for-bit (DESIGN.md §8 pt 0 at width k)."""
    v_loc = logits_loc.shape[-1]
    lf = logits_loc.astype(jnp.float32)
    ids = jnp.broadcast_to(jnp.arange(v_loc, dtype=jnp.int32), lf.shape)
    lv, li = select_topk(lf, ids, k)
    li = li + ctx.model_index().astype(jnp.int32) * v_loc
    if ctx.model is None:
        return lv, li
    return prim.cluster_reduce_pairs((lv, li), ctx.model, topk_pair_merge)


# ---------------------------------------------------------------------------
# Finalize: temperature / top-k / top-p / Gumbel-max on the k candidates
# ---------------------------------------------------------------------------
def finalize_candidates(vals: jax.Array, ids: jax.Array,
                        samp: Dict[str, jax.Array]
                        ) -> Tuple[jax.Array, jax.Array]:
    """``(values [B, K] sorted desc, global_indices [B, K], sampling
    leaves)`` → ``(token [B] int32, head_val [B] f32)``.

    ``head_val`` is the chosen token's RAW (pre-temperature) merged
    logit — the value the ``check_finite`` sentinel tests and the
    shadow-head probe re-derives against a pristine head copy
    (serving/integrity.py), identical in meaning to the greedy pair's
    max logit.

    Every rank runs this on identical (replicated) candidates and
    leaves, so ranks agree on the token without further collectives.
    The Gumbel key is ``fold_in(PRNGKey(seed_b), step_b)`` — positional,
    so journal replay re-derives the identical stream on any replica.
    """
    B, K = vals.shape
    temp = samp["temp"]
    rank = jnp.broadcast_to(jnp.arange(K, dtype=jnp.int32), (B, K))
    # top-k: candidates are sorted value-descending, so the mask is a
    # pure rank comparison
    keep = rank < jnp.clip(samp["topk"], 1, K)[:, None]
    scaled = vals / jnp.maximum(temp, 1e-6)[:, None]
    scaled = jnp.where(keep, scaled, -jnp.inf)
    # top-p (nucleus) on the surviving sorted candidates: keep while the
    # cumulative probability BEFORE the candidate is < p; rank 0 always
    # survives so the distribution is never empty
    probs = jax.nn.softmax(scaled, axis=-1)
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    keep_p = (cum_before < samp["topp"][:, None]) | (rank == 0)
    scaled = jnp.where(keep_p, scaled, -jnp.inf)

    def _gumbel(seed_b, step_b):
        key = jax.random.fold_in(jax.random.PRNGKey(seed_b), step_b)
        return jax.random.gumbel(key, (K,), jnp.float32)

    gum = jax.vmap(_gumbel)(samp["seed"], samp["step"])
    choice = jnp.argmax(scaled + gum, axis=-1).astype(jnp.int32)
    # temperature 0: bypass the PRNG, take candidate 0 (bit-identical
    # to the greedy (max, argmax) pair)
    j = jnp.where(temp > 0, choice, 0)
    tok = jnp.take_along_axis(ids, j[:, None], axis=-1)[:, 0]
    val = jnp.take_along_axis(vals, j[:, None], axis=-1)[:, 0]
    return tok.astype(jnp.int32), val


def advance_sampling_step(samp: Dict[str, jax.Array], active: jax.Array
                          ) -> Dict[str, jax.Array]:
    """Active slots' emit offset advances by one (free slots frozen) —
    the decode-step counterpart of ``cache_lens + 1``."""
    return dict(samp, step=jnp.where(active, samp["step"] + 1,
                                     samp["step"]))


# ---------------------------------------------------------------------------
# Greedy pair reduce (moved verbatim from serving.engine, PR 5)
# ---------------------------------------------------------------------------
def _greedy_pair_merge(a, b):
    """THE (value, index) reduce operator for greedy sampling: maximum
    value, LOWEST global index among equal maxima.

    The index tie-break makes the operator commutative as well as
    associative, so every rank's tree association order yields the same
    winner — without it, equal-max logits on different vocab shards
    made ranks DISAGREE on the sampled token (each rank's tree folds
    the shards in a different order, and a first-argument-wins tie kept
    a different shard per rank).  One definition on purpose: the fused
    head tail must reproduce ``greedy_sample`` exactly, and a divergent
    copy would be a silent cross-path token mismatch on ties.  This IS
    ``topk.select_topk``'s total order at k = 1; the k-wide merge
    (``topk.topk_pair_merge``) generalizes it verbatim.
    """
    mv, mi = a
    nv, ni = b
    take_b = (nv > mv) | ((nv == mv) & (ni < mi))
    return jnp.where(take_b, nv, mv), jnp.where(take_b, ni, mi)


def greedy_sample_pair(ctx: ParallelCtx, logits_loc: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Greedy over vocab-sharded logits, returning BOTH halves of the
    reduced (max_value, argmax_global_index) pair: the index is the
    sampled token, the max logit is the cheapest per-slot health value
    the ``check_finite`` sentinel can test (a NaN anywhere in a slot's
    logits surfaces in its max under IEEE max-with-NaN or upstream in
    the residual check).  Ties pick the lowest global index on every
    rank (:func:`_greedy_pair_merge`)."""
    v_loc = logits_loc.shape[-1]
    shard = ctx.model_index()
    lf = logits_loc.astype(jnp.float32)
    loc_max = jnp.max(lf, axis=-1)
    loc_idx = jnp.argmax(lf, axis=-1).astype(jnp.int32) + shard * v_loc
    if ctx.model is None:
        return loc_idx, loc_max
    mx, idx = prim.cluster_reduce_pairs((loc_max, loc_idx), ctx.model,
                                        _greedy_pair_merge)
    return idx, mx


def greedy_sample(ctx: ParallelCtx, logits_loc: jax.Array) -> jax.Array:
    """Greedy over vocab-sharded logits: pair-wise tree reduce on
    (max_value, argmax_global_index); ties pick the lowest global index
    on every rank (:func:`_greedy_pair_merge`)."""
    return greedy_sample_pair(ctx, logits_loc)[0]


Sampling = Dict[str, Any]   # the state["sampling"] leaf dict
