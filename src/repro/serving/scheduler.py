"""Slot-based continuous batching over the ragged decode engine.

The decode step is RAGGED (per-slot ``cache_lens`` — serving/engine.py,
DESIGN.md §6), so the batch no longer advances in lockstep: this module
runs the request-level loop on top of it.  The engine's ``B`` batch rows
become **slots** with a lifecycle::

    FREE (cache_lens = −1)
      └─ admit ──▶ ACTIVE   targeted prefill-insert at the slot's offset
                            (EngineHandle.admit_fn; one jitted call admits
                            every request picked this tick, and emits each
                            request's FIRST token)
    ACTIVE ── decode ──▶    one ragged decode step per tick advances ALL
                            active slots (per-slot RoPE position, append
                            slot, live-span cull; free slots do zero
                            attend-step work — state["work_blocks"])
      └─ retire ──▶ FREE    on EOS or max_new (EngineHandle.retire_fn);
                            the slot is immediately re-admittable

Scheduling policy (deterministic, mirrored by the pure-Python reference
simulator in tests/test_scheduler.py): arrivals enqueue FIFO; each tick
admits queue-head requests into the lowest-numbered free slots, retires
any one-token requests, runs one decode step for the active slots, then
retires finished ones.

The driver is host-side Python issuing three jitted programs (admit /
decode / retire) — the decode hot loop itself stays ONE fused dispatch
per token, exactly the paper's fusion story; continuous batching only
changes which slots carry live work.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.launch.serve import EngineHandle
from repro.serving.sampling import (GREEDY, SamplingParams,
                                    fill_sampling_row, host_sampling_rows,
                                    validate_sampling)


@dataclass
class Request:
    """One generation request.  ``prompt``: token ids (≤ the scheduler's
    ``prompt_cap``); ``max_new``: tokens to generate (counting the one
    sampled by the prefill insert).

    ``replay``: journaled tokens to RECONSTRUCT before generating live
    (fleet recovery — serving/router.py).  The slot admits ``prompt``
    normally, then force-feeds the replay tokens as decode inputs in
    order, re-building the exact device state of the original stream
    (same jitted programs, same inputs, same order ⇒ same floats —
    DESIGN.md §9).  The engine's re-emitted tokens are cross-checked
    against the journal (``replay_mismatch``); the journaled value is
    authoritative for both the result stream and the next decode input.
    ``max_new`` counts the replayed tokens, so a resumed request keeps
    its original budget.

    ``sampling``: per-request :class:`SamplingParams`
    (serving/sampling.py) — temperature / top-k / top-p / seed, default
    greedy.  The params ride the admit call into the slot's device
    state leaves and every emission of this request uses them; the PRNG
    stream is positional (seed × emit offset), so a replayed request
    re-derives its original sampled stream bit-exactly."""
    rid: int
    prompt: Sequence[int]
    max_new: int
    replay: Sequence[int] = ()
    sampling: SamplingParams = GREEDY


class SchedulerHooks:
    """Extension points for perturbing a live scheduler — the ONLY
    sanctioned way the fault-injection harness (serving/faults.py)
    touches a running engine: the hooks are threaded through the
    admit/decode call sites, never monkeypatched, so every injected
    fault is visible in the call graph.  The base class is a no-op;
    a scheduler built with ``hooks=None`` behaves identically.
    """

    def pre_step(self, sched: "SlotScheduler") -> None:
        """Start of every tick; may raise (e.g. faults.ReplicaKilled)."""

    def admit_args(self, sched: "SlotScheduler", toks: np.ndarray,
                   lens: np.ndarray):
        """Rewrite the (tokens, lengths) the DEVICE admit call sees —
        host bookkeeping keeps the original request (dropped admits)."""
        return toks, lens

    def post_admit(self, sched: "SlotScheduler") -> None:
        """After the tick's admit call (duplicate-admit injection)."""

    def decode_args(self, sched: "SlotScheduler", params, state, tokens):
        """Rewrite what the device decode call consumes (KV/length
        corruption, weight poisoning)."""
        return params, state, tokens

    def decode_blackholed(self, sched: "SlotScheduler") -> bool:
        """True ⇒ the decode call never returns (network blackhole):
        the scheduler's host loop sees a stale echo of its own inputs
        while device state freezes."""
        return False


@dataclass
class _Slot:
    rid: Optional[int] = None
    remaining: int = 0          # tokens still to emit
    last_tok: int = 0
    prompt_len: int = 0         # admitted prompt length (journal model)
    emitted: int = 0            # tokens emitted so far (incl. replayed)
    replay: List[int] = field(default_factory=list)
    replay_mismatch: int = 0    # engine token ≠ journaled token count

    @property
    def free(self) -> bool:
        return self.rid is None


@dataclass
class RequestResult:
    rid: int
    tokens: List[int] = field(default_factory=list)
    slot: int = -1
    admit_tick: int = -1
    finish_tick: int = -1
    # effective per-request sampling params (what the device actually
    # used — audit trail for sampled streams)
    sampling: SamplingParams = GREEDY


class SlotScheduler:
    """Continuous-batching driver over an :class:`EngineHandle`.

    Build the engine with ``build_engine_full(..., track_work=True)`` to
    get the per-slot attend-step counters the tests assert on.  Requires
    an attention-only decoder (the targeted prefill-insert pads prompts
    to ``prompt_cap`` — recurrent scans would fold the padding into
    their state) and the batch replicated over the data axes.
    """

    def __init__(self, engine: EngineHandle, *, prompt_cap: int,
                 eos_id: Optional[int] = None,
                 hooks: Optional[SchedulerHooks] = None,
                 integrity_latch: bool = False):
        cfg = engine.cfg
        assert cfg.frontend is None and cfg.encoder is None, \
            "SlotScheduler supports decoder-only text models"
        # capacity-based MoE dispatch couples batch rows (experts drop by
        # PER-BATCH capacity), so a request's tokens would depend on its
        # slot neighbors — breaking the slot-independence contract the
        # scheduler (and its tests) guarantee
        assert cfg.moe is None, \
            "SlotScheduler requires dense-FFN models: MoE capacity " \
            "routing makes tokens depend on co-resident slots"
        assert engine.scfg.batch_local == engine.batch_global, \
            "SlotScheduler needs the batch replicated over data axes"
        self.eng = engine
        self.prompt_cap = int(prompt_cap)
        self.eos_id = eos_id
        self.hooks = hooks
        self.n_slots = engine.batch_global
        self.slots = [_Slot() for _ in range(self.n_slots)]
        self.queue: List[Request] = []
        self.results: Dict[int, RequestResult] = {}
        self.events: List[Tuple[int, str, int, int]] = []   # (tick, kind,
        self.occupancy: List[float] = []                    #  rid, slot)
        self.tick = 0
        self.decode_calls = 0
        # Pre-retire integrity latch (router probes, DESIGN.md §9).
        # Retiring a slot resets its cache length and finite sentinel —
        # which would DESTROY the evidence of a fault whose victim
        # finishes on the fault tick, letting a corrupt final token
        # commit.  With the latch on, violations are snapshotted to the
        # host between the decode and the retire that would erase them.
        self.integrity_latch = integrity_latch
        self.latched: List[str] = []
        self._replay_mismatch_retired = 0
        # all slots start FREE (cache_lens = −1)
        self.state = engine.retire_fn(engine.state,
                                      np.ones((self.n_slots,), np.int32))

    # -- host views of the device state ----------------------------------
    def cache_lens(self) -> np.ndarray:
        """Per-slot cache lengths (−1 = free); identical across shards."""
        leaf = np.asarray(jax.device_get(self.state["cache_lens"]))
        return leaf.reshape(-1, self.n_slots)[0]

    def work_blocks(self) -> np.ndarray:
        """Per-slot attend-step counters, summed over the (dp, model)
        device grid — each cluster rank counts its own rank-local blocks
        (core/tracecount.live_attend_blocks)."""
        if "work_blocks" not in self.state:
            raise ValueError("build the engine with track_work=True")
        leaf = np.asarray(jax.device_get(self.state["work_blocks"]))
        return leaf.reshape(-1, self.n_slots).sum(axis=0)

    # -- host model of the device cache lengths ---------------------------
    def expected_cache_lens(self) -> np.ndarray:
        """What ``cache_lens`` MUST read if the device executed exactly
        the admits/decodes this host issued: an active slot's cache
        holds its prompt plus one entry per decode input so far
        (``prompt_len + emitted − 1`` — the admit insert itself emits
        the first token without consuming a cache entry); free slots
        sit at −1.  The router's journal cross-check compares this
        against the device vector every tick: a dropped or duplicated
        admit, a blackholed (frozen) replica, or a corrupted length all
        surface as a mismatch (DESIGN.md §9)."""
        out = np.full((self.n_slots,), -1, np.int64)
        for b, s in enumerate(self.slots):
            if not s.free:
                out[b] = s.prompt_len + s.emitted - 1
        return out

    def replay_mismatches(self) -> int:
        """Total journal/engine token disagreements across recovery
        replays, live and retired (zero under the supported fault
        model)."""
        return self._replay_mismatch_retired + sum(
            s.replay_mismatch for s in self.slots)

    def _latch_integrity(self) -> None:
        """Snapshot per-slot integrity violations BEFORE the post-decode
        retire can reset them (see ``integrity_latch``).  All reads are
        [shards, B] host pulls — the same cost as one router probe."""
        st = self.state
        if "nonfinite" in st:
            nf = np.asarray(jax.device_get(st["nonfinite"]))
            if (nf > 0).any():
                self.latched.append("detect_nonfinite")
        lens = np.asarray(jax.device_get(st["cache_lens"]))
        lens = lens.reshape(-1, self.n_slots)
        if ((lens < -1).any()
                or (lens > self.eng.scfg.max_seq).any()
                or (lens != lens[0]).any()):
            self.latched.append("detect_lens_bounds")
        if (lens[0] != self.expected_cache_lens()).any():
            self.latched.append("detect_journal_stale")

    # -- request intake ---------------------------------------------------
    def submit(self, req: Request) -> None:
        # length 0 means "slot untouched" to the prefill insert, so an
        # empty prompt would desync host bookkeeping from device state
        plen = len(req.prompt)
        if plen == 0:
            raise ValueError(
                f"request {req.rid}: empty prompt — the targeted prefill "
                "insert treats length 0 as 'leave this slot untouched', "
                "so an admitted request needs at least 1 prompt token")
        if plen > self.prompt_cap:
            raise ValueError(
                f"request {req.rid}: prompt length {plen} exceeds this "
                f"scheduler's prompt_cap={self.prompt_cap}")
        if plen > self.eng.scfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt length {plen} exceeds the "
                f"engine's cache capacity max_seq={self.eng.scfg.max_seq}")
        if req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: max_new must be ≥ 1 "
                f"(got {req.max_new})")
        if len(req.replay) >= req.max_new:
            raise ValueError(
                f"request {req.rid}: replay carries {len(req.replay)} "
                f"tokens but max_new={req.max_new} — a resumed request "
                "must have live tokens left to generate")
        validate_sampling(req.rid, req.sampling)
        if req.rid in self.results:
            raise ValueError(f"request {req.rid}: duplicate request id")
        self.queue.append(req)
        self.results[req.rid] = RequestResult(rid=req.rid,
                                              sampling=req.sampling)

    # -- lifecycle pieces -------------------------------------------------
    def _admit(self) -> None:
        free = [i for i, s in enumerate(self.slots) if s.free]
        admitted: List[Tuple[int, Request]] = []
        while self.queue and free:
            admitted.append((free.pop(0), self.queue.pop(0)))
        if not admitted:
            return
        toks = np.zeros((self.n_slots, self.prompt_cap), np.int32)
        lens = np.zeros((self.n_slots,), np.int32)
        samp = host_sampling_rows(self.n_slots)
        for b, req in admitted:
            toks[b, :len(req.prompt)] = np.asarray(req.prompt, np.int32)
            lens[b] = len(req.prompt)
            fill_sampling_row(samp, b, req.sampling)
        if self.hooks is not None:
            toks, lens = self.hooks.admit_args(self, toks, lens)
        first, self.state = self.eng.admit_fn(
            self.eng.params["train"], self.state, toks, lens, samp)
        first = np.asarray(jax.device_get(first)).reshape(-1)
        for b, req in admitted:
            self.slots[b] = _Slot(rid=req.rid, remaining=req.max_new,
                                  prompt_len=len(req.prompt),
                                  replay=list(req.replay))
            res = self.results[req.rid]
            res.slot, res.admit_tick = b, self.tick
            self.events.append((self.tick, "admit", req.rid, b))
            self._emit(b, int(first[b]))
        if self.hooks is not None:
            self.hooks.post_admit(self)

    def _emit(self, b: int, tok: int) -> None:
        s = self.slots[b]
        if s.replay:
            # recovery replay: the journal is authoritative — the
            # engine's re-emitted token must MATCH it (same weights,
            # same inputs); count any divergence as a detection signal
            # rather than corrupting the stream
            want = s.replay.pop(0)
            if tok != want:
                s.replay_mismatch += 1
            tok = want
        s.last_tok = tok
        s.remaining -= 1
        s.emitted += 1
        self.results[s.rid].tokens.append(tok)

    def _retire_finished(self) -> None:
        fin = [b for b, s in enumerate(self.slots) if not s.free
               and (s.remaining <= 0
                    or (self.eos_id is not None
                        and s.last_tok == self.eos_id))]
        if not fin:
            return
        mask = np.zeros((self.n_slots,), np.int32)
        for b in fin:
            mask[b] = 1
            rid = self.slots[b].rid
            self.results[rid].finish_tick = self.tick
            self.events.append((self.tick, "finish", rid, b))
            self._replay_mismatch_retired += self.slots[b].replay_mismatch
            self.slots[b] = _Slot()
        self.state = self.eng.retire_fn(self.state, mask)

    # -- one scheduler tick ----------------------------------------------
    def step(self) -> None:
        if self.hooks is not None:
            self.hooks.pre_step(self)
        self._admit()
        if self.integrity_latch and any(
                not s.free and (s.remaining <= 0
                                or (self.eos_id is not None
                                    and s.last_tok == self.eos_id))
                for s in self.slots):
            # a request admitted THIS tick finishes before the decode
            # stage — latch now or the retire below erases the evidence
            # of a dropped/corrupted admit
            self._latch_integrity()
        self._retire_finished()          # one-token / instant-EOS admits
        active = [b for b, s in enumerate(self.slots) if not s.free]
        if active:
            tok_in = np.asarray([s.last_tok for s in self.slots], np.int32)
            if self.hooks is not None and self.hooks.decode_blackholed(self):
                # the decode call never returns: the host loop proceeds
                # on a stale echo of its own inputs while device state
                # freezes — the router's expected-lens cross-check trips
                # at its next probe (DESIGN.md §9)
                nxt = tok_in
            else:
                params, st, ti = self.eng.params["serve"], self.state, tok_in
                if self.hooks is not None:
                    params, st, ti = self.hooks.decode_args(
                        self, params, st, ti)
                nxt, self.state = self.eng.decode_fn(params, st, ti)
                self.decode_calls += 1
                nxt = np.asarray(jax.device_get(nxt)).reshape(-1)
            for b in active:
                self._emit(b, int(nxt[b]))
            if self.integrity_latch:
                self._latch_integrity()
            self._retire_finished()
        self.occupancy.append(len(active) / self.n_slots)
        self.tick += 1

    def idle(self) -> bool:
        return not self.queue and all(s.free for s in self.slots)

    def run(self, max_ticks: int = 10_000) -> Dict[int, RequestResult]:
        while not self.idle() and self.tick < max_ticks:
            self.step()
        assert self.idle(), f"scheduler did not drain in {max_ticks} ticks"
        return self.results


def replay_trace(sched: SlotScheduler,
                 trace: Sequence[Tuple[int, Request]],
                 max_ticks: int = 10_000) -> Dict[int, RequestResult]:
    """Drive ``sched`` from an arrival trace: ``(arrival_tick, Request)``
    pairs.  Requests join the queue at the START of their arrival tick;
    the scheduler then runs until drained."""
    pending = sorted(trace, key=lambda ar: ar[0])
    i = 0
    while (i < len(pending) or not sched.idle()) and sched.tick < max_ticks:
        while i < len(pending) and pending[i][0] <= sched.tick:
            sched.submit(pending[i][1])
            i += 1
        sched.step()
    assert sched.idle(), "trace did not drain"
    return sched.results
