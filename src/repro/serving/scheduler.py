"""Slot-based continuous batching over the ragged decode engine.

The decode step is RAGGED (per-slot ``cache_lens`` — serving/engine.py,
DESIGN.md §6), so the batch no longer advances in lockstep: this module
runs the request-level loop on top of it.  The engine's ``B`` batch rows
become **slots** with a lifecycle::

    FREE (cache_lens = −1)
      └─ admit ──▶ ACTIVE   targeted prefill-insert at the slot's offset
                            (EngineHandle.admit_fn; one jitted call admits
                            every request picked this tick, and emits each
                            request's FIRST token)
    ACTIVE ── decode ──▶    one ragged decode step per tick advances ALL
                            active slots (per-slot RoPE position, append
                            slot, live-span cull; free slots do zero
                            attend-step work — state["work_blocks"])
      └─ retire ──▶ FREE    on EOS or max_new (EngineHandle.retire_fn);
                            the slot is immediately re-admittable

Scheduling policy (deterministic, mirrored by the pure-Python reference
simulator in tests/test_scheduler.py): arrivals enqueue FIFO; each tick
admits queue-head requests into the lowest-numbered free slots, retires
any one-token requests, runs one decode step for the active slots, then
retires finished ones.

The driver is host-side Python issuing three jitted programs (admit /
decode / retire) — the decode hot loop itself stays ONE fused dispatch
per token, exactly the paper's fusion story; continuous batching only
changes which slots carry live work.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.launch.serve import EngineHandle


@dataclass
class Request:
    """One generation request.  ``prompt``: token ids (≤ the scheduler's
    ``prompt_cap``); ``max_new``: tokens to generate (counting the one
    sampled by the prefill insert)."""
    rid: int
    prompt: Sequence[int]
    max_new: int


@dataclass
class _Slot:
    rid: Optional[int] = None
    remaining: int = 0          # tokens still to emit
    last_tok: int = 0

    @property
    def free(self) -> bool:
        return self.rid is None


@dataclass
class RequestResult:
    rid: int
    tokens: List[int] = field(default_factory=list)
    slot: int = -1
    admit_tick: int = -1
    finish_tick: int = -1


class SlotScheduler:
    """Continuous-batching driver over an :class:`EngineHandle`.

    Build the engine with ``build_engine_full(..., track_work=True)`` to
    get the per-slot attend-step counters the tests assert on.  Requires
    an attention-only decoder (the targeted prefill-insert pads prompts
    to ``prompt_cap`` — recurrent scans would fold the padding into
    their state) and the batch replicated over the data axes.
    """

    def __init__(self, engine: EngineHandle, *, prompt_cap: int,
                 eos_id: Optional[int] = None):
        cfg = engine.cfg
        assert cfg.frontend is None and cfg.encoder is None, \
            "SlotScheduler supports decoder-only text models"
        # capacity-based MoE dispatch couples batch rows (experts drop by
        # PER-BATCH capacity), so a request's tokens would depend on its
        # slot neighbors — breaking the slot-independence contract the
        # scheduler (and its tests) guarantee
        assert cfg.moe is None, \
            "SlotScheduler requires dense-FFN models: MoE capacity " \
            "routing makes tokens depend on co-resident slots"
        assert engine.scfg.batch_local == engine.batch_global, \
            "SlotScheduler needs the batch replicated over data axes"
        self.eng = engine
        self.prompt_cap = int(prompt_cap)
        self.eos_id = eos_id
        self.n_slots = engine.batch_global
        self.slots = [_Slot() for _ in range(self.n_slots)]
        self.queue: List[Request] = []
        self.results: Dict[int, RequestResult] = {}
        self.events: List[Tuple[int, str, int, int]] = []   # (tick, kind,
        self.occupancy: List[float] = []                    #  rid, slot)
        self.tick = 0
        self.decode_calls = 0
        # all slots start FREE (cache_lens = −1)
        self.state = engine.retire_fn(engine.state,
                                      np.ones((self.n_slots,), np.int32))

    # -- host views of the device state ----------------------------------
    def cache_lens(self) -> np.ndarray:
        """Per-slot cache lengths (−1 = free); identical across shards."""
        leaf = np.asarray(jax.device_get(self.state["cache_lens"]))
        return leaf.reshape(-1, self.n_slots)[0]

    def work_blocks(self) -> np.ndarray:
        """Per-slot attend-step counters, summed over the (dp, model)
        device grid — each cluster rank counts its own rank-local blocks
        (core/tracecount.live_attend_blocks)."""
        if "work_blocks" not in self.state:
            raise ValueError("build the engine with track_work=True")
        leaf = np.asarray(jax.device_get(self.state["work_blocks"]))
        return leaf.reshape(-1, self.n_slots).sum(axis=0)

    # -- request intake ---------------------------------------------------
    def submit(self, req: Request) -> None:
        # length 0 means "slot untouched" to the prefill insert, so an
        # empty prompt would desync host bookkeeping from device state
        assert 1 <= len(req.prompt) <= self.prompt_cap, \
            (len(req.prompt), self.prompt_cap)
        assert req.max_new >= 1 and req.rid not in self.results
        self.queue.append(req)
        self.results[req.rid] = RequestResult(rid=req.rid)

    # -- lifecycle pieces -------------------------------------------------
    def _admit(self) -> None:
        free = [i for i, s in enumerate(self.slots) if s.free]
        admitted: List[Tuple[int, Request]] = []
        while self.queue and free:
            admitted.append((free.pop(0), self.queue.pop(0)))
        if not admitted:
            return
        toks = np.zeros((self.n_slots, self.prompt_cap), np.int32)
        lens = np.zeros((self.n_slots,), np.int32)
        for b, req in admitted:
            toks[b, :len(req.prompt)] = np.asarray(req.prompt, np.int32)
            lens[b] = len(req.prompt)
        first, self.state = self.eng.admit_fn(
            self.eng.params["train"], self.state, toks, lens)
        first = np.asarray(jax.device_get(first)).reshape(-1)
        for b, req in admitted:
            self.slots[b] = _Slot(rid=req.rid, remaining=req.max_new)
            res = self.results[req.rid]
            res.slot, res.admit_tick = b, self.tick
            self.events.append((self.tick, "admit", req.rid, b))
            self._emit(b, int(first[b]))

    def _emit(self, b: int, tok: int) -> None:
        s = self.slots[b]
        s.last_tok = tok
        s.remaining -= 1
        self.results[s.rid].tokens.append(tok)

    def _retire_finished(self) -> None:
        fin = [b for b, s in enumerate(self.slots) if not s.free
               and (s.remaining <= 0
                    or (self.eos_id is not None
                        and s.last_tok == self.eos_id))]
        if not fin:
            return
        mask = np.zeros((self.n_slots,), np.int32)
        for b in fin:
            mask[b] = 1
            rid = self.slots[b].rid
            self.results[rid].finish_tick = self.tick
            self.events.append((self.tick, "finish", rid, b))
            self.slots[b] = _Slot()
        self.state = self.eng.retire_fn(self.state, mask)

    # -- one scheduler tick ----------------------------------------------
    def step(self) -> None:
        self._admit()
        self._retire_finished()          # one-token / instant-EOS admits
        active = [b for b, s in enumerate(self.slots) if not s.free]
        if active:
            tok_in = np.asarray([s.last_tok for s in self.slots], np.int32)
            nxt, self.state = self.eng.decode_fn(
                self.eng.params["serve"], self.state, tok_in)
            self.decode_calls += 1
            nxt = np.asarray(jax.device_get(nxt)).reshape(-1)
            for b in active:
                self._emit(b, int(nxt[b]))
            self._retire_finished()
        self.occupancy.append(len(active) / self.n_slots)
        self.tick += 1

    def idle(self) -> bool:
        return not self.queue and all(s.free for s in self.slots)

    def run(self, max_ticks: int = 10_000) -> Dict[int, RequestResult]:
        while not self.idle() and self.tick < max_ticks:
            self.step()
        assert self.idle(), f"scheduler did not drain in {max_ticks} ticks"
        return self.results


def replay_trace(sched: SlotScheduler,
                 trace: Sequence[Tuple[int, Request]],
                 max_ticks: int = 10_000) -> Dict[int, RequestResult]:
    """Drive ``sched`` from an arrival trace: ``(arrival_tick, Request)``
    pairs.  Requests join the queue at the START of their arrival tick;
    the scheduler then runs until drained."""
    pending = sorted(trace, key=lambda ar: ar[0])
    i = 0
    while (i < len(pending) or not sched.idle()) and sched.tick < max_ticks:
        while i < len(pending) and pending[i][0] <= sched.tick:
            sched.submit(pending[i][1])
            i += 1
        sched.step()
    assert sched.idle(), "trace did not drain"
    return sched.results
