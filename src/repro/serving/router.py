"""Fleet front-end: a multi-replica router with journaling, health
probes, and zero-corruption reconstructive recovery.

ClusterFusion keeps decode intermediates on-chip and the KV cache as
the only per-request device state — there is no checkpointable serving
state, so surviving a replica loss means *reconstructing* streams, not
restoring them.  The router makes that safe with three mechanisms
(DESIGN.md §9):

1. **Journal**: every request's prompt and every COMMITTED token live
   in the router (:class:`JournalEntry`).  Tokens a replica emits in
   tick *t* are committed only after tick-*t*'s integrity probes pass;
   a failed probe discards the whole tick's emissions, so the journal
   never contains a byte produced by a corrupt replica.
2. **Probes** (per replica, per tick, all O(B) host work):
   the ``check_finite`` sentinel leaf (non-finite residual/head output
   on an active slot), ``cache_lens`` bounds + cross-shard agreement,
   and the journal cross-check (device lengths vs the scheduler's
   host-side model — catches dropped/duplicated admits and blackholed
   replicas), plus a heartbeat (the step raising).  With
   ``integrity=IntegrityConfig(...)`` the SDC probes join the loop
   (serving/integrity.py): KV-cache fingerprints, rotating weight
   spot-checks, and the shadow logit recompute — silent single-bit
   flips below the non-finite floor.  Each firing is recorded via
   :func:`repro.core.tracecount.record_signal`.
3. **Recovery**: a failed replica is drained; its in-flight requests
   re-queue onto survivors as ``Request(prompt, max_new,
   replay=committed_tokens)`` — the survivor re-prefills the prompt,
   then REPLAYS the journaled tokens through the same jitted decode
   program before generating live.  Same weights (replicas share the
   init seed — ``build_replicas``), same programs, same inputs in the
   same order ⇒ the reconstructed device state and the continuation
   are bit-identical to an uninterrupted run.  Sampled streams recover
   the same way: the PRNG stream is POSITIONAL (per-request seed ×
   emit offset — serving/sampling.py), so the journaled
   :class:`SamplingParams` plus the committed-token count fully
   determine every future key; no mid-stream PRNG state is ever
   checkpointed.
   Replayed emissions are cross-checked against the journal and never
   re-committed.  A replica failed by the WEIGHT fingerprint probe
   additionally HEALS: the serve layout re-materializes from the train
   view (``EngineHandle.repack_fn``), every leaf fingerprint re-verifies,
   and the replica rejoins with a fresh scheduler at the start of the
   next tick (``replica_healed``).

The rotating weight probe only covers every leaf once per
``IntegrityMonitor.commit_lag()`` ticks, so commits are DEFERRED by
exactly that window: tick-*t* emissions sit in a per-replica staging
buffer and reach the journal only once every probe through tick
``t + lag`` has passed — a flip detected at the end of a rotation still
discards every token it could have influenced (the buffer is dropped on
failure).  With integrity off the lag is zero and commits are
immediate, byte-identical to the PR-6 router.

``max_requeues`` caps per-request recovery attempts (the requeue-storm
guard): a request whose requeue count exceeds the cap is terminally
FAILED in the journal (``JournalEntry.failed``, ``request_failed``
signal) instead of bouncing between replicas forever when faults repeat
across survivors.

Dispatch is queue-depth-aware: each pending request goes to the live
replica with the fewest queued + active requests (ties to the lowest
index, keeping the whole fleet deterministic for the chaos tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import tracecount
from repro.launch.serve import EngineHandle
from repro.serving.faults import ReplicaKilled
from repro.serving.integrity import IntegrityConfig, IntegrityMonitor
from repro.serving.sampling import (GREEDY, SamplingParams,
                                    validate_sampling)
from repro.serving.scheduler import Request, SchedulerHooks, SlotScheduler


@dataclass
class JournalEntry:
    """The router's durable record of one request: everything needed to
    reconstruct the stream on any replica, plus the committed tokens."""
    rid: int
    prompt: List[int]
    max_new: int
    sampling: SamplingParams = GREEDY   # journaled per-request params —
                                # with the positional PRNG stream
                                # (seed × emit offset) these plus the
                                # committed tokens are ALL the state a
                                # survivor needs to resume a sampled
                                # stream bit-exactly
    seed: int = 0               # journaled sampling PRNG seed (kept in
                                # sync with ``sampling.seed``; retained
                                # as its own column for the PR-6 journal
                                # readers)
    tokens: List[int] = field(default_factory=list)   # COMMITTED only
    replicas: List[int] = field(default_factory=list)  # dispatch history
    submit_tick: int = -1
    finish_tick: int = -1
    requeues: int = 0
    # (requeue_tick, first_new_commit_tick) per recovery — the bench's
    # recovery-latency column is the max delta over these
    recoveries: List[Tuple[int, int]] = field(default_factory=list)
    done: bool = False
    failed: bool = False        # terminal: hit the max_requeues cap

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.tokens)


class _Replica:
    """One engine replica as the router sees it: its scheduler (with
    the replica's fault-injection hooks, if any), the local→router
    request-id map, and per-request commit watermarks."""

    def __init__(self, idx: int, eng: EngineHandle, prompt_cap: int,
                 eos_id: Optional[int], hooks: Optional[SchedulerHooks],
                 monitor: Optional[IntegrityMonitor] = None):
        self.idx = idx
        self.eng = eng
        self.prompt_cap = prompt_cap
        self.eos_id = eos_id
        self.hooks = hooks
        self.monitor = monitor
        # integrity_latch: snapshot violations before a same-tick retire
        # can reset the offending slot (the probe below would otherwise
        # miss a fault whose victim finishes on the fault tick and
        # commit its corrupt final token)
        self.sched = SlotScheduler(eng, prompt_cap=prompt_cap,
                                   eos_id=eos_id, hooks=hooks,
                                   integrity_latch=True)
        self.alive = True
        self.owner: Dict[int, int] = {}       # local rid → router rid
        self.committed: Dict[int, int] = {}   # local rid → commit mark
        self.staged_mark: Dict[int, int] = {}  # local rid → staged mark
        # deferred-commit staging: (emit_tick, local rid, tokens) —
        # flushed to the journal once every probe through emit_tick +
        # commit_lag has passed; dropped wholesale on failure
        self.staged: List[Tuple[int, int, List[int]]] = []

    def load(self) -> int:
        """Queue depth + active slots — the dispatch cost metric."""
        return len(self.sched.queue) + sum(
            not s.free for s in self.sched.slots)

    def reset_sched(self) -> None:
        """Fresh scheduler over the (healed) engine — construction
        retires every slot, so the replica rejoins with clean device
        state and zero in-flight bookkeeping."""
        self.sched = SlotScheduler(self.eng, prompt_cap=self.prompt_cap,
                                   eos_id=self.eos_id, hooks=self.hooks,
                                   integrity_latch=True)

    def probe(self) -> List[str]:
        """Post-step integrity probes; returns the fired signal labels
        (empty = healthy).  All reads are host-side snapshots of [B]
        vectors — no device compute (the SDC monitor adds the
        fingerprint / shadow pulls it accounts in the probe counters)."""
        fired = list(self.sched.latched)   # pre-retire snapshots first
        st = self.sched.state
        n = self.sched.n_slots
        if "nonfinite" in st:
            nf = np.asarray(jax.device_get(st["nonfinite"])).reshape(-1, n)
            if (nf > 0).any():
                fired.append("detect_nonfinite")
        lens = np.asarray(jax.device_get(st["cache_lens"])).reshape(-1, n)
        if ((lens < -1).any() or
                (lens > self.eng.scfg.max_seq).any() or
                (lens != lens[0]).any()):      # shard disagreement
            fired.append("detect_lens_bounds")
        if (lens[0] != self.sched.expected_cache_lens()).any():
            fired.append("detect_journal_stale")
        if self.sched.replay_mismatches() > 0:
            fired.append("detect_journal_mismatch")
        if self.monitor is not None:
            fired += self.monitor.probe(self.sched)
        return list(dict.fromkeys(fired))   # latch + probe may agree


class Router:
    """Load-balance a request stream over N replicas with journaled,
    probe-gated commits and reconstructive recovery.

    ``injectors`` maps replica index → :class:`SchedulerHooks` (chaos
    tests pass a :class:`~repro.serving.faults.FaultInjector`); omitted
    replicas run clean.  All replicas must share weights (same init
    seed — :func:`repro.launch.serve.build_replicas`): recovery moves a
    stream between replicas and is only exact if they agree.

    ``integrity`` enables the SDC probes (one
    :class:`~repro.serving.integrity.IntegrityMonitor` per replica) and
    turns on the deferred-commit window (see the module docstring).
    ``max_requeues`` is the requeue-storm guard (``None`` = unbounded,
    the PR-6 behavior).
    """

    def __init__(self, engines: Sequence[EngineHandle], *,
                 prompt_cap: int, max_new_cap: int,
                 eos_id: Optional[int] = None,
                 injectors: Optional[Dict[int, SchedulerHooks]] = None,
                 integrity: Optional[IntegrityConfig] = None,
                 max_requeues: Optional[int] = None):
        if not engines:
            raise ValueError("router needs at least one replica")
        max_seq = engines[0].scfg.max_seq
        # a full-length stream appends prompt + (max_new − 1) inputs
        if prompt_cap + max_new_cap - 1 > max_seq:
            raise ValueError(
                f"prompt_cap={prompt_cap} + max_new_cap={max_new_cap} - 1 "
                f"exceeds the engines' cache capacity max_seq={max_seq}")
        if max_requeues is not None and max_requeues < 0:
            raise ValueError(
                f"max_requeues must be ≥ 0 or None, got {max_requeues}")
        injectors = injectors or {}
        for idx, hooks in injectors.items():
            if not 0 <= idx < len(engines):
                raise ValueError(
                    f"injector replica={idx} out of range for a "
                    f"{len(engines)}-replica fleet")
            for s in getattr(hooks, "specs", ()):
                if getattr(s, "replica", 0) >= len(engines):
                    raise ValueError(
                        f"FaultSpec.replica={s.replica} out of range "
                        f"for a {len(engines)}-replica fleet")
        self.max_new_cap = max_new_cap
        self.max_requeues = max_requeues
        self.replicas = [
            _Replica(i, eng, prompt_cap, eos_id, injectors.get(i),
                     IntegrityMonitor(eng, integrity)
                     if integrity is not None else None)
            for i, eng in enumerate(engines)]
        # the weight rotation's full-coverage period: the window commits
        # defer by, so no committed token predates the probe that could
        # have vetoed it (0 without integrity — immediate commits)
        self.commit_lag = max(
            (r.monitor.commit_lag() for r in self.replicas
             if r.monitor is not None), default=0)
        self.journal: Dict[int, JournalEntry] = {}
        self.pending: List[int] = []          # rids awaiting dispatch
        self.tick = 0
        self.events: List[Tuple[int, str, Any, Any]] = []
        self.detections: List[Dict[str, Any]] = []
        self.live_frac: List[float] = []      # per-tick availability
        self._next_local = 0
        self._to_heal: List[_Replica] = []

    # -- intake -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.rid in self.journal:
            raise ValueError(f"request {req.rid}: duplicate request id")
        if req.max_new > self.max_new_cap:
            raise ValueError(
                f"request {req.rid}: max_new={req.max_new} exceeds the "
                f"router's max_new_cap={self.max_new_cap}")
        sampling = getattr(req, "sampling", GREEDY)
        validate_sampling(req.rid, sampling)
        self.journal[req.rid] = JournalEntry(
            rid=req.rid, prompt=list(req.prompt), max_new=req.max_new,
            sampling=sampling, seed=sampling.seed, submit_tick=self.tick)
        self.pending.append(req.rid)

    # -- dispatch ---------------------------------------------------------
    def _dispatch(self) -> None:
        for rid in self.pending:
            live = [r for r in self.replicas if r.alive]
            if not live:
                raise RuntimeError(
                    "no live replicas left — the fleet cannot make "
                    "progress (all replicas failed probes or died)")
            r = min(live, key=lambda rr: (rr.load(), rr.idx))
            e = self.journal[rid]
            lr = self._next_local
            self._next_local += 1
            r.owner[lr] = rid
            # already-committed tokens replay on the new replica and are
            # never re-committed
            r.committed[lr] = len(e.tokens)
            r.staged_mark[lr] = len(e.tokens)
            # the replay carries the committed prefix; the SAME sampling
            # params ride along, so the survivor's positional PRNG keys
            # (seed × emit offset) line up with the dead replica's and
            # the live continuation stays bit-exact for sampled streams
            r.sched.submit(Request(lr, list(e.prompt), e.max_new,
                                   replay=list(e.tokens),
                                   sampling=e.sampling))
            e.replicas.append(r.idx)
            self.events.append((self.tick, "dispatch", rid, r.idx))
        self.pending.clear()

    # -- commit / failure -------------------------------------------------
    def _stage(self, r: _Replica) -> None:
        """Pull this tick's emissions into the replica's staging buffer;
        they reach the journal only after every probe through the
        deferred-commit window has passed (:meth:`_commit`)."""
        for lr in list(r.owner):
            res = r.sched.results.get(lr)
            if res is None:
                continue
            new = res.tokens[r.staged_mark[lr]:]
            if new:
                r.staged.append((self.tick, lr, list(new)))
                r.staged_mark[lr] = len(res.tokens)

    def _commit(self, r: _Replica) -> None:
        """Flush staged emissions whose deferred-commit window has
        closed (emit_tick ≤ now − commit_lag; with integrity off the
        lag is 0 and this commits the tick's tokens immediately)."""
        cutoff = self.tick - self.commit_lag
        keep: List[Tuple[int, int, List[int]]] = []
        for emit_tick, lr, toks in r.staged:
            rid = r.owner.get(lr)
            if rid is None:
                continue                  # request left this replica
            if emit_tick > cutoff:
                keep.append((emit_tick, lr, toks))
                continue
            e = self.journal[rid]
            e.tokens.extend(toks)
            r.committed[lr] += len(toks)
            if e.recoveries and e.recoveries[-1][1] < 0:
                rq_tick, _ = e.recoveries[-1]
                e.recoveries[-1] = (rq_tick, self.tick)
        r.staged = keep
        pending_lrs = {lr for _, lr, _ in r.staged}
        for lr, rid in list(r.owner.items()):
            res = r.sched.results.get(lr)
            if res is None or res.finish_tick < 0 or lr in pending_lrs:
                continue                  # still emitting or still staged
            e = self.journal[rid]
            e.done = True
            e.finish_tick = self.tick
            del r.owner[lr], r.committed[lr], r.staged_mark[lr]
            self.events.append((self.tick, "finish", rid, r.idx))

    def _fail(self, r: _Replica, signals: Sequence[str]) -> None:
        """Drain a failed replica: nothing uncommitted survives — the
        staging buffer is dropped wholesale — and every in-flight
        request re-queues onto survivors from its last committed state
        (zero-corruption invariant).  Requests past the requeue cap are
        terminally FAILED instead (requeue-storm guard); a weight-SDC
        failure schedules the heal for the start of the next tick."""
        r.alive = False
        for sig in signals:
            tracecount.record_signal(sig)
        tracecount.record_signal("replica_failed")
        details = list(r.monitor.last_details) if r.monitor else []
        self.detections.append({"tick": self.tick, "replica": r.idx,
                                "signals": list(signals),
                                "details": details})
        self.events.append((self.tick, "fail", r.idx, tuple(signals)))
        for lr, rid in r.owner.items():
            e = self.journal[rid]
            if e.done:
                continue
            e.requeues += 1
            if self.max_requeues is not None \
                    and e.requeues > self.max_requeues:
                e.failed = True
                tracecount.record_signal("request_failed")
                self.events.append(
                    (self.tick, "request_failed", rid, r.idx))
                continue
            e.recoveries.append((self.tick, -1))
            self.pending.append(rid)
            self.events.append((self.tick, "requeue", rid, r.idx))
        r.owner.clear()
        r.committed.clear()
        r.staged_mark.clear()
        r.staged.clear()
        if "detect_weight_fingerprint" in signals and r.monitor is not None:
            self._to_heal.append(r)

    def _heal_pending(self) -> None:
        """Heal weight-SDC replicas quarantined last tick: re-materialize
        the serve layout from the (uncorrupted) train view, re-verify
        EVERY leaf fingerprint, and rejoin with a fresh scheduler.  A
        replica whose heal fails re-verification (train view also
        corrupt — outside the fault model) stays quarantined."""
        heals, self._to_heal = self._to_heal, []
        for r in heals:
            if r.eng.repack_fn is not None:
                r.eng.params["serve"] = r.eng.repack_fn(
                    r.eng.params["train"])
            bad = r.monitor.verify_weights_full()
            if bad:
                self.events.append(
                    (self.tick, "heal_failed", r.idx, tuple(bad)))
                continue
            r.reset_sched()
            r.alive = True
            tracecount.record_signal("replica_healed")
            self.events.append((self.tick, "heal", r.idx, None))

    # -- one fleet tick ---------------------------------------------------
    def step(self, arrivals: Sequence[Request] = ()) -> None:
        for req in arrivals:
            self.submit(req)
        self._heal_pending()     # last tick's quarantines rejoin first
        self._dispatch()
        for r in self.replicas:
            if not r.alive:
                continue
            try:
                r.sched.step()
            except ReplicaKilled:
                self._fail(r, ["detect_heartbeat"])
                continue
            signals = r.probe()
            if signals:
                self._fail(r, signals)
            else:
                self._stage(r)
                self._commit(r)
        self.live_frac.append(
            sum(r.alive for r in self.replicas) / len(self.replicas))
        self.tick += 1

    def idle(self) -> bool:
        return (not self.pending and not self._to_heal
                and all(not r.staged for r in self.replicas)
                and all(e.done or e.failed
                        for e in self.journal.values()))

    def run(self, trace: Sequence[Tuple[int, Request]] = (),
            max_ticks: int = 10_000) -> Dict[int, JournalEntry]:
        """Drive the fleet from an arrival trace (``(arrival_tick,
        Request)`` pairs, joining at the START of their tick) until
        every journaled request completes."""
        pending = sorted(trace, key=lambda ar: ar[0])
        i = 0
        while (i < len(pending) or not self.idle()) \
                and self.tick < max_ticks:
            arrivals = []
            while i < len(pending) and pending[i][0] <= self.tick:
                arrivals.append(pending[i][1])
                i += 1
            self.step(arrivals)
        assert self.idle(), f"fleet did not drain in {max_ticks} ticks"
        return self.journal

    # -- metrics ----------------------------------------------------------
    def availability(self) -> float:
        """Mean fraction of live replicas over the run (1.0 = no
        failures)."""
        return float(np.mean(self.live_frac)) if self.live_frac else 1.0

    def recovery_steps(self) -> int:
        """Worst-case ticks from a requeue to the affected stream's
        first NEW committed token (0 when no request was in flight
        across a failure)."""
        deltas = [ct - rt for e in self.journal.values()
                  for rt, ct in e.recoveries if ct >= 0]
        return max(deltas) if deltas else 0

    def detection_latency(self, injector) -> List[int]:
        """Ticks from each injected fault's firing to the first
        detection at or after it (chaos tests assert these bounded)."""
        out = []
        for spec, fire_tick in injector.fired:
            hits = [d["tick"] - fire_tick for d in self.detections
                    if d["tick"] >= fire_tick]
            out.append(min(hits) if hits else -1)
        return out
