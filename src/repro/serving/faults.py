"""Deterministic fault injection for the fleet serving layer.

Every fault is a declarative :class:`FaultSpec` — ``(kind, step,
target, seed, replica)`` — applied through the scheduler's
:class:`~repro.serving.scheduler.SchedulerHooks` extension points by
:class:`FaultInjector`, never by monkeypatching: the injector IS the
hooks object the scheduler was built with, so every perturbation is
visible in the call graph and reproducible from the spec alone.

Fault taxonomy (DESIGN.md §9) and the probe each one trips:

* ``kill`` — the replica dies inside its step (:class:`ReplicaKilled`
  raised from ``pre_step``); caught by the router's heartbeat.
* ``blackhole`` — the decode call never returns; the host loop
  proceeds on a stale echo of its own inputs while device state
  freezes → the expected-``cache_lens`` cross-check trips.
* ``corrupt_kv`` — NaN-poison the target slot's rank-0 KV rows at
  sequence position 0 (live for any active slot, so the poison reaches
  the attention scores on the very next decode) → non-finite sentinel.
* ``corrupt_lens`` — the target slot's ``cache_lens`` entry is forced
  out of ``[−1, max_seq]`` on every rank → bounds check.
* ``poison_weight`` — NaN/Inf into a column of the serve-layout
  embedding table (a poisoned COPY is fed to every subsequent decode
  call; the replica's real params are never mutated, so test fixtures
  can reuse the engine) → non-finite sentinel.
* ``drop_admit`` — the device admit call sees length 0 for the target
  slot while host bookkeeping proceeds → expected-lens mismatch.
* ``dup_admit`` — an extra device-side admit is injected into the
  target slot with a prompt length chosen to differ from the host's
  expected ``cache_lens`` → expected-lens mismatch.  (A byte-identical
  re-admit would be idempotent by construction — re-prefill of the
  same prefix writes the same cache — so the harmful variant is the
  one with different state, and that is what the harness injects.)

Bit-addressed SDC faults (BELOW the non-finite floor — a single XORed
bit, never a NaN/Inf; serving/integrity.py is the detection layer):

* ``flip_kv_bit`` — XOR bit ``spec.bit`` of one seed-chosen live K
  element in the target slot's rank-0 cache rows → KV fingerprint.
* ``flip_weight_bit`` — XOR bit ``spec.bit`` of one seed-chosen
  element of serve-tree leaf ``spec.target`` (indexing
  :func:`repro.serving.integrity.weight_leaves` order).  Unlike
  ``poison_weight`` this mutates the replica's REAL serve tree — a
  persistent HBM flip — so recovery must re-materialize the layout
  from the train view (the router's heal path) → rotating weight
  fingerprint (or the shadow recompute, for head-path leaves).

:class:`FaultSweep` enumerates systematic (kind × target × bit × step
× replica) grids of these specs for the DAVOS-style coverage sweeps
(serving/sweep.py, ROADMAP fleet phase 2).

All corruption is host-side ``device_get → mutate → device_put`` with
the leaf's own sharding, so the injected state round-trips through the
same jitted programs as real state.  Everything is seeded and
step-addressed: the same spec over the same trace perturbs the same
bytes every run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.serving.scheduler import SchedulerHooks, SlotScheduler

FAULT_KINDS = ("kill", "blackhole", "corrupt_kv", "corrupt_lens",
               "poison_weight", "drop_admit", "dup_admit")
BIT_FAULT_KINDS = ("flip_kv_bit", "flip_weight_bit")
ALL_FAULT_KINDS = FAULT_KINDS + BIT_FAULT_KINDS


class ReplicaKilled(RuntimeError):
    """The replica process is gone mid-step; the router's heartbeat
    converts this into a drain + re-queue (serving/router.py)."""


@dataclass(frozen=True)
class FaultSpec:
    """One declarative fault: ``kind`` fires at scheduler tick ``step``
    on ``replica``; ``target`` addresses a batch slot where relevant
    (``corrupt_kv`` / ``corrupt_lens`` / ``drop_admit`` / ``dup_admit``
    / ``flip_kv_bit``) or a serve-tree leaf index (``flip_weight_bit``);
    ``seed`` drives any generated corruption bytes; ``bit`` is the XORed
    bit position for the ``flip_*`` kinds (0–6 bf16 mantissa, 7–14
    exponent, 15 sign) and must stay −1 for every other kind."""
    kind: str
    step: int
    target: int = 0
    seed: int = 0
    replica: int = 0
    bit: int = -1

    def __post_init__(self):
        if self.kind not in ALL_FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {ALL_FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(
                f"FaultSpec.step must be ≥ 0, got step={self.step}")
        if self.replica < 0:
            raise ValueError(f"FaultSpec.replica must be ≥ 0, got "
                             f"replica={self.replica} (the router also "
                             f"rejects replica ≥ its fleet size)")
        if self.target < 0:
            raise ValueError(
                f"FaultSpec.target must be ≥ 0, got target={self.target}")
        if self.kind in BIT_FAULT_KINDS:
            if not 0 <= self.bit < 16:
                raise ValueError(
                    f"FaultSpec.bit must be in [0, 16) for "
                    f"{self.kind!r} (bf16 bit address), got "
                    f"bit={self.bit}")
        elif self.bit != -1:
            raise ValueError(f"FaultSpec.bit only applies to "
                             f"{BIT_FAULT_KINDS}, got bit={self.bit} "
                             f"for {self.kind!r}")


# ---------------------------------------------------------------------------
# Host-side state corruption (device_get → mutate → device_put)
# ---------------------------------------------------------------------------
def _put_back(host: np.ndarray, leaf) -> jax.Array:
    return jax.device_put(host, leaf.sharding)


def corrupt_kv_slot(state: Dict[str, Any], slot: int,
                    value: float = np.nan) -> Dict[str, Any]:
    """Poison ``slot``'s rank-0 K rows at sequence position 0 of the
    first attention cache.  Position 0 is live for every active slot,
    so the poison lands in the attention scores on the next decode
    step; state leaves are device-major ``[dp, ms, (n_groups,) s_blk,
    rows, hd]`` and only the ``[0, 0]`` shard is touched (a single-rank
    corruption, the realistic HBM-flip case)."""
    def poison(entry):
        k = np.array(jax.device_get(entry.k))
        B = entry.pos.shape[-1]
        rows_per = k.shape[-2] // B
        sl = slice(slot * rows_per, (slot + 1) * rows_per)
        k[0, 0, ..., 0, sl, :] = value
        return entry._replace(k=_put_back(k, entry.k))

    new = dict(state)
    layers = list(state["layers"])
    for i, entry in enumerate(layers):
        if hasattr(entry, "k"):
            layers[i] = poison(entry)
            new["layers"] = layers
            return new
    tail = list(state["tail"])
    for i, entry in enumerate(tail):
        if hasattr(entry, "k"):
            tail[i] = poison(entry)
            new["tail"] = tail
            return new
    raise ValueError("no attention cache in state to corrupt")


def corrupt_cache_lens(state: Dict[str, Any], slot: int,
                       value: int) -> Dict[str, Any]:
    """Force ``cache_lens[slot]`` to ``value`` on every rank (the
    uniform-corruption case: shards still agree, so only the bounds
    probe can catch it — pick ``value`` outside ``[−1, max_seq]``)."""
    lens = np.array(jax.device_get(state["cache_lens"]))
    lens[..., slot] = value
    new = dict(state)
    new["cache_lens"] = _put_back(lens, state["cache_lens"])
    return new


def poison_embed(params: Dict[str, Any], seed: int) -> Dict[str, Any]:
    """Return a COPY of the serve param tree whose embedding table has
    one ``d_model`` column poisoned with NaN or Inf (seed-chosen), so
    every token's embedding — and therefore the residual stream — goes
    non-finite regardless of how the table is sharded."""
    rng = np.random.default_rng(seed)
    bad = float(rng.choice([np.nan, np.inf, -np.inf]))
    emb = np.array(jax.device_get(params["embed"]), np.float32)
    col = int(rng.integers(emb.shape[-1]))
    emb[..., col] = bad
    new = dict(params)
    new["embed"] = _put_back(emb.astype(
        np.asarray(jax.device_get(params["embed"])).dtype), params["embed"])
    return new


def _uint_view(a: np.ndarray) -> np.ndarray:
    """Same-buffer unsigned view for single-bit XOR (bf16 → uint16,
    f32/int32 → uint32): mutating the view mutates ``a``."""
    return a.view(np.dtype(f"uint{a.dtype.itemsize * 8}"))


def flip_kv_bit(state: Dict[str, Any], slot: int, bit: int,
                seed: int = 0) -> Dict[str, Any]:
    """XOR bit ``bit`` of ONE seed-chosen K element in ``slot``'s rank-0
    rows at sequence position 0 of the first attention cache (live for
    any active slot, like :func:`corrupt_kv_slot` — but a single flipped
    bit instead of a NaN, so the non-finite sentinel stays silent and
    only the KV fingerprint can see it)."""
    def flip(entry):
        k = np.array(jax.device_get(entry.k))
        B = entry.pos.shape[-1]
        rows_per = k.shape[-2] // B
        rng = np.random.default_rng(seed)
        r = slot * rows_per + int(rng.integers(rows_per))
        c = int(rng.integers(k.shape[-1]))
        idx = (0, 0) + (0,) * (k.ndim - 5) + (0, r, c)
        u = _uint_view(k)
        u[idx] ^= np.asarray(1 << bit, u.dtype)
        return entry._replace(k=_put_back(k, entry.k))

    new = dict(state)
    for field in ("layers", "tail"):
        entries = list(state[field])
        for i, entry in enumerate(entries):
            if hasattr(entry, "k"):
                entries[i] = flip(entry)
                new[field] = entries
                return new
    raise ValueError("no attention cache in state to corrupt")


def flip_weight_bit(params: Dict[str, Any], target: int, bit: int,
                    seed: int = 0) -> Tuple[Dict[str, Any], str]:
    """XOR bit ``bit`` of one seed-chosen element of serve-tree array
    leaf ``target`` (modular index into
    :func:`repro.serving.integrity.weight_leaves` order — the SAME
    enumeration the monitor fingerprints, so sweeps and probes address
    leaves identically).  Returns ``(corrupted tree, leaf name)``; the
    caller installs the tree as the replica's live serve params (a
    persistent flip, unlike ``poison_weight``'s shadow copy)."""
    from repro.serving.integrity import weight_leaves
    names = [n for n, _ in weight_leaves(params)]
    flat, treedef = jax.tree_util.tree_flatten(params)
    arr_pos = [j for j, l in enumerate(flat)
               if hasattr(l, "dtype") and hasattr(l, "shape")]
    sel = target % len(arr_pos)
    pos, name = arr_pos[sel], names[sel]
    leaf = flat[pos]
    a = np.array(jax.device_get(leaf))
    rng = np.random.default_rng(seed)
    u = _uint_view(a.reshape(-1))
    i = int(rng.integers(a.size))
    u[i] ^= np.asarray(1 << bit, u.dtype)
    flat[pos] = _put_back(a, leaf)
    return jax.tree_util.tree_unflatten(treedef, flat), name


# ---------------------------------------------------------------------------
# The injector: SchedulerHooks driven by FaultSpecs
# ---------------------------------------------------------------------------
class FaultInjector(SchedulerHooks):
    """Applies each armed spec exactly once at (or, for faults that
    need a carrier event, at the first opportunity after) its step.
    ``fired`` records ``(spec, actual_tick)`` so tests and the bench
    can measure injection-to-detection latency in ticks."""

    def __init__(self, specs: Sequence[FaultSpec]):
        seen: set = set()
        for s in specs:
            key = (s.kind, s.target, s.step, s.replica)
            if key in seen:
                raise ValueError(
                    f"duplicate FaultSpec (kind, target, step, replica)="
                    f"{key}: each fault fires exactly once, so two specs "
                    f"at the same address are a harness bug")
            seen.add(key)
        self.specs: List[FaultSpec] = sorted(specs, key=lambda s: s.step)
        self.fired: List[Tuple[FaultSpec, int]] = []
        self.flipped_weight: List[str] = []
        self._done: set = set()
        self._poisoned_params = None
        self._blackholed = False

    def _due(self, sched: SlotScheduler,
             kind: str) -> List[Tuple[int, FaultSpec]]:
        out = []
        for i, s in enumerate(self.specs):
            if s.kind == kind and i not in self._done and \
                    sched.tick >= s.step:
                out.append((i, s))
        return out

    def _mark(self, i: int, spec: FaultSpec, tick: int) -> None:
        self._done.add(i)
        self.fired.append((spec, tick))

    # -- hook protocol ----------------------------------------------------
    def pre_step(self, sched: SlotScheduler) -> None:
        for i, s in self._due(sched, "kill"):
            self._mark(i, s, sched.tick)
            raise ReplicaKilled(f"fault-injected kill at tick {sched.tick}")

    def admit_args(self, sched: SlotScheduler, toks, lens):
        for i, s in self._due(sched, "drop_admit"):
            if lens[s.target] > 0:       # needs a carrier admit to drop
                lens = np.array(lens)
                lens[s.target] = 0
                self._mark(i, s, sched.tick)
        return toks, lens

    def post_admit(self, sched: SlotScheduler) -> None:
        for i, s in self._due(sched, "dup_admit"):
            self._mark(i, s, sched.tick)
            exp = int(sched.expected_cache_lens()[s.target])
            # a prompt length ≠ the host's expected cache length, so the
            # duplicate is the harmful (state-changing) kind; the token
            # buffer stays prompt_cap wide like every real admit (the
            # jitted program is shape-specialized — and cluster-sharded
            # prefill requires the padded width)
            want = exp + 1
            plen = want if 1 <= want <= sched.prompt_cap \
                else max(1, exp - 1)
            rng = np.random.default_rng(s.seed)
            toks = np.zeros((sched.n_slots, sched.prompt_cap), np.int32)
            toks[s.target, :plen] = rng.integers(
                sched.eng.cfg.vocab_size, size=(plen,))
            lens = np.zeros((sched.n_slots,), np.int32)
            lens[s.target] = plen
            _, sched.state = sched.eng.admit_fn(
                sched.eng.params["train"], sched.state, toks, lens)

    def decode_args(self, sched: SlotScheduler, params, state, tokens):
        for i, s in self._due(sched, "corrupt_kv"):
            self._mark(i, s, sched.tick)
            state = corrupt_kv_slot(state, s.target)
        for i, s in self._due(sched, "corrupt_lens"):
            self._mark(i, s, sched.tick)
            state = corrupt_cache_lens(state, s.target,
                                       sched.eng.scfg.max_seq + 7)
        for i, s in self._due(sched, "poison_weight"):
            self._mark(i, s, sched.tick)
            self._poisoned_params = poison_embed(params, s.seed)
        for i, s in self._due(sched, "flip_kv_bit"):
            self._mark(i, s, sched.tick)
            state = flip_kv_bit(state, s.target, s.bit, s.seed)
        for i, s in self._due(sched, "flip_weight_bit"):
            self._mark(i, s, sched.tick)
            # a PERSISTENT flip: the replica's real serve tree is
            # replaced, so every subsequent decode uses the corrupted
            # leaf until the router's heal path repacks from train
            new_serve, name = flip_weight_bit(
                sched.eng.params["serve"], s.target, s.bit, s.seed)
            sched.eng.params["serve"] = new_serve
            self.flipped_weight.append(name)
            params = new_serve
        if self._poisoned_params is not None:   # weights STAY poisoned
            params = self._poisoned_params
        return params, state, tokens

    def decode_blackholed(self, sched: SlotScheduler) -> bool:
        if self._blackholed:
            return True
        for i, s in self._due(sched, "blackhole"):
            self._mark(i, s, sched.tick)
            self._blackholed = True     # the link stays dark
        return self._blackholed


# ---------------------------------------------------------------------------
# Systematic sweep grids (DAVOS-style fault loads)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultSweep:
    """Systematic (kind × target × bit × step × replica) grid of
    single-bit fault specs.  ``targets`` are batch slots for
    ``flip_kv_bit`` and serve-leaf indices for ``flip_weight_bit``;
    ``bits`` are bf16 bit addresses (0–6 mantissa, 7–14 exponent, 15
    sign).  The sweep harness (serving/sweep.py) runs ONE spec per
    router run, so the grid measures per-fault detection coverage and
    latency, not fault interactions."""
    kinds: Tuple[str, ...] = BIT_FAULT_KINDS
    targets: Tuple[int, ...] = (0,)
    bits: Tuple[int, ...] = tuple(range(16))
    steps: Tuple[int, ...] = (2,)
    replicas: Tuple[int, ...] = (0,)
    seed: int = 0

    def specs(self) -> List[FaultSpec]:
        """The grid, in deterministic (kind, target, bit, step,
        replica) lexicographic order.  Every spec validates through
        :class:`FaultSpec` construction."""
        return [FaultSpec(kind, step, target=t, seed=self.seed,
                          replica=r, bit=b)
                for kind in self.kinds
                for t in self.targets
                for b in self.bits
                for step in self.steps
                for r in self.replicas]
