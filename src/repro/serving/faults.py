"""Deterministic fault injection for the fleet serving layer.

Every fault is a declarative :class:`FaultSpec` — ``(kind, step,
target, seed, replica)`` — applied through the scheduler's
:class:`~repro.serving.scheduler.SchedulerHooks` extension points by
:class:`FaultInjector`, never by monkeypatching: the injector IS the
hooks object the scheduler was built with, so every perturbation is
visible in the call graph and reproducible from the spec alone.

Fault taxonomy (DESIGN.md §9) and the probe each one trips:

* ``kill`` — the replica dies inside its step (:class:`ReplicaKilled`
  raised from ``pre_step``); caught by the router's heartbeat.
* ``blackhole`` — the decode call never returns; the host loop
  proceeds on a stale echo of its own inputs while device state
  freezes → the expected-``cache_lens`` cross-check trips.
* ``corrupt_kv`` — NaN-poison the target slot's rank-0 KV rows at
  sequence position 0 (live for any active slot, so the poison reaches
  the attention scores on the very next decode) → non-finite sentinel.
* ``corrupt_lens`` — the target slot's ``cache_lens`` entry is forced
  out of ``[−1, max_seq]`` on every rank → bounds check.
* ``poison_weight`` — NaN/Inf into a column of the serve-layout
  embedding table (a poisoned COPY is fed to every subsequent decode
  call; the replica's real params are never mutated, so test fixtures
  can reuse the engine) → non-finite sentinel.
* ``drop_admit`` — the device admit call sees length 0 for the target
  slot while host bookkeeping proceeds → expected-lens mismatch.
* ``dup_admit`` — an extra device-side admit is injected into the
  target slot with a prompt length chosen to differ from the host's
  expected ``cache_lens`` → expected-lens mismatch.  (A byte-identical
  re-admit would be idempotent by construction — re-prefill of the
  same prefix writes the same cache — so the harmful variant is the
  one with different state, and that is what the harness injects.)

All corruption is host-side ``device_get → mutate → device_put`` with
the leaf's own sharding, so the injected state round-trips through the
same jitted programs as real state.  Everything is seeded and
step-addressed: the same spec over the same trace perturbs the same
bytes every run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.serving.scheduler import SchedulerHooks, SlotScheduler

FAULT_KINDS = ("kill", "blackhole", "corrupt_kv", "corrupt_lens",
               "poison_weight", "drop_admit", "dup_admit")


class ReplicaKilled(RuntimeError):
    """The replica process is gone mid-step; the router's heartbeat
    converts this into a drain + re-queue (serving/router.py)."""


@dataclass(frozen=True)
class FaultSpec:
    """One declarative fault: ``kind`` fires at scheduler tick ``step``
    on ``replica``; ``target`` addresses a batch slot where relevant
    (``corrupt_kv`` / ``corrupt_lens`` / ``drop_admit`` / ``dup_admit``);
    ``seed`` drives any generated corruption bytes."""
    kind: str
    step: int
    target: int = 0
    seed: int = 0
    replica: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")


# ---------------------------------------------------------------------------
# Host-side state corruption (device_get → mutate → device_put)
# ---------------------------------------------------------------------------
def _put_back(host: np.ndarray, leaf) -> jax.Array:
    return jax.device_put(host, leaf.sharding)


def corrupt_kv_slot(state: Dict[str, Any], slot: int,
                    value: float = np.nan) -> Dict[str, Any]:
    """Poison ``slot``'s rank-0 K rows at sequence position 0 of the
    first attention cache.  Position 0 is live for every active slot,
    so the poison lands in the attention scores on the next decode
    step; state leaves are device-major ``[dp, ms, (n_groups,) s_blk,
    rows, hd]`` and only the ``[0, 0]`` shard is touched (a single-rank
    corruption, the realistic HBM-flip case)."""
    def poison(entry):
        k = np.array(jax.device_get(entry.k))
        B = entry.pos.shape[-1]
        rows_per = k.shape[-2] // B
        sl = slice(slot * rows_per, (slot + 1) * rows_per)
        k[0, 0, ..., 0, sl, :] = value
        return entry._replace(k=_put_back(k, entry.k))

    new = dict(state)
    layers = list(state["layers"])
    for i, entry in enumerate(layers):
        if hasattr(entry, "k"):
            layers[i] = poison(entry)
            new["layers"] = layers
            return new
    tail = list(state["tail"])
    for i, entry in enumerate(tail):
        if hasattr(entry, "k"):
            tail[i] = poison(entry)
            new["tail"] = tail
            return new
    raise ValueError("no attention cache in state to corrupt")


def corrupt_cache_lens(state: Dict[str, Any], slot: int,
                       value: int) -> Dict[str, Any]:
    """Force ``cache_lens[slot]`` to ``value`` on every rank (the
    uniform-corruption case: shards still agree, so only the bounds
    probe can catch it — pick ``value`` outside ``[−1, max_seq]``)."""
    lens = np.array(jax.device_get(state["cache_lens"]))
    lens[..., slot] = value
    new = dict(state)
    new["cache_lens"] = _put_back(lens, state["cache_lens"])
    return new


def poison_embed(params: Dict[str, Any], seed: int) -> Dict[str, Any]:
    """Return a COPY of the serve param tree whose embedding table has
    one ``d_model`` column poisoned with NaN or Inf (seed-chosen), so
    every token's embedding — and therefore the residual stream — goes
    non-finite regardless of how the table is sharded."""
    rng = np.random.default_rng(seed)
    bad = float(rng.choice([np.nan, np.inf, -np.inf]))
    emb = np.array(jax.device_get(params["embed"]), np.float32)
    col = int(rng.integers(emb.shape[-1]))
    emb[..., col] = bad
    new = dict(params)
    new["embed"] = _put_back(emb.astype(
        np.asarray(jax.device_get(params["embed"])).dtype), params["embed"])
    return new


# ---------------------------------------------------------------------------
# The injector: SchedulerHooks driven by FaultSpecs
# ---------------------------------------------------------------------------
class FaultInjector(SchedulerHooks):
    """Applies each armed spec exactly once at (or, for faults that
    need a carrier event, at the first opportunity after) its step.
    ``fired`` records ``(spec, actual_tick)`` so tests and the bench
    can measure injection-to-detection latency in ticks."""

    def __init__(self, specs: Sequence[FaultSpec]):
        self.specs: List[FaultSpec] = sorted(specs, key=lambda s: s.step)
        self.fired: List[Tuple[FaultSpec, int]] = []
        self._done: set = set()
        self._poisoned_params = None
        self._blackholed = False

    def _due(self, sched: SlotScheduler,
             kind: str) -> List[Tuple[int, FaultSpec]]:
        out = []
        for i, s in enumerate(self.specs):
            if s.kind == kind and i not in self._done and \
                    sched.tick >= s.step:
                out.append((i, s))
        return out

    def _mark(self, i: int, spec: FaultSpec, tick: int) -> None:
        self._done.add(i)
        self.fired.append((spec, tick))

    # -- hook protocol ----------------------------------------------------
    def pre_step(self, sched: SlotScheduler) -> None:
        for i, s in self._due(sched, "kill"):
            self._mark(i, s, sched.tick)
            raise ReplicaKilled(f"fault-injected kill at tick {sched.tick}")

    def admit_args(self, sched: SlotScheduler, toks, lens):
        for i, s in self._due(sched, "drop_admit"):
            if lens[s.target] > 0:       # needs a carrier admit to drop
                lens = np.array(lens)
                lens[s.target] = 0
                self._mark(i, s, sched.tick)
        return toks, lens

    def post_admit(self, sched: SlotScheduler) -> None:
        for i, s in self._due(sched, "dup_admit"):
            self._mark(i, s, sched.tick)
            exp = int(sched.expected_cache_lens()[s.target])
            # a prompt length ≠ the host's expected cache length, so the
            # duplicate is the harmful (state-changing) kind; the token
            # buffer stays prompt_cap wide like every real admit (the
            # jitted program is shape-specialized — and cluster-sharded
            # prefill requires the padded width)
            want = exp + 1
            plen = want if 1 <= want <= sched.prompt_cap \
                else max(1, exp - 1)
            rng = np.random.default_rng(s.seed)
            toks = np.zeros((sched.n_slots, sched.prompt_cap), np.int32)
            toks[s.target, :plen] = rng.integers(
                sched.eng.cfg.vocab_size, size=(plen,))
            lens = np.zeros((sched.n_slots,), np.int32)
            lens[s.target] = plen
            _, sched.state = sched.eng.admit_fn(
                sched.eng.params["train"], sched.state, toks, lens)

    def decode_args(self, sched: SlotScheduler, params, state, tokens):
        for i, s in self._due(sched, "corrupt_kv"):
            self._mark(i, s, sched.tick)
            state = corrupt_kv_slot(state, s.target)
        for i, s in self._due(sched, "corrupt_lens"):
            self._mark(i, s, sched.tick)
            state = corrupt_cache_lens(state, s.target,
                                       sched.eng.scfg.max_seq + 7)
        for i, s in self._due(sched, "poison_weight"):
            self._mark(i, s, sched.tick)
            self._poisoned_params = poison_embed(params, s.seed)
        if self._poisoned_params is not None:   # weights STAY poisoned
            params = self._poisoned_params
        return params, state, tokens

    def decode_blackholed(self, sched: SlotScheduler) -> bool:
        if self._blackholed:
            return True
        for i, s in self._due(sched, "blackhole"):
            self._mark(i, s, sched.tick)
            self._blackholed = True     # the link stays dark
        return self._blackholed
