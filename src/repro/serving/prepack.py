"""Decode-plan weight prepacking: training layout → serve layout, once
at weight-load time (DESIGN.md §2/§5).

The Pallas decode path's weight-segment ClusterGather is step-invariant
(``x·gather(W) == gather(x·W)`` — the hoisted Alg. 3 line 3), yet the
adapter path re-runs it inside every decode step, paying
``O(D·heads·hd)`` ICI bytes per layer per token; both backends
additionally re-slice ``wo``/``wuk``/``wuv`` with ``lax.dynamic_slice``
per layer.  :func:`prepack_for_serving` eliminates all of it by
materializing, ONCE, the per-rank tensors each backend actually
consumes:

* ``backend="pallas"`` → :class:`~repro.core.dataflow.PackedSplitTokenWeights`
  (cluster-gathered ``wqkv`` + fused bias + per-head ``wo`` column
  tiles for the in-kernel ``fuse_out="partial_o"`` projection) and
  :class:`~repro.core.dataflow.PackedMLAWeights` (gathered ``wq``/
  ``wdkv``, full ``wuk``, and the folded ``wproj = W_UV · W_O(cols)``).
* ``backend="xla"`` → plain :class:`~repro.core.dataflow.SplitTokenWeights`
  / :class:`~repro.core.dataflow.MLAWeights` with the rank slices taken
  up front (the XLA dataflow keeps its activation gathers — those are
  the paper's schedule and move only ``O(B·heads·hd)`` bytes).

Everything operates on the GLOBAL device-major tree (``[model_size,
*local]`` leaves, models/transformer.py), so the transform is pure
reshape / transpose / slice — no collectives — and a single
``jax.jit(..., out_shardings=...)`` call redistributes the packed
tensors device-major at load.  The packed tree is DERIVED state: it is
never checkpointed (checkpoint/manager.py strips it) and is rebuilt
from the training-layout weights on every launch.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.dataflow import (MLAWeights, PackedFFNWeights,
                                 PackedHeadWeights, PackedMLAWeights,
                                 PackedSplitTokenWeights, SplitTokenWeights)
from repro.models.attention import AttnParams, MLAAttnParams
from repro.models.layers import FFNParams
from repro.models.transformer import Layout

PyTree = Any


# ---------------------------------------------------------------------------
# Global device-major layout transforms (axis indices INCLUDE the leading
# model dim at 0; stacked group dims are handled by vmap in the tree pass)
# ---------------------------------------------------------------------------
def _gather_seg(x: jax.Array, hs: int, n: int, axis: int) -> jax.Array:
    """Materialize, per rank, the cluster-gathered ``axis`` — exactly what
    ``cluster_gather_tiled`` produces per step at runtime (segment of
    cluster rank c lands at offset c), replicated over the cluster
    sub-axis.  Device order is heads-major (rank = h·N + c)."""
    if n == 1:
        return x
    g = x.reshape((hs, n) + x.shape[1:])       # dim `axis` now at axis+1
    g = jnp.moveaxis(g, 1, axis)               # n right before the seg dim
    shp = g.shape
    g = g.reshape(shp[:axis] + (shp[axis] * shp[axis + 1],) + shp[axis + 2:])
    g = jnp.broadcast_to(g[:, None], (hs, n) + g.shape[1:])
    return g.reshape((hs * n,) + g.shape[2:])


def _col_tile(x: jax.Array, hs: int, n: int, axis: int) -> jax.Array:
    """Per-rank column tile of ``axis``: rank (h, c) keeps columns
    ``[c·d/N, (c+1)·d/N)`` — the slice ``_split_token_weights`` /
    ``_mla_weights`` used to take dynamically every layer, every step."""
    if n == 1:
        return x
    dn = x.shape[axis] // n
    g = x.reshape((hs, n) + x.shape[1:])
    tiles = [lax.slice_in_dim(g[:, c], c * dn, (c + 1) * dn, axis=axis)
             for c in range(n)]
    g = jnp.stack(tiles, axis=1)               # [hs, n, ..., d/N, ...]
    return g.reshape((hs * n,) + g.shape[2:])


def _pack_attn(cfg: ModelConfig, lay: Layout, backend: str, a: AttnParams,
               ln1=None):
    hs, n = lay.heads_sub, lay.cluster
    if backend != "pallas":
        # XLA dataflow keeps the train-layout segments; only the rank
        # slices move to load time.
        return SplitTokenWeights(wq=a.wq, wk=a.wk, wv=a.wv,
                                 wo=_col_tile(a.wo, hs, n, 2),
                                 bq=a.bq, bk=a.bk, bv=a.bv)
    ms, D, q_loc, hd_n = a.wq.shape
    kv_loc = a.wk.shape[2]
    hd = hd_n * n
    wq = _gather_seg(a.wq, hs, n, 3).reshape(ms, D, q_loc * hd)
    wk = _gather_seg(a.wk, hs, n, 3).reshape(ms, D, kv_loc * hd)
    wv = _gather_seg(a.wv, hs, n, 3).reshape(ms, D, kv_loc * hd)
    wqkv = jnp.concatenate([wq, wk, wv], axis=2)
    bqkv = None
    if a.bq is not None:
        bqkv = jnp.concatenate(
            [_gather_seg(a.bq, hs, n, 2).reshape(ms, q_loc * hd),
             _gather_seg(a.bk, hs, n, 2).reshape(ms, kv_loc * hd),
             _gather_seg(a.bv, hs, n, 2).reshape(ms, kv_loc * hd)], axis=1)
    # Full-width Output-Projection rows, per head.  Every cluster rank
    # projects into the SAME [D] output basis, so the in-kernel partial_o
    # tiles are summable by the flash merge (a per-rank *column* tile
    # would put each rank's partial in a different basis and break the
    # single-ClusterReduce combine) and the post-combine cluster gather
    # of the output vanishes.
    wo = a.wo.reshape(ms, q_loc, hd, a.wo.shape[-1])
    # Pre-attention RMSNorm scale rides the pack: the kernel normalizes
    # the raw residual stream in VMEM (DESIGN.md §7).
    return PackedSplitTokenWeights(wqkv=wqkv, wo=wo, bqkv=bqkv, ln1=ln1)


def _pack_mla(cfg: ModelConfig, lay: Layout, backend: str, a: MLAAttnParams,
              ln1=None):
    hs, n = lay.heads_sub, lay.cluster
    if backend != "pallas":
        return MLAWeights(wq=a.wq, wdkv=a.wdkv,
                          wuk=_col_tile(a.wuk, hs, n, 3),
                          wuv=_col_tile(a.wuv, hs, n, 2),
                          wo=_col_tile(a.wo, hs, n, 2))
    m = cfg.mla
    ms, D = a.wq.shape[0], a.wq.shape[1]
    q_loc = a.wuk.shape[1]
    v_dim = a.wuv.shape[-1]
    wq = _gather_seg(a.wq, hs, n, 3)           # [ms, D, q, nope+rope]
    wq2 = wq.reshape(ms, D, q_loc * (m.nope_head_dim + m.rope_head_dim))
    wdkv = _gather_seg(a.wdkv, hs, n, 2)       # [ms, D, l_rank+rope]
    # wuk/wuv are stored full (replicated over the cluster) in the train
    # layout — the adapter sliced them only to re-gather on the Pallas
    # path, so the packed form is the stored tensor itself.
    wo4 = a.wo.reshape(ms, q_loc, v_dim, a.wo.shape[-1])
    # Fold value Up-Projection into the full-width Output-Projection rows
    # — one per-head matrix, applied in-kernel (fuse_out="partial_o").
    # Full [D] width keeps every cluster rank's partial in the same
    # output basis (summable by the flash merge, no post-combine gather).
    wproj = jnp.einsum("mqlv,mqvd->mqld", a.wuv.astype(jnp.float32),
                       wo4.astype(jnp.float32)).astype(a.wo.dtype)
    return PackedMLAWeights(wq=wq2, wdkv=wdkv, wuk=a.wuk, wproj=wproj,
                            ln1=ln1)


# ---------------------------------------------------------------------------
# Tree pass
# ---------------------------------------------------------------------------
def map_blocks(fn, params: PyTree, *others: PyTree) -> PyTree:
    """THE traversal of the attention-bearing block lists: apply
    ``fn(blk, *other_blks, stacked)`` to each entry of ``"blocks"``
    (stacked scan leaves) and ``"tail"`` (unstacked), preserving every
    other top-level entry of ``params``.  Extra trees zip positionally.
    All serve-layout passes (pack, subtree projection, alias merge, and
    the engine's per-step hoist) share this walk so a new
    attention-bearing subtree only has to be taught here."""
    out = dict(params)
    out["blocks"] = [fn(*bs, True) for bs in
                     zip(params["blocks"], *(o["blocks"] for o in others))]
    out["tail"] = [fn(*bs, False) for bs in
                   zip(params["tail"], *(o["tail"] for o in others))]
    return out


def _ffn_packable(cfg: ModelConfig, backend: str, blk: Dict[str, Any]) -> bool:
    """The fused block-tail megakernel applies to dense-FFN self-attention
    blocks on the Pallas backend.  MoE blocks keep the XLA expert
    dispatch, enc-dec blocks interleave cross-attention between the
    residual adds, and recurrent/RWKV blocks have their own fused steps
    (DESIGN.md §4/§7)."""
    return (backend == "pallas" and cfg.encoder is None
            and isinstance(blk.get("attn"), (AttnParams, MLAAttnParams,
                                             PackedSplitTokenWeights,
                                             PackedMLAWeights))
            and isinstance(blk.get("ffn"), FFNParams))


def _pack_ffn(blk: Dict[str, Any]) -> PackedFFNWeights:
    """Pure-aliasing FFN bundle: the Megatron train layout is already the
    serve layout (column gate/up, FULL-width down rows), so no tensor is
    re-materialized — the bundle just binds the fused norm scales."""
    f: FFNParams = blk["ffn"]
    return PackedFFNWeights(w_in=f.w_in, w_out=f.w_out, ln2=blk["ln2"],
                            w_gate=f.w_gate,
                            post_ln1=blk.get("post_ln1"))


def bundle_ffn(cfg: ModelConfig, params: PyTree, *,
               backend: str = "pallas") -> PyTree:
    """Replace every packable dense-FFN entry with its
    :class:`PackedFFNWeights` bundle — a structural pass (NamedTuple
    wrapping of the existing buffers, zero copies), valid on param AND
    spec trees.  Kept separate from the jitted attention pack so the FFN
    bytes never round-trip through ``jax.jit`` (which would duplicate
    them instead of aliasing — DESIGN.md §5)."""
    def bb(blk, stacked):
        if not _ffn_packable(cfg, backend, blk):
            return blk
        return dict(blk, ffn=_pack_ffn(blk))

    return map_blocks(bb, params)


def bundle_head(cfg: ModelConfig, params: PyTree, *,
                backend: str = "pallas") -> PyTree:
    """Bind the LM-head/sampling tail's serve view: a pure-aliasing
    :class:`PackedHeadWeights` under the top-level ``"head"`` key
    (``table`` aliases the tied ``embed`` buffer or ``lm_head``, ``ln``
    aliases ``final_norm`` — zero bytes duplicated).  The decode step
    dispatches the fused head kernel on its presence
    (``engine._fused_head_tail``); the XLA backend keeps the loose
    ``lm_head_logits``/``greedy_sample`` tail.  Structural (NamedTuple
    wrapping of existing leaves), valid on param AND spec trees; kept
    outside the jitted attention pack like :func:`bundle_ffn` so the
    table never round-trips through ``jax.jit``."""
    key = "embed" if cfg.tie_embeddings else "lm_head"
    if backend != "pallas" or key not in params:
        # subtree passes (the jitted attention pack) carry no head leaves
        return params
    return dict(params, head=PackedHeadWeights(table=params[key],
                                               ln=params["final_norm"]))


def head_view(cfg: ModelConfig, params: PyTree) -> PackedHeadWeights:
    """The (table, ln) view the DECODE step actually samples with.

    Accepts ``build_engine``'s ``{"train", "serve"}`` pair or a bare
    param tree; returns the serve tree's :class:`PackedHeadWeights`
    when the head is bundled (fused tail), else the equivalent view of
    the unfused tail's leaves.  Examples route token printing through
    this helper instead of reaching into the train tree — with prepack
    on, the train view is NOT what sampling consumed (they alias today,
    but only because the head bundle is pure aliasing; the helper is
    the contract, the aliasing the implementation)."""
    if isinstance(params, dict) and {"train", "serve"} <= params.keys():
        params = params["serve"]
    h = params.get("head")
    if isinstance(h, PackedHeadWeights):
        return h
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return PackedHeadWeights(table=table, ln=params["final_norm"])


def head_table_np(cfg: ModelConfig, params: PyTree):
    """Serve-view head table as a ``[V, D]`` numpy array (device-major
    vocab shards flattened back to global order) — the examples' token-
    printout path.  Handed the engine's ``{"train", "serve"}`` pair it
    ALSO smoke-asserts the serve view aliases the train-layout head
    bytes (the head bundle is pure aliasing; a mismatch means the pack
    materialized or drifted)."""
    import numpy as np

    hv = head_view(cfg, params)
    tab = np.asarray(hv.table, np.float32).reshape(-1, cfg.d_model)
    if isinstance(params, dict) and {"train", "serve"} <= params.keys():
        src = "embed" if cfg.tie_embeddings else "lm_head"
        np.testing.assert_array_equal(
            tab, np.asarray(params["train"][src],
                            np.float32).reshape(-1, cfg.d_model))
    return tab


def prepack_for_serving(cfg: ModelConfig, lay: Layout, params: PyTree,
                        *, backend: str = "pallas") -> PyTree:
    """Training-layout device-major params → serve-layout params.

    Replaces every self-attention block's ``attn`` entry with the
    backend's packed form (carrying the fused pre-attention norm scale
    on the Pallas backend) and — for dense-FFN attention blocks on the
    Pallas backend — the ``ffn`` entry with the aliasing
    :class:`PackedFFNWeights` bundle, plus the aliasing
    :class:`PackedHeadWeights` tail bundle (:func:`bundle_head`); every
    other leaf (MoE, norms, recurrent blocks, embeddings, encoder,
    cross-attention) rides through untouched.  Pure layout math — run it under ``jax.jit`` with
    ``out_shardings`` to materialize device-major (launch/serve.py jits
    only the attention subtree and applies :func:`bundle_ffn` outside
    the jit, so FFN bytes stay aliased).
    """
    def pack_block(blk: Dict[str, Any], stacked: bool) -> Dict[str, Any]:
        a = blk.get("attn")
        if isinstance(a, MLAAttnParams):
            fn = partial(_pack_mla, cfg, lay, backend)
        elif isinstance(a, AttnParams):
            fn = partial(_pack_attn, cfg, lay, backend)
        else:
            return blk
        out = dict(blk)
        out["attn"] = (jax.vmap(fn, in_axes=(1, 1), out_axes=1)(
            a, blk["ln1"]) if stacked else fn(a, blk["ln1"]))
        return out

    return bundle_head(cfg, bundle_ffn(cfg, map_blocks(pack_block, params),
                                       backend=backend), backend=backend)


def prepack_abstract(cfg: ModelConfig, lay: Layout, params_abs: PyTree,
                     *, backend: str = "pallas") -> PyTree:
    """Shape-only prepack (for spec construction / dry runs)."""
    return jax.eval_shape(
        partial(prepack_for_serving, cfg, lay, backend=backend), params_abs)


def attn_subtree(params: PyTree) -> PyTree:
    """``{"blocks": …, "tail": …}`` carrying ONLY the attention entries
    (plus their pre-attention norm scale, which the Pallas pack fuses
    into the kernel) — the subset the jitted pack actually transforms.
    launch/serve.py jits the pack over this subtree so the serve tree
    duplicates no FFN/MoE/embedding bytes: everything else is aliased
    from the training tree (:func:`merge_packed`; the FFN bundle is the
    separate no-copy :func:`bundle_ffn` pass)."""
    def pick(blk, stacked):
        if "attn" not in blk:
            return {}
        return {"attn": blk["attn"], "ln1": blk["ln1"]}
    return map_blocks(pick, {"blocks": params["blocks"],
                             "tail": params["tail"]})


def merge_packed(params: PyTree, packed_attn: PyTree) -> PyTree:
    """Serve tree = packed subtree entries + every other leaf ALIASED
    from the training tree (same buffers, no duplication).  Works on
    spec trees too.  The residual memory cost of serving with prepack is
    therefore only the packed attention tensors themselves (DESIGN.md
    §5)."""
    def mb(tb, pb, stacked):
        return dict(tb, **pb) if pb else tb
    return map_blocks(mb, params, packed_attn)
