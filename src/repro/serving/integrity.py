"""Silent-data-corruption (SDC) detection for the fused decode path.

Full-block fusion deletes the intermediate HBM writes where operator-
boundary sanity checks would otherwise live (DESIGN.md §7), and the
router's PR-6 probes only see fail-stop and non-finite faults — a bit
flip below the non-finite floor (any mantissa bit, most exponent bits)
changes committed tokens silently.  This module closes that gap with
three probes, all host-side and wired into the router's per-tick probe
loop (serving/router.py, DESIGN.md §9):

1. **KV-cache fingerprints** — every attention cache entry carries a
   per-slot int32 checksum leaf (``state["kv_fp"]`` /
   ``state["kv_fp_tail"]``, one [B] vector per entry) over the BIT
   PATTERNS of its K/V rows.  The decode step updates it incrementally
   on append/ring-wrap (:func:`kv_fp_delta` — masked by the per-row
   ``pos`` change, inside the fused program where the cache is already
   resident); the admit insert recomputes admitted slots from scratch
   (:func:`kv_entry_fp` — a re-admit can rewrite rows without moving
   ``pos``).  The probe re-derives every slot's checksum on the host
   and compares EXACTLY: integer bit-pattern sums are associative and
   commutative, so accumulation order cannot manufacture a mismatch
   (an f32 checksum would false-positive on reassociation — the
   refinement over the naive scheme, DESIGN.md §9), and ANY single-bit
   flip in a cached row is caught on the next probe (≤ 1 tick).
2. **Weight fingerprints** — per-leaf checksums of the serve tree taken
   at monitor construction (prepack time), spot-checked on a rotating
   schedule of ``weight_leaves_per_tick`` leaves so the per-tick probe
   cost is bounded.  Full coverage takes ``ceil(n_leaves / per_tick)``
   ticks — the monitor's :meth:`IntegrityMonitor.commit_lag` — and the
   router defers journal commits by exactly that window, so a flip
   detected at the END of a rotation still fails the probe before any
   token it influenced commits.
3. **Shadow recompute** — the decode step stashes each slot's pre-head
   residual, winning logit and sampled token
   (``ServeConfig.shadow_head``); the probe re-derives the winning
   logit on the host (final RMSNorm → bf16 round → f32 dot against a
   PRISTINE copy of the head table cached at monitor init → softcap)
   for one rotating slot per tick.  Catches head-path corruption the
   checksums cannot see (a flipped head-table or final-norm bit flows
   into tokens without touching any fingerprinted state).

Probe overhead is accounted in :mod:`repro.core.tracecount`'s probe
counters (``probe_bytes_kv`` / ``probe_bytes_weights`` /
``probe_bytes_shadow`` / ``probe_ticks``) so the bench can report
bytes-per-tick and CI can gate it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import tracecount

PyTree = Any


# ---------------------------------------------------------------------------
# Bit-pattern checksums (device side: jnp, int32 wraparound arithmetic)
# ---------------------------------------------------------------------------
def _bits_i32(x: jax.Array) -> jax.Array:
    """Reinterpret any fixed-width leaf as int32 bit patterns (bf16 →
    int16 → sign-extended int32; f32 → int32).  Pure bit movement — two
    tensors agree here iff they agree byte-for-byte."""
    if jnp.issubdtype(x.dtype, jnp.integer):
        return x.astype(jnp.int32)
    nbits = x.dtype.itemsize * 8
    return lax.bitcast_convert_type(
        x, jnp.dtype(f"int{nbits}")).astype(jnp.int32)


def _rowsum(x: jax.Array, B: int) -> jax.Array:
    """Per-(seq-slot, batch-slot) bit sums: ``[..., s_blk, rows, hd]``
    → int32 ``[..., s_blk, B]``.  Cache rows are batch-slot-major
    (``rows = B * kv_loc``), so the reshape groups each slot's rows."""
    *lead, s_blk, rows, hd = x.shape
    b = _bits_i32(x).reshape(tuple(lead) + (s_blk, B, (rows // B) * hd))
    return jnp.sum(b, axis=-1, dtype=jnp.int32)


def kv_entry_fp(cache, B: int) -> jax.Array:
    """Full per-slot checksum of one KV cache entry: int32 ``[..., B]``
    (leading dims = the stacked ``n_groups`` axis when present).  Sums
    are mod 2^32 — associative, commutative, exact."""
    return jnp.sum(_rowsum(cache.k, B) + _rowsum(cache.v, B),
                   axis=-2, dtype=jnp.int32)


def kv_fp_delta(old, new, fp: jax.Array) -> jax.Array:
    """Incremental checksum update for one decode step: only (seq-slot,
    batch-slot) positions whose ``pos`` entry moved (append or ring
    wrap) contribute their old→new bit-sum delta.  Equivalent to a full
    recompute whenever the engine's invariant holds (rows change only
    where ``pos`` changes — the admit path recomputes from scratch
    precisely because a same-length re-admit violates it)."""
    B = old.pos.shape[-1]
    changed = new.pos != old.pos                       # [..., s_blk, B]
    d = (_rowsum(new.k, B) - _rowsum(old.k, B)
         + _rowsum(new.v, B) - _rowsum(old.v, B))
    return fp + jnp.sum(jnp.where(changed, d, 0), axis=-2,
                        dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Host-side mirrors (numpy, same mod-2^32 arithmetic)
# ---------------------------------------------------------------------------
def _np_bits(a: np.ndarray) -> np.ndarray:
    if a.dtype.kind in "iu":
        return a.astype(np.int64)
    nbits = a.dtype.itemsize * 8
    return a.view(np.dtype(f"int{nbits}")).astype(np.int64)


def _np_u32(x: np.ndarray) -> np.ndarray:
    return np.asarray(x, np.int64) & 0xFFFFFFFF


def np_kv_entry_fp(k: np.ndarray, v: np.ndarray, B: int) -> np.ndarray:
    """Host recompute of :func:`kv_entry_fp` on device-major leaves
    ``[dp, ms, (n_groups,) s_blk, rows, hd]`` → uint32-valued int64
    ``[dp, ms, (n_groups,) B]``."""
    def rs(x):
        *lead, s_blk, rows, hd = x.shape
        b = _np_bits(x).reshape(tuple(lead) + (s_blk, B, (rows // B) * hd))
        return b.sum(axis=(-1, -3))
    return _np_u32(rs(k) + rs(v))


def leaf_checksum(leaf) -> int:
    """Mod-2^32 bit-pattern checksum of one (device or host) array."""
    a = np.asarray(jax.device_get(leaf))
    return int(_np_bits(a).sum() & 0xFFFFFFFF)


def weight_leaves(tree: PyTree) -> List[Tuple[str, Any]]:
    """Canonical ``(path, leaf)`` enumeration of a param tree's array
    leaves, in tree-flatten order.  Shared between the monitor's
    fingerprint table and the fault injector's ``flip_weight_bit``
    targeting, so ``FaultSpec.target`` indexes the same leaf both
    corrupt and verify."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat
            if hasattr(leaf, "dtype") and hasattr(leaf, "shape")]


def weight_fingerprints(tree: PyTree) -> Dict[str, int]:
    """Checksum per array leaf of the serve tree (prepack-time
    reference)."""
    return {name: leaf_checksum(leaf) for name, leaf in weight_leaves(tree)}


# ---------------------------------------------------------------------------
# The monitor
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class IntegrityConfig:
    """Which SDC probes the router runs, and how hard.

    ``weight_leaves_per_tick`` bounds the rotating weight spot-check's
    per-tick cost; full coverage — and therefore the router's deferred-
    commit window — takes ``ceil(n_leaves / per_tick)`` ticks.  The
    shadow tolerances absorb dot-product reassociation between the
    host recompute and the device matmul (the operands are identical
    bf16 bit patterns; only the f32 accumulation order differs)."""
    kv: bool = True
    weights: bool = True
    weight_leaves_per_tick: int = 1
    shadow: bool = True
    shadow_rtol: float = 1e-3
    shadow_atol: float = 1e-4


class IntegrityMonitor:
    """Per-replica SDC probe state: the weight fingerprint table, the
    pristine host copy of the sampling head, and the rotation/shadow
    cursors.  ``probe(sched)`` is the router's per-tick entry point —
    it returns the fired signal labels (empty = clean)."""

    def __init__(self, eng, icfg: IntegrityConfig):
        self.eng = eng
        self.icfg = icfg
        self.tick = 0
        self.last_details: List[str] = []
        if icfg.kv and not getattr(eng.scfg, "kv_fingerprint", False):
            raise ValueError(
                "IntegrityConfig.kv needs engines built with "
                "kv_fingerprint=True (build_engine_full / build_replicas)")
        if icfg.shadow and not getattr(eng.scfg, "shadow_head", False):
            raise ValueError(
                "IntegrityConfig.shadow needs engines built with "
                "shadow_head=True (build_engine_full / build_replicas)")
        if icfg.weights and icfg.weight_leaves_per_tick < 1:
            raise ValueError("weight_leaves_per_tick must be ≥ 1")
        self.weight_ref: Dict[str, int] = (
            weight_fingerprints(eng.params["serve"]) if icfg.weights else {})
        self._leaf_names = list(self.weight_ref)
        if icfg.shadow:
            from repro.serving.prepack import head_view
            cfg = eng.cfg
            hv = head_view(cfg, eng.params["serve"])
            # pristine host copies, taken while the tree is known-clean
            # (construction time = prepack time): the shadow recompute
            # must NOT consult the possibly-corrupted device table, or
            # it would agree with the corruption it exists to catch
            self._table = np.asarray(
                jax.device_get(hv.table), np.float32).reshape(-1, cfg.d_model)
            ln = np.asarray(jax.device_get(hv.ln), np.float32).reshape(-1)
            self._ln = ln[:cfg.d_model]          # device-major replicas agree

    # -- commit-lag contract ---------------------------------------------
    def commit_lag(self) -> int:
        """Ticks the router must defer commits so every weight flip is
        probed before any token it influenced commits: the rotation's
        full-coverage period (0 when weight checking is off — KV and
        shadow probes both fire on the tick of the corruption)."""
        if not self.icfg.weights or not self._leaf_names:
            return 0
        return math.ceil(len(self._leaf_names)
                         / self.icfg.weight_leaves_per_tick)

    # -- probes -----------------------------------------------------------
    def probe(self, sched) -> List[str]:
        """Run the configured probes against ``sched``'s live state;
        returns fired signal labels.  One call = one router tick."""
        fired: List[str] = []
        self.last_details = []
        tracecount.record_probe("probe_ticks")
        if self.icfg.kv and not self.verify_kv(sched.state):
            fired.append("detect_kv_fingerprint")
        if self.icfg.weights:
            bad = self.verify_weights(self._rotation(self.tick))
            if bad:
                fired.append("detect_weight_fingerprint")
                self.last_details += [f"weight:{n}" for n in bad]
        if self.icfg.shadow:
            slot = self.tick % sched.n_slots
            if not self.verify_shadow(sched.state, slot):
                fired.append("detect_shadow_recompute")
                self.last_details.append(f"shadow:slot{slot}")
        self.tick += 1
        return fired

    def verify_kv(self, state: Dict[str, Any]) -> bool:
        """Host-recompute every attention entry's per-slot checksum and
        compare EXACTLY against the device fingerprint leaves."""
        pairs = [(c, f) for c, f in zip(state["layers"], state["kv_fp"])
                 if hasattr(c, "k")]
        pairs += [(c, f) for c, f in zip(state["tail"], state["kv_fp_tail"])
                  if hasattr(c, "k")]
        ok, nbytes = True, 0
        for cache, fp in pairs:
            k = np.asarray(jax.device_get(cache.k))
            v = np.asarray(jax.device_get(cache.v))
            have = np.asarray(jax.device_get(fp))
            nbytes += k.nbytes + v.nbytes + have.nbytes
            want = np_kv_entry_fp(k, v, B=have.shape[-1])
            if (want != _np_u32(have)).any():
                ok = False
                self.last_details.append("kv:" + ",".join(
                    map(str, np.argwhere(want != _np_u32(have))[:4])))
        tracecount.record_probe("probe_bytes_kv", nbytes)
        return ok

    def _rotation(self, tick: int) -> List[int]:
        n = len(self._leaf_names)
        if n == 0:
            return []
        k = self.icfg.weight_leaves_per_tick
        return [(tick * k + j) % n for j in range(min(k, n))]

    def verify_weights(self, idxs) -> List[str]:
        """Re-checksum the given leaves of the replica's LIVE serve
        tree; returns the names that diverged from the prepack-time
        reference."""
        leaves = weight_leaves(self.eng.params["serve"])
        bad, nbytes = [], 0
        for i in idxs:
            name, leaf = leaves[i]
            nbytes += leaf.dtype.itemsize * int(np.prod(leaf.shape))
            if leaf_checksum(leaf) != self.weight_ref[name]:
                bad.append(name)
        tracecount.record_probe("probe_bytes_weights", nbytes)
        return bad

    def verify_weights_full(self) -> List[str]:
        """Every leaf (heal-time re-verification before a replica
        rejoins — serving/router.py)."""
        return self.verify_weights(range(len(self._leaf_names)))

    def verify_shadow(self, state: Dict[str, Any], slot: int) -> bool:
        """Re-derive ``slot``'s winning logit from its stashed pre-head
        residual with the PRISTINE head copy and compare against the
        device's ``head_val``.  The (residual, value, token) triple is
        written atomically by one step, so any stashed triple is
        internally consistent — stale slots cannot false-positive."""
        import ml_dtypes
        cfg = self.eng.cfg
        n = state["head_val"].shape[-1] if hasattr(
            state["head_val"], "shape") else 0
        resid = np.asarray(
            jax.device_get(state["head_resid"])).reshape(-1, n, cfg.d_model)
        val = np.asarray(jax.device_get(state["head_val"])).reshape(-1, n)
        tok = np.asarray(jax.device_get(state["head_tok"])).reshape(-1, n)
        tracecount.record_probe(
            "probe_bytes_shadow",
            resid[0, slot].nbytes + val[:1, :1].nbytes + tok[:1, :1].nbytes)
        t = int(tok[0, slot])
        if not (0 <= t < cfg.vocab_size):
            return False
        # mirror the device tail: f32 RMSNorm → round to bf16 → f32 dot
        # against the bf16-exact table row → softcap (models/layers.py)
        xf = resid[0, slot].astype(np.float32)
        y = xf / np.sqrt(np.mean(xf * xf) + cfg.norm_eps) * (1.0 + self._ln)
        y = y.astype(ml_dtypes.bfloat16).astype(np.float32)
        logit = float(y @ self._table[t])
        if cfg.logit_softcap:
            logit = float(np.tanh(logit / cfg.logit_softcap)
                          * cfg.logit_softcap)
        have = float(val[0, slot])
        return abs(logit - have) <= (self.icfg.shadow_atol
                                     + self.icfg.shadow_rtol * abs(logit))
