"""Pure-JAX model zoo (manual SPMD via ParallelCtx)."""
from repro.models.ctx import (  # noqa: F401
    ParallelCtx, make_train_ctx, pick_heads_sub, single_device_ctx,
)
from repro.models.transformer import (  # noqa: F401
    Layout, apply_block, forward, init_device_major, init_logical,
    layout_for, loss_fn, param_specs, to_device_major, unwrap_local,
)
