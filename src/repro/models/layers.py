"""Shared layers: norms, activations, rotary, FFN (TP), vocab-parallel
embedding and cross-entropy.

Everything is written against :class:`~repro.models.ctx.ParallelCtx`; when
no axes are bound the collectives vanish and the code is a plain
single-device model (the test oracle).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.ctx import ParallelCtx


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (full-sequence form)
# ---------------------------------------------------------------------------
def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # [..., half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, H, head_dim]; cos/sin: [S, half] (broadcast over heads)."""
    half = x.shape[-1] // 2
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense FFN with tensor parallelism over the full model axis
# ---------------------------------------------------------------------------
class FFNParams(NamedTuple):
    """Gated: w_in [D, F_loc], w_gate [D, F_loc], w_out [F_loc, D].
    Ungated: w_gate is None."""

    w_in: jax.Array
    w_out: jax.Array
    w_gate: Optional[jax.Array] = None


def ffn_apply(ctx: ParallelCtx, p: FFNParams, x: jax.Array, act: str
              ) -> jax.Array:
    """Column-sharded up/gate, row-sharded down, psum on the way out
    (Megatron pattern)."""
    h = x @ p.w_in
    if p.w_gate is not None:
        h = activation(act)(x @ p.w_gate) * h
    else:
        h = activation(act)(h)
    y = h @ p.w_out
    return ctx.psum_model(y)


def ffn_init(key, d_model: int, d_ff_local: int, gated: bool,
             dtype=jnp.bfloat16) -> FFNParams:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff_local)
    return FFNParams(
        w_in=(jax.random.normal(k1, (d_model, d_ff_local)) * s_in).astype(dtype),
        w_out=(jax.random.normal(k2, (d_ff_local, d_model)) * s_out).astype(dtype),
        w_gate=(jax.random.normal(k3, (d_model, d_ff_local)) * s_in).astype(dtype)
        if gated else None,
    )


# ---------------------------------------------------------------------------
# Vocab-parallel embedding + LM head + cross entropy (Megatron pattern)
# ---------------------------------------------------------------------------
class EmbedParams(NamedTuple):
    table: jax.Array        # [V_loc, D] — vocab-sharded over the model axis


def padded_vocab(vocab: int, shards: int) -> int:
    return ((vocab + shards - 1) // shards) * shards


def embed_init(key, vocab: int, d_model: int, shards: int,
               dtype=jnp.bfloat16) -> EmbedParams:
    v_pad = padded_vocab(vocab, shards)
    table = jax.random.normal(key, (v_pad // shards, d_model)) * 0.02
    return EmbedParams(table=table.astype(dtype))


def embed_lookup(ctx: ParallelCtx, p: EmbedParams, tokens: jax.Array
                 ) -> jax.Array:
    """Tokens whose id falls outside this shard contribute zero; a psum over
    the model axis assembles the full embedding."""
    v_loc = p.table.shape[0]
    shard = ctx.model_index()
    local = tokens - shard * v_loc
    in_range = (local >= 0) & (local < v_loc)
    local = jnp.clip(local, 0, v_loc - 1)
    emb = jnp.take(p.table, local, axis=0)
    emb = jnp.where(in_range[..., None], emb, 0)
    return ctx.psum_model(emb)


def lm_head_logits(ctx: ParallelCtx, table: jax.Array, x: jax.Array
                   ) -> jax.Array:
    """Returns vocab-SHARDED logits [..., V_loc] in f32 (never materialize
    full V).

    f32 on purpose: every consumer (cross-entropy, greedy sampling)
    immediately upcasts, so XLA's excess-precision pass elided the
    model-dtype round-trip anyway — computing in f32 PINS that staging,
    making the fused head kernel's math (f32 logit tiles on the rounded
    ``rms_norm`` output) bit-identical to this path instead of dependent
    on a convert-elision heuristic (kernels/fused_head, DESIGN.md §7).
    The OPERANDS stay in the model dtype (``preferred_element_type``
    carries the f32 accumulation): bf16 values are exact in f32, so the
    result is bit-identical to an f32×f32 matmul, without forcing the
    training-xent / prefill head matmul — the model's largest — onto
    the half-throughput f32 MXU path or materializing an f32 table.

    Trace-time counter: the fused LM-head/sampling tail must never
    materialize the ``[B, V_loc]`` logits — tests assert this traces
    ZERO times in a fused decode step.
    """
    from repro.core import tracecount
    tracecount.bump("lm_head_logits")
    return jnp.matmul(x, table.T.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def vocab_parallel_xent(ctx: ParallelCtx, logits_loc: jax.Array,
                        targets: jax.Array, valid: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over vocab-sharded logits (Megatron algorithm).

    Returns ``(sum_loss, sum_valid)`` — *local* partial sums over this
    shard's tokens; callers psum over the data axes.
    """
    v_loc = logits_loc.shape[-1]
    shard = ctx.model_index()
    lf = logits_loc.astype(jnp.float32)
    # stable logsumexp over the sharded vocab
    m_loc = jnp.max(lf, axis=-1)
    if ctx.model is not None:
        from repro.core import primitives as prim
        m = prim.cluster_reduce(m_loc, ctx.model, "max")
    else:
        m = m_loc
    se = jnp.sum(jnp.exp(lf - m[..., None]), axis=-1)
    se = ctx.psum_model(se)
    lse = jnp.log(se) + m
    # pick out the target logit (zero if not on this shard, then psum)
    local = targets - shard * v_loc
    in_range = (local >= 0) & (local < v_loc)
    local_c = jnp.clip(local, 0, v_loc - 1)
    tgt = jnp.take_along_axis(lf, local_c[..., None], axis=-1)[..., 0]
    tgt = jnp.where(in_range, tgt, 0.0)
    tgt = ctx.psum_model(tgt)
    nll = lse - tgt
    if valid is None:
        valid = jnp.ones_like(nll, dtype=jnp.float32)
    else:
        valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid), jnp.sum(valid)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap and cap > 0 else x
