"""Griffin / RecurrentGemma recurrent block (RG-LRU).

Block structure (arXiv:2402.19427):

    x ─ linear ─ conv1d(width 4) ─ RG-LRU ─┐
                                            ⊙ ─ out-linear
    x ─ linear ───────────── gelu ─────────┘

RG-LRU recurrence (per channel, diagonal — embarrassingly parallel over
channels, so the model axis shards channels with zero recurrence comm):

    r_t = σ(W_r x_t + b_r)          i_t = σ(W_i x_t + b_i)
    a_t = exp(−c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses ``lax.associative_scan`` (log-depth — the TPU-native choice);
decode is a single fused step.  The Pallas kernel in
``kernels/rglru_scan`` implements the sequential scan with VMEM-resident
state for the decode/prefill hot path.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.ctx import ParallelCtx

_C = 8.0  # Griffin's fixed constant


class RGLRUParams(NamedTuple):
    """Local shapes (d_loc = rglru_d_state / model_size):

    w_x [D, d_loc], w_gate [D, d_loc]  — input / gate branches
    conv_w [width, d_loc], conv_b [d_loc]
    w_r / w_i [nb_loc, bs, bs] — Griffin's gates are *block-diagonal*
    linear layers with ``n_blocks = n_heads`` blocks (RecurrentGemma's
    BlockDiagonalLinear); the block structure is part of the published
    architecture and is what makes the channel sharding exact: blocks are
    distributed whole across the model axis.
    lam [d_loc] — Λ parameter; out [d_loc, D].
    """

    w_x: jax.Array
    w_gate: jax.Array
    conv_w: jax.Array
    conv_b: jax.Array
    w_r: jax.Array
    b_r: jax.Array
    w_i: jax.Array
    b_i: jax.Array
    lam: jax.Array
    w_out: jax.Array


class RGLRUState(NamedTuple):
    h: jax.Array            # [B, d_loc] recurrent state
    conv: jax.Array         # [B, width-1, d_loc] conv tail


def _block_linear(w: jax.Array, u: jax.Array) -> jax.Array:
    """Block-diagonal matmul: w [nb, bs, bs]; u [..., nb*bs]."""
    nb, bs, _ = w.shape
    uu = u.reshape(u.shape[:-1] + (nb, bs))
    return jnp.einsum("...nb,nbc->...nc", uu, w).reshape(u.shape)


def _gates(p: RGLRUParams, u: jax.Array):
    r = jax.nn.sigmoid(_block_linear(p.w_r, u) + p.b_r)
    i = jax.nn.sigmoid(_block_linear(p.w_i, u) + p.b_i)
    log_a = -_C * jax.nn.softplus(p.lam) * r          # log a_t  (≤ 0)
    return log_a, i


def rglru_scan(p: RGLRUParams, u: jax.Array) -> jax.Array:
    """Associative scan over time.  u: [B, S, d_loc] → h: [B, S, d_loc].

    The recurrence h_t = a_t h_{t−1} + b_t is linear ⇒ composable elements
    (a, b) with (a2, b2)∘(a1, b1) = (a1·a2, a2·b1 + b2).
    """
    log_a, i = _gates(p, u.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(u.dtype)


def rglru_step(p: RGLRUParams, u: jax.Array, h_prev: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. u: [B, d_loc]."""
    log_a, i = _gates(p, u.astype(jnp.float32))
    a = jnp.exp(log_a)
    h = a * h_prev.astype(jnp.float32) + \
        jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u.astype(jnp.float32))
    return h.astype(u.dtype), h.astype(h_prev.dtype)


def _causal_conv(p: RGLRUParams, x: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d.  x: [B, S, d_loc]."""
    width = p.conv_w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * p.conv_w[i] for i in range(width))
    return out + p.conv_b


def rglru_block(ctx: ParallelCtx, p: RGLRUParams, x: jax.Array
                ) -> jax.Array:
    """Full Griffin recurrent block (train / prefill).  x: [B, S, D]."""
    u = x @ p.w_x                                    # [B,S,d_loc]
    u = _causal_conv(p, u)
    h = rglru_scan(p, u)
    gate = jax.nn.gelu(x @ p.w_gate, approximate=True)
    y = (h * gate) @ p.w_out
    return ctx.psum_model(y)


def rglru_block_step(ctx: ParallelCtx, p: RGLRUParams, x: jax.Array,
                     state: RGLRUState) -> Tuple[jax.Array, RGLRUState]:
    """Decode step.  x: [B, D] → ([B, D], new state)."""
    u = x @ p.w_x                                    # [B, d_loc]
    width = p.conv_w.shape[0]
    hist = jnp.concatenate([state.conv, u[:, None]], axis=1)  # [B,width,d]
    u_c = jnp.einsum("bwd,wd->bd", hist.astype(jnp.float32),
                     p.conv_w.astype(jnp.float32)).astype(u.dtype) + p.conv_b
    h, h_new = rglru_step(p, u_c, state.h)
    gate = jax.nn.gelu(x @ p.w_gate, approximate=True)
    y = (h * gate) @ p.w_out
    y = ctx.psum_model(y)
    return y, RGLRUState(h=h_new, conv=hist[:, 1:])


def rglru_init(key, d_model: int, d_state: int, n_blocks: int,
               width: int = 4, dtype=jnp.bfloat16) -> RGLRUParams:
    """Logical init; ``n_blocks`` = number of gate blocks (= n_heads)."""
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d_model)
    bs = d_state // n_blocks
    sb = 1.0 / math.sqrt(bs)
    # Λ init so that a ∈ (0.9, 0.999) at r = 0.5 (Griffin's stable range)
    lam = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, d_state)) * 2.0 / _C))
    return RGLRUParams(
        w_x=(jax.random.normal(ks[0], (d_model, d_state)) * s).astype(dtype),
        w_gate=(jax.random.normal(ks[1], (d_model, d_state)) * s).astype(dtype),
        conv_w=(jax.random.normal(ks[2], (width, d_state)) * 0.2).astype(dtype),
        conv_b=jnp.zeros((d_state,), dtype),
        w_r=(jax.random.normal(ks[3], (n_blocks, bs, bs)) * sb).astype(dtype),
        b_r=jnp.zeros((d_state,), jnp.float32),
        w_i=(jax.random.normal(ks[4], (n_blocks, bs, bs)) * sb).astype(dtype),
        b_i=jnp.zeros((d_state,), jnp.float32),
        lam=lam.astype(jnp.float32),
        w_out=(jax.random.normal(ks[5], (d_state, d_model)) * sb).astype(dtype),
    )


def rglru_state_init(batch: int, d_state_local: int, width: int = 4,
                     dtype=jnp.float32) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((batch, d_state_local), dtype),
        conv=jnp.zeros((batch, width - 1, d_state_local), dtype),
    )
