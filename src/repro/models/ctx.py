"""Parallel execution context.

All model code is written against :class:`ParallelCtx`, which either
binds real mesh (sub-)axes inside ``shard_map`` — manual-SPMD, explicit
collectives, MaxText/Megatron style — or is the single-device no-op
context used by CPU smoke tests and oracles.  This keeps ONE model
implementation for both paths and makes every collective visible in the
lowered HLO (which the roofline analysis parses).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import primitives as prim
from repro.core import tracecount
from repro.core.primitives import Axis, SubAxis


@dataclass(frozen=True)
class ParallelCtx:
    """Axis bindings for manual-SPMD model code.

    ``model``   — the whole model axis (TP+EP), or None (single device).
    ``heads``   — sub-axis sharding (grouped) heads; equals ``model`` when
                  the head count divides the full axis.
    ``cluster`` — the paper's cluster sub-axis (head-dim / KV-seq / out-dim
                  cooperation).  Size 1 in pure-TP training.
    ``data``    — tuple of data-parallel axis names (("pod","data") multi-pod).
    """

    model: Optional[Axis] = None
    heads: Optional[Axis] = None
    cluster: Optional[Axis] = None
    data: Tuple[str, ...] = ()
    # static size of the model axis (usable outside shard_map tracing)
    model_static: int = 1
    # paper-dataflow options
    fused_combine: bool = False
    use_xla_collectives: bool = False

    # -- sizes -------------------------------------------------------------
    @property
    def model_size(self) -> int:
        if self.model is None:
            return 1
        if isinstance(self.model, SubAxis):
            return self.model.size
        return self.model_static

    @property
    def heads_size(self) -> int:
        return prim._axis_size(self.heads) if self.heads is not None else 1

    @property
    def cluster_size(self) -> int:
        return prim._axis_size(self.cluster) if self.cluster is not None else 1

    # -- collectives (no-ops when unbound) ----------------------------------
    def psum_model(self, x):
        # trace-time counter: the fused full-block decode path must issue
        # ZERO per-layer activation psums (tests/test_prepack.py asserts
        # exactly one per step — the embedding lookup)
        tracecount.bump("psum_model")
        if self.model is None:
            return x
        if isinstance(self.model, SubAxis):
            return prim.cluster_reduce(x, self.model, "sum")
        return lax.psum(x, self.model)

    def psum_data(self, x):
        return lax.psum(x, self.data) if self.data else x

    def psum_heads(self, x):
        if self.heads is None:
            return x
        # When the heads sub-axis spans the whole model axis (cluster == 1)
        # the reduction is an ordinary all-reduce: XLA's bandwidth-optimal
        # schedule moves 2·(N−1)/N·size vs the tree's log2(N)·size — a 2×
        # collective-byte win on [B,S,D]-sized prefill/train activations
        # (§Perf iter: the paper's tree is for SMALL decode messages).
        if (isinstance(self.heads, SubAxis)
                and self.heads.size * 1 == self.model_size
                and self.cluster_size == 1):
            return lax.psum(x, self.heads.name)
        if isinstance(self.heads, SubAxis) or not self.use_xla_collectives:
            return prim.cluster_reduce(x, self.heads, "sum")
        return lax.psum(x, self.heads)

    def gather_cluster(self, x, axis: int):
        """ClusterGather (paper Alg. 2) along ``axis``."""
        if self.cluster is None:
            return x
        if self.use_xla_collectives and not isinstance(self.cluster, SubAxis):
            return lax.all_gather(x, self.cluster, axis=axis, tiled=True)
        return prim.cluster_gather_tiled(x, self.cluster, axis=axis)

    def reduce_cluster(self, x, op="sum"):
        if self.cluster is None:
            return x
        if self.use_xla_collectives and not isinstance(self.cluster, SubAxis):
            return prim.cluster_reduce_xla(x, self.cluster, op)
        return prim.cluster_reduce(x, self.cluster, op)

    def heads_index(self) -> jax.Array:
        return prim.axis_index(self.heads) if self.heads is not None else jnp.int32(0)

    def cluster_index(self) -> jax.Array:
        return prim.axis_index(self.cluster) if self.cluster is not None else jnp.int32(0)

    def model_index(self) -> jax.Array:
        return prim.axis_index(self.model) if self.model is not None else jnp.int32(0)


def make_train_ctx(model_axis: str = "model", heads_sub: int = 0,
                   model_size: int = 1, data: Tuple[str, ...] = ("data",),
                   **extra) -> ParallelCtx:
    """Context factoring ``model`` into (heads_sub × cluster).

    ``heads_sub == model_size`` (the common case: head count divisible by
    the axis) degenerates to pure TP with ``cluster`` size 1.
    """
    if model_size == 1:
        return ParallelCtx(data=data, **extra)
    heads_sub = heads_sub or model_size
    seq_sub = model_size // heads_sub
    heads = SubAxis(model_axis, heads_sub, minor_size=seq_sub)
    cluster = SubAxis(model_axis, seq_sub, minor_size=1)
    return ParallelCtx(model=model_axis, heads=heads, cluster=cluster,
                       data=data, model_static=model_size, **extra)


def single_device_ctx() -> ParallelCtx:
    return ParallelCtx()


def pick_heads_sub(n_heads: int, n_kv: int, model_size: int) -> int:
    """Largest power-of-two sub-axis ≤ model_size that divides n_heads.

    The residual factor becomes the ``cluster`` sub-axis (head-dim /
    sequence cooperation) — the paper's knob, which also neatly absorbs
    head counts that don't divide the mesh (e.g. minitron's 24, arctic's
    56 over a 16-wide axis).
    """
    h = model_size
    while h > 1 and (n_heads % h) != 0:
        h //= 2
    return max(h, 1)
