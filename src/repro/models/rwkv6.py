"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free,
data-dependent decay.

Time-mix (per head, state S ∈ R^{hd×hd}):

    w_t = exp(−exp(w_base + tanh(x̃_t A_w) B_w))      (data-dependent decay)
    S_t = diag(w_t) S_{t−1} + k_tᵀ v_t
    o_t = r_t · (S_{t−1} + diag(u) k_tᵀ v_t)

Channel-mix:  k = relu(x̃ W_k)²;  out = σ(x̃ W_r) ⊙ (k W_v).

Token shift (x̃ = lerp(x_t, x_{t−1}, μ)) follows Finch; we keep the
per-projection learned μ and implement the LoRA refinement for the decay
(the signature "data-dependent" part) only — documented simplification.

Sharding: heads over the ``heads`` sub-axis (channel-mix d_ff over the
full model axis); the recurrence is head-diagonal ⇒ no comm.  The paper's
ClusterFusion dataflow is inapplicable here (no QKV/KV-cache structure —
see DESIGN.md §4); the fused Pallas recurrence kernel lives in
``kernels/rwkv6_scan``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.ctx import ParallelCtx


class RWKV6Params(NamedTuple):
    """Local shapes: h_loc heads of dim hd (D_loc = h_loc·hd).

    mu [5, D] token-shift lerp weights (r,k,v,w,g);
    w_r/w_k/w_v/w_g [D, D_loc]; w_out [D_loc, D];
    w_base [D_loc]; lora_a [D, lora]; lora_b [lora, D_loc]; u [D_loc].
    Channel-mix: mu_c [2, D]; cm_k [D, F_loc]; cm_v [F_loc, D]; cm_r [D, D].
    """

    mu: jax.Array
    w_r: jax.Array
    w_k: jax.Array
    w_v: jax.Array
    w_g: jax.Array
    w_out: jax.Array
    w_base: jax.Array
    lora_a: jax.Array
    lora_b: jax.Array
    u: jax.Array
    ln_scale: jax.Array          # group-norm scale over heads
    mu_c: jax.Array
    cm_k: jax.Array
    cm_v: jax.Array
    cm_r: jax.Array


class RWKV6State(NamedTuple):
    s: jax.Array                 # [B, h_loc, hd, hd] wkv state
    x_prev_t: jax.Array          # [B, D] last input (time-mix shift)
    x_prev_c: jax.Array          # [B, D] last input (channel-mix shift)


def _shift(x: jax.Array, x0: Optional[jax.Array] = None) -> jax.Array:
    """x_{t−1} along the sequence axis.  x: [B, S, D]."""
    pad = jnp.zeros_like(x[:, :1]) if x0 is None else x0[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _decay(p: RWKV6Params, xw: jax.Array) -> jax.Array:
    """Data-dependent per-channel decay in (0,1): exp(−exp(·))."""
    delta = jnp.tanh(xw.astype(jnp.float32) @ p.lora_a.astype(jnp.float32)) \
        @ p.lora_b.astype(jnp.float32)
    return jnp.exp(-jnp.exp(p.w_base.astype(jnp.float32) + delta))


def _wkv_scan(r, k, v, w, u, s0):
    """Sequential WKV recurrence (the jnp oracle for the Pallas kernel).

    r/k/v: [B, S, H, hd]; w: [B, S, H, hd]; u: [H, hd]; s0: [B, H, hd, hd].
    Returns (o [B, S, H, hd], s_final).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                       # [B, H, hd]
        kv = k_t[..., :, None] * v_t[..., None, :]     # [B,H,hd,hd]
        o_t = jnp.einsum("bhi,bhij->bhj", r_t,
                         s + u[..., :, None] * kv)
        s_new = w_t[..., :, None] * s + kv
        return s_new, o_t

    rs, ks_, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_fin, os_ = lax.scan(step, s0, (rs, ks_, vs, ws))
    return jnp.moveaxis(os_, 0, 1), s_fin


def rwkv6_time_mix(ctx: ParallelCtx, p: RWKV6Params, x: jax.Array,
                   head_dim: int, state: Optional[RWKV6State] = None,
                   ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Time-mix over a full sequence.  x: [B, S, D] → [B, S, D]."""
    B, S, D = x.shape
    d_loc = p.w_r.shape[1]
    h_loc = d_loc // head_dim
    xs = _shift(x, state.x_prev_t if state is not None else None)
    mix = lambda i: x + p.mu[i] * (xs - x)
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))

    r = (xr @ p.w_r).reshape(B, S, h_loc, head_dim).astype(jnp.float32)
    k = (xk @ p.w_k).reshape(B, S, h_loc, head_dim).astype(jnp.float32)
    v = (xv @ p.w_v).reshape(B, S, h_loc, head_dim).astype(jnp.float32)
    g = jax.nn.silu(xg @ p.w_g)
    w = _decay(p, xw).reshape(B, S, h_loc, head_dim)
    u = p.u.astype(jnp.float32).reshape(h_loc, head_dim)

    s0 = (jnp.zeros((B, h_loc, head_dim, head_dim), jnp.float32)
          if state is None else state.s.astype(jnp.float32))
    o, s_fin = _wkv_scan(r, k, v, w, u, s0)

    # per-head group norm (Finch)
    o = o.reshape(B, S, h_loc, head_dim)
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mean) * lax.rsqrt(var + 1e-5)
    o = (o * p.ln_scale.reshape(h_loc, head_dim)).reshape(B, S, d_loc)

    y = ((o.astype(x.dtype) * g) @ p.w_out)
    return ctx.psum_heads(y), s_fin


def rwkv6_channel_mix(ctx: ParallelCtx, p: RWKV6Params, x: jax.Array,
                      x_prev: Optional[jax.Array] = None) -> jax.Array:
    xs = _shift(x, x_prev)
    xk = x + p.mu_c[0] * (xs - x)
    xr = x + p.mu_c[1] * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p.cm_k))
    y = ctx.psum_model(k @ p.cm_v)
    return jax.nn.sigmoid(xr @ p.cm_r) * y


def rwkv6_block(ctx: ParallelCtx, p: RWKV6Params, x: jax.Array,
                head_dim: int, ln1: jax.Array, ln2: jax.Array,
                eps: float) -> jax.Array:
    """Full RWKV-6 layer (train / prefill path)."""
    from repro.models.layers import rms_norm
    a, _ = rwkv6_time_mix(ctx, p, rms_norm(x, ln1, eps), head_dim)
    x = x + a
    x = x + rwkv6_channel_mix(ctx, p, rms_norm(x, ln2, eps))
    return x


def rwkv6_step(ctx: ParallelCtx, p: RWKV6Params, x: jax.Array,
               head_dim: int, state: RWKV6State
               ) -> Tuple[jax.Array, jax.Array, RWKV6State]:
    """Single decode step of the time-mix.  x: [B, D].

    Returns (time_mix_out, channel-mix closure input, new state).  The
    caller composes with norms/residuals (see transformer.py).
    """
    B, D = x.shape
    d_loc = p.w_r.shape[1]
    h_loc = d_loc // head_dim
    xs = state.x_prev_t
    mix = lambda i: x + p.mu[i] * (xs - x)
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ p.w_r).reshape(B, h_loc, head_dim).astype(jnp.float32)
    k = (xk @ p.w_k).reshape(B, h_loc, head_dim).astype(jnp.float32)
    v = (xv @ p.w_v).reshape(B, h_loc, head_dim).astype(jnp.float32)
    g = jax.nn.silu(xg @ p.w_g)
    w = _decay(p, xw).reshape(B, h_loc, head_dim)
    u = p.u.astype(jnp.float32).reshape(h_loc, head_dim)

    s = state.s.astype(jnp.float32)
    kv = k[..., :, None] * v[..., None, :]
    o = jnp.einsum("bhi,bhij->bhj", r, s + u[..., :, None] * kv)
    s_new = w[..., :, None] * s + kv

    o = o.reshape(B, h_loc, head_dim)
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mean) * lax.rsqrt(var + 1e-5)
    o = (o * p.ln_scale.reshape(h_loc, head_dim)).reshape(B, d_loc)
    y = ctx.psum_heads((o.astype(x.dtype) * g) @ p.w_out)
    new_state = RWKV6State(s=s_new.astype(state.s.dtype), x_prev_t=x,
                           x_prev_c=state.x_prev_c)
    return y, x, new_state


def rwkv6_channel_step(ctx: ParallelCtx, p: RWKV6Params, x: jax.Array,
                       state: RWKV6State) -> Tuple[jax.Array, RWKV6State]:
    xs = state.x_prev_c
    xk = x + p.mu_c[0] * (xs - x)
    xr = x + p.mu_c[1] * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p.cm_k))
    y = ctx.psum_model(k @ p.cm_v)
    y = jax.nn.sigmoid(xr @ p.cm_r) * y
    return y, state._replace(x_prev_c=x)


def rwkv6_init(key, d_model: int, head_dim: int, heads_sub: int,
               n_heads: int, d_ff: int, model_size: int, lora: int = 32,
               dtype=jnp.bfloat16) -> RWKV6Params:
    h_loc = max(1, n_heads // heads_sub)
    d_loc = h_loc * head_dim
    f_loc = max(1, d_ff // model_size)
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d_model)
    return RWKV6Params(
        mu=(jax.random.uniform(ks[0], (5, d_model))).astype(dtype),
        w_r=(jax.random.normal(ks[1], (d_model, d_loc)) * s).astype(dtype),
        w_k=(jax.random.normal(ks[2], (d_model, d_loc)) * s).astype(dtype),
        w_v=(jax.random.normal(ks[3], (d_model, d_loc)) * s).astype(dtype),
        w_g=(jax.random.normal(ks[4], (d_model, d_loc)) * s).astype(dtype),
        w_out=(jax.random.normal(ks[5], (d_loc, d_model))
               * (1.0 / math.sqrt(d_loc * heads_sub))).astype(dtype),
        w_base=(jnp.zeros((d_loc,)) - 0.5).astype(jnp.float32),
        lora_a=(jax.random.normal(ks[6], (d_model, lora)) * s).astype(dtype),
        lora_b=(jax.random.normal(ks[7], (lora, d_loc)) * 0.01).astype(dtype),
        u=(jax.random.normal(ks[8], (d_loc,)) * 0.1).astype(jnp.float32),
        ln_scale=jnp.ones((d_loc,), jnp.float32),
        mu_c=(jax.random.uniform(ks[9], (2, d_model))).astype(dtype),
        cm_k=(jax.random.normal(ks[10], (d_model, f_loc)) * s).astype(dtype),
        cm_v=(jax.random.normal(ks[11], (f_loc, d_model))
              * (1.0 / math.sqrt(f_loc))).astype(dtype),
        cm_r=(jax.random.normal(ks[0], (d_model, d_model)) * s).astype(dtype),
    )


def rwkv6_state_init(batch: int, n_heads_local: int, head_dim: int,
                     d_model: int, dtype=jnp.float32) -> RWKV6State:
    return RWKV6State(
        s=jnp.zeros((batch, n_heads_local, head_dim, head_dim), dtype),
        x_prev_t=jnp.zeros((batch, d_model), jnp.bfloat16),
        x_prev_c=jnp.zeros((batch, d_model), jnp.bfloat16),
    )
