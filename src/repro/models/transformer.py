"""Model assembly: logical init → device-major layout → scanned forward.

Parameter story (see DESIGN.md §5):

* ``init_logical`` builds the *published* architecture's tensors (e.g.
  ``wq [D, n_heads, head_dim]``) — this is what checkpoints store and what
  the single-device oracle consumes.
* ``to_device_major`` re-lays every tensor out as ``[model_size, *local]``
  (device-major), optionally stacked ``[n_groups, model_size, *local]`` for
  the scanned layer groups.  The shard_map in_spec is then uniformly
  ``P("model", …)`` / ``P(None, "model", …)`` — sub-axis factorisations
  (heads × cluster) and GQA KV replication are all resolved at layout
  time by pure reshape/transpose/repeat, so a jitted init with
  ``out_shardings`` distributes correctly at any scale.
* Model code receives LOCAL params (leading device dim stripped).

Layer groups: the block pattern (period P) is scanned over
``n_layers // P`` groups with remat; remainder layers run unrolled.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV6,
                                ModelConfig)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.attention import AttnParams, MLAAttnParams
from repro.models.ctx import ParallelCtx, pick_heads_sub
from repro.models.layers import (EmbedParams, FFNParams, embed_lookup,
                                 ffn_apply, lm_head_logits, padded_vocab,
                                 rms_norm, softcap, vocab_parallel_xent)
from repro.models.moe import MoEParams
from repro.models.rglru import RGLRUParams
from repro.models.rwkv6 import RWKV6Params

PyTree = Any


@dataclass(frozen=True)
class Layout:
    """Device-major layout parameters for one model axis."""

    model_size: int = 1

    @property
    def heads_sub(self) -> int:
        return self._heads_sub

    def __init__(self, model_size: int = 1, heads_sub: int = 0):
        object.__setattr__(self, "model_size", model_size)
        object.__setattr__(self, "_heads_sub", heads_sub or model_size)

    @property
    def cluster(self) -> int:
        return self.model_size // self._heads_sub


def layout_for(cfg: ModelConfig, model_size: int) -> Layout:
    return Layout(model_size, pick_heads_sub(cfg.n_heads, cfg.n_kv_heads,
                                             model_size))


# ===========================================================================
# Logical init
# ===========================================================================
def _norm(d):
    return jnp.zeros((d,), jnp.float32)


def _dense(key, shape, scale, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_logical_block(key, cfg: ModelConfig, kind: str,
                       dtype=jnp.bfloat16) -> Dict[str, Any]:
    """One layer's logical parameters."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    blk: Dict[str, Any] = {"ln1": _norm(d), "ln2": _norm(d)}
    if cfg.use_post_norm:
        blk["post_ln1"] = _norm(d)
        blk["post_ln2"] = _norm(d)
    s_in = 1.0 / math.sqrt(d)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        if cfg.mla is not None:
            m = cfg.mla
            hr = m.nope_head_dim + m.rope_head_dim
            blk["attn"] = MLAAttnParams(
                wq=_dense(ks[0], (d, cfg.n_heads, hr), s_in, dtype),
                wdkv=_dense(ks[1], (d, m.kv_lora_rank + m.rope_head_dim),
                            s_in, dtype),
                wuk=_dense(ks[2], (cfg.n_heads, m.nope_head_dim,
                                   m.kv_lora_rank), 0.05, dtype),
                wuv=_dense(ks[3], (cfg.n_heads, m.kv_lora_rank,
                                   m.v_head_dim), 0.05, dtype),
                wo=_dense(ks[4], (cfg.n_heads * m.v_head_dim, d),
                          1.0 / math.sqrt(cfg.n_heads * m.v_head_dim), dtype),
            )
        else:
            bias = cfg.qkv_bias
            blk["attn"] = AttnParams(
                wq=_dense(ks[0], (d, cfg.n_heads, hd), s_in, dtype),
                wk=_dense(ks[1], (d, cfg.n_kv_heads, hd), s_in, dtype),
                wv=_dense(ks[2], (d, cfg.n_kv_heads, hd), s_in, dtype),
                wo=_dense(ks[3], (cfg.n_heads * hd, d),
                          1.0 / math.sqrt(cfg.n_heads * hd), dtype),
                bq=jnp.zeros((cfg.n_heads, hd), dtype) if bias else None,
                bk=jnp.zeros((cfg.n_kv_heads, hd), dtype) if bias else None,
                bv=jnp.zeros((cfg.n_kv_heads, hd), dtype) if bias else None,
            )
    elif kind == RECURRENT:
        ds = cfg.rglru_d_state or d
        blk["rglru"] = rglru_mod.rglru_init(ks[0], d, ds,
                                            n_blocks=cfg.n_heads,
                                            width=cfg.conv1d_width,
                                            dtype=dtype)
    elif kind == RWKV6:
        blk["rwkv"] = rwkv_mod.rwkv6_init(
            ks[0], d, cfg.rwkv_head_dim, heads_sub=1,
            n_heads=d // cfg.rwkv_head_dim, d_ff=cfg.d_ff, model_size=1,
            dtype=dtype)
        return blk                               # rwkv owns both sub-layers
    # FFN / MoE (not for RWKV which has its own channel-mix)
    if cfg.moe is not None and kind != RECURRENT:
        blk["ffn"] = moe_mod.moe_init(ks[5], d, cfg.moe, n_shards=1,
                                      gated=cfg.ffn_gated, dtype=dtype)
    else:
        from repro.models.layers import ffn_init
        blk["ffn"] = ffn_init(ks[5], d, cfg.d_ff, cfg.ffn_gated, dtype)
    return blk


def init_logical_encoder_block(key, cfg: ModelConfig,
                               dtype=jnp.bfloat16) -> Dict[str, Any]:
    e = cfg.encoder
    d = cfg.d_model
    hd = d // e.n_heads
    ks = jax.random.split(key, 6)
    s_in = 1.0 / math.sqrt(d)
    from repro.models.layers import ffn_init
    return {
        "ln1": _norm(d), "ln2": _norm(d),
        "attn": AttnParams(
            wq=_dense(ks[0], (d, e.n_heads, hd), s_in, dtype),
            wk=_dense(ks[1], (d, e.n_kv_heads, hd), s_in, dtype),
            wv=_dense(ks[2], (d, e.n_kv_heads, hd), s_in, dtype),
            wo=_dense(ks[3], (e.n_heads * hd, d),
                      1.0 / math.sqrt(e.n_heads * hd), dtype),
        ),
        "ffn": ffn_init(ks[4], d, e.d_ff, cfg.ffn_gated, dtype),
    }


def init_logical(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Full logical parameter tree (published shapes)."""
    d = cfg.d_model
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    n_tail = cfg.n_layers - n_groups * period
    keys = jax.random.split(key, cfg.n_layers + 8)

    blocks: List[Any] = []
    for p in range(period):
        # stack group params for scan: leaves [n_groups, ...]
        per_group = [init_logical_block(keys[g * period + p], cfg, kinds[p],
                                        dtype) for g in range(n_groups)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))
    tail = [init_logical_block(keys[n_groups * period + t], cfg,
                               kinds[n_groups * period + t], dtype)
            for t in range(n_tail)]

    kb = keys[cfg.n_layers:]
    params: Dict[str, Any] = {
        "embed": _dense(kb[0], (padded_vocab(cfg.vocab_size, 1), d), 0.02,
                        dtype),
        "final_norm": _norm(d),
        "blocks": blocks,
        "tail": tail,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(kb[1], (padded_vocab(cfg.vocab_size, 1), d),
                                   1.0 / math.sqrt(d), dtype)
    if cfg.frontend is not None:
        params["frontend_proj"] = _dense(
            kb[2], (cfg.frontend.feature_dim, d),
            1.0 / math.sqrt(cfg.frontend.feature_dim), dtype)
    if cfg.encoder is not None:
        enc_keys = jax.random.split(kb[3], cfg.encoder.n_layers)
        per = [init_logical_encoder_block(k, cfg, dtype) for k in enc_keys]
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        params["enc_final_norm"] = _norm(d)
        # decoder cross-attention (one per decoder layer, stacked)
        ca_keys = jax.random.split(kb[4], cfg.n_layers)
        hd = cfg.resolved_head_dim
        s_in = 1.0 / math.sqrt(d)
        per_ca = [{
            "ln": _norm(d),
            "attn": AttnParams(
                wq=_dense(jax.random.fold_in(k, 0), (d, cfg.n_heads, hd),
                          s_in, dtype),
                wk=_dense(jax.random.fold_in(k, 1), (d, cfg.n_kv_heads, hd),
                          s_in, dtype),
                wv=_dense(jax.random.fold_in(k, 2), (d, cfg.n_kv_heads, hd),
                          s_in, dtype),
                wo=_dense(jax.random.fold_in(k, 3), (cfg.n_heads * hd, d),
                          1.0 / math.sqrt(cfg.n_heads * hd), dtype),
            )} for k in ca_keys]
        params["cross_attn"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                            *per_ca)
    return params


# ===========================================================================
# Device-major layout (logical → [model_size, *local])
# ===========================================================================
def _dm_replicate(x, ms):
    return jnp.broadcast_to(x[None], (ms,) + x.shape)


def _dm_split(x, ms, axis):
    """Split ``axis`` into ms shards → leading device dim."""
    n = x.shape[axis]
    assert n % ms == 0, (x.shape, ms, axis)
    shaped = x.reshape(x.shape[:axis] + (ms, n // ms) + x.shape[axis + 1:])
    return jnp.moveaxis(shaped, axis, 0)


def _dm_heads(x, lay: Layout, head_axis: int, hd_axis: Optional[int],
              n_kv_repl: int = 1):
    """Shard ``head_axis`` over heads_sub (with optional replication for
    GQA KV) and ``hd_axis`` over cluster; device order = heads-major."""
    hs, cl, ms = lay.heads_sub, lay.cluster, lay.model_size
    if n_kv_repl > 1:
        x = jnp.repeat(x, n_kv_repl, axis=head_axis)
    nh = x.shape[head_axis]
    x = x.reshape(x.shape[:head_axis] + (hs, nh // hs)
                  + x.shape[head_axis + 1:])
    x = jnp.moveaxis(x, head_axis, 0)                    # [hs, ...]
    if hd_axis is not None:
        a = hd_axis + 1                                  # one new dim, front
        hdn = x.shape[a]
        x = x.reshape(x.shape[:a] + (cl, hdn // cl) + x.shape[a + 1:])
        x = jnp.moveaxis(x, a, 1)                        # [hs, cl, ...]
    else:
        x = jnp.broadcast_to(x[:, None], (hs, cl) + x.shape[1:])
    return x.reshape((ms,) + x.shape[2:])


def _layout_attn(a: AttnParams, cfg: ModelConfig, lay: Layout) -> AttnParams:
    hs = lay.heads_sub
    kv_repl = max(1, hs // cfg.n_kv_heads)
    d = cfg.d_model
    hd = a.wq.shape[-1]
    nh = a.wq.shape[1]
    wo = a.wo.reshape(nh, hd, d)
    return AttnParams(
        wq=_dm_heads(a.wq, lay, head_axis=1, hd_axis=2),
        wk=_dm_heads(a.wk, lay, head_axis=1, hd_axis=2, n_kv_repl=kv_repl),
        wv=_dm_heads(a.wv, lay, head_axis=1, hd_axis=2, n_kv_repl=kv_repl),
        # wo rows sharded by head over heads_sub, replicated over cluster
        wo=_dm_heads(wo, lay, head_axis=0, hd_axis=None).reshape(
            lay.model_size, (nh // hs) * hd, d),
        bq=None if a.bq is None else _dm_heads(a.bq, lay, 0, 1),
        bk=None if a.bk is None else _dm_heads(a.bk, lay, 0, 1,
                                               n_kv_repl=kv_repl),
        bv=None if a.bv is None else _dm_heads(a.bv, lay, 0, 1,
                                               n_kv_repl=kv_repl),
    )


def _layout_mla(a: MLAAttnParams, cfg: ModelConfig, lay: Layout
                ) -> MLAAttnParams:
    ms, hs, cl = lay.model_size, lay.heads_sub, lay.cluster
    m = cfg.mla
    nh = cfg.n_heads
    d = cfg.d_model
    wo = a.wo.reshape(nh, m.v_head_dim, d)
    # wdkv: cluster-sharded cols, replicated across heads groups
    wdkv = _dm_split(a.wdkv, cl, axis=1)                 # [cl, D, seg]
    wdkv = jnp.broadcast_to(wdkv[None], (hs,) + wdkv.shape).reshape(
        (ms,) + wdkv.shape[1:])
    return MLAAttnParams(
        wq=_dm_heads(a.wq, lay, head_axis=1, hd_axis=2),
        wdkv=wdkv,
        wuk=_dm_heads(a.wuk, lay, head_axis=0, hd_axis=None),
        wuv=_dm_heads(a.wuv, lay, head_axis=0, hd_axis=None),
        wo=_dm_heads(wo, lay, head_axis=0, hd_axis=None).reshape(
            ms, (nh // hs) * m.v_head_dim, d),
    )


def _layout_ffn(f: FFNParams, lay: Layout) -> FFNParams:
    ms = lay.model_size
    return FFNParams(
        w_in=_dm_split(f.w_in, ms, axis=1),
        w_out=_dm_split(f.w_out, ms, axis=0),
        w_gate=None if f.w_gate is None else _dm_split(f.w_gate, ms, axis=1),
    )


def _layout_moe(p: MoEParams, lay: Layout) -> MoEParams:
    ms = lay.model_size
    return MoEParams(
        router=_dm_replicate(p.router, ms),
        w_in=_dm_split(p.w_in, ms, axis=0),
        w_out=_dm_split(p.w_out, ms, axis=0),
        w_gate=None if p.w_gate is None else _dm_split(p.w_gate, ms, axis=0),
        dense=None if p.dense is None else _layout_ffn(p.dense, lay),
    )


def _layout_rglru(p: RGLRUParams, lay: Layout) -> RGLRUParams:
    """Gate blocks (= heads) distribute whole over the model axis; all other
    tensors shard on the d_state channel dim (block-major ⇒ consistent)."""
    ms = lay.model_size
    return RGLRUParams(
        w_x=_dm_split(p.w_x, ms, 1), w_gate=_dm_split(p.w_gate, ms, 1),
        conv_w=_dm_split(p.conv_w, ms, 1), conv_b=_dm_split(p.conv_b, ms, 0),
        w_r=_dm_split(p.w_r, ms, 0),
        b_r=_dm_split(p.b_r, ms, 0),
        w_i=_dm_split(p.w_i, ms, 0),
        b_i=_dm_split(p.b_i, ms, 0),
        lam=_dm_split(p.lam, ms, 0),
        w_out=_dm_split(p.w_out, ms, 0),
    )


def _layout_rwkv(p: RWKV6Params, cfg: ModelConfig, lay: Layout) -> RWKV6Params:
    ms, hs = lay.model_size, lay.heads_sub
    hd = cfg.rwkv_head_dim
    nh = cfg.d_model // hd

    def by_head_cols(w):                         # [D, D_all] cols by head
        x = w.reshape(w.shape[0], nh, hd)
        return _dm_heads(x, lay, head_axis=1, hd_axis=None).reshape(
            ms, w.shape[0], (nh // hs) * hd)

    def by_head_vec(v):                          # [D_all] by head
        x = v.reshape(nh, hd)
        return _dm_heads(x, lay, head_axis=0, hd_axis=None).reshape(ms, -1)

    w_out = p.w_out.reshape(nh, hd, cfg.d_model)
    return RWKV6Params(
        mu=_dm_replicate(p.mu, ms),
        w_r=by_head_cols(p.w_r), w_k=by_head_cols(p.w_k),
        w_v=by_head_cols(p.w_v), w_g=by_head_cols(p.w_g),
        w_out=_dm_heads(w_out, lay, head_axis=0, hd_axis=None).reshape(
            ms, (nh // hs) * hd, cfg.d_model),
        w_base=by_head_vec(p.w_base),
        lora_a=_dm_replicate(p.lora_a, ms),
        lora_b=by_head_cols(p.lora_b.reshape(p.lora_a.shape[1], -1)
                            if p.lora_b.ndim == 2 else p.lora_b),
        u=by_head_vec(p.u),
        ln_scale=by_head_vec(p.ln_scale),
        mu_c=_dm_replicate(p.mu_c, ms),
        cm_k=_dm_split(p.cm_k, ms, 1),
        cm_v=_dm_split(p.cm_v, ms, 0),
        cm_r=_dm_replicate(p.cm_r, ms),
    )


def _layout_block(blk: Dict[str, Any], cfg: ModelConfig, lay: Layout,
                  encoder: bool = False) -> Dict[str, Any]:
    ms = lay.model_size
    out: Dict[str, Any] = {}
    for name, val in blk.items():
        if name.startswith("ln") or name.startswith("post_ln"):
            out[name] = _dm_replicate(val, ms)
        elif name == "attn":
            if isinstance(val, MLAAttnParams):
                out[name] = _layout_mla(val, cfg, lay)
            elif encoder:
                # encoder shares the decoder's (heads_sub × cluster)
                # factoring — runtime ctx is one per model
                e = cfg.encoder
                assert e.n_heads % lay.heads_sub == 0, (e.n_heads, lay)
                kv_repl = max(1, lay.heads_sub // e.n_kv_heads)
                out[name] = AttnParams(
                    wq=_dm_heads(val.wq, lay, 1, 2),
                    wk=_dm_heads(val.wk, lay, 1, 2, n_kv_repl=kv_repl),
                    wv=_dm_heads(val.wv, lay, 1, 2, n_kv_repl=kv_repl),
                    wo=_dm_heads(val.wo.reshape(e.n_heads, -1, cfg.d_model),
                                 lay, 0, None).reshape(
                        ms, (e.n_heads // lay.heads_sub) * val.wq.shape[-1],
                        cfg.d_model),
                )
            else:
                out[name] = _layout_attn(val, cfg, lay)
        elif name == "rglru":
            out[name] = _layout_rglru(val, lay)
        elif name == "rwkv":
            out[name] = _layout_rwkv(val, cfg, lay)
        elif name == "ffn":
            out[name] = (_layout_moe(val, lay) if isinstance(val, MoEParams)
                         else _layout_ffn(val, lay))
        elif name == "ln":
            out[name] = _dm_replicate(val, ms)
        else:
            raise KeyError(name)
    return out


def to_device_major(cfg: ModelConfig, lay: Layout, logical: Dict[str, Any]
                    ) -> Dict[str, Any]:
    ms = lay.model_size
    out: Dict[str, Any] = {}
    vmap_blk = lambda blk, enc=False: jax.vmap(
        lambda b: _layout_block(b, cfg, lay, enc), in_axes=0, out_axes=1
    )(blk)
    out["blocks"] = [vmap_blk(b) for b in logical["blocks"]]
    out["tail"] = [_layout_block(b, cfg, lay) for b in logical["tail"]]
    v_pad = padded_vocab(cfg.vocab_size, ms)
    emb = logical["embed"]
    if emb.shape[0] < v_pad:
        emb = jnp.pad(emb, ((0, v_pad - emb.shape[0]), (0, 0)))
    out["embed"] = _dm_split(emb, ms, 0)
    out["final_norm"] = _dm_replicate(logical["final_norm"], ms)
    if "lm_head" in logical:
        lm = logical["lm_head"]
        if lm.shape[0] < v_pad:
            lm = jnp.pad(lm, ((0, v_pad - lm.shape[0]), (0, 0)))
        out["lm_head"] = _dm_split(lm, ms, 0)
    if "frontend_proj" in logical:
        out["frontend_proj"] = _dm_replicate(logical["frontend_proj"], ms)
    if "encoder" in logical:
        out["encoder"] = vmap_blk(logical["encoder"], enc=True)
        out["enc_final_norm"] = _dm_replicate(logical["enc_final_norm"], ms)
        out["cross_attn"] = vmap_blk(logical["cross_attn"])
    return out


def init_device_major(cfg: ModelConfig, lay: Layout, key,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    return to_device_major(cfg, lay, init_logical(cfg, key, dtype))


# ===========================================================================
# Sharding specs for the device-major tree
# ===========================================================================
def param_specs(cfg: ModelConfig, params: PyTree, model_axis: str = "model"):
    """PartitionSpec tree — every leaf is device-major: [model, …]
    (scanned-group leaves are [model, n_groups, …])."""
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(
        lambda leaf: P(model_axis, *([None] * (leaf.ndim - 1))), params)


def unwrap_local(params: PyTree) -> PyTree:
    """Strip the (sharded-to-1) device dim inside shard_map bodies."""
    return jax.tree.map(lambda leaf: leaf[0], params)


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================
def apply_block(ctx: ParallelCtx, cfg: ModelConfig, kind: str,
                blk: Dict[str, Any], x: jax.Array, *,
                causal: bool = True, return_kv: bool = False,
                enc_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                cross_blk: Optional[Dict[str, Any]] = None):
    eps = cfg.norm_eps
    kv = None
    if kind == RWKV6:
        p: RWKV6Params = blk["rwkv"]
        a, _ = rwkv_mod.rwkv6_time_mix(ctx, p, rms_norm(x, blk["ln1"], eps),
                                       cfg.rwkv_head_dim)
        x = x + a
        c = rwkv_mod.rwkv6_channel_mix(ctx, p, rms_norm(x, blk["ln2"], eps))
        return x + c, kv
    if kind == RECURRENT:
        a = rglru_mod.rglru_block(ctx, blk["rglru"],
                                  rms_norm(x, blk["ln1"], eps))
    elif cfg.mla is not None:
        a, kv = attn_mod.mla_attention_train(
            ctx, blk["attn"], rms_norm(x, blk["ln1"], eps), cfg,
            return_kv=return_kv)
    else:
        a, kv = attn_mod.attention_train(
            ctx, blk["attn"], rms_norm(x, blk["ln1"], eps), cfg, kind,
            return_kv=return_kv)
    if "post_ln1" in blk:
        a = rms_norm(a, blk["post_ln1"], eps)
    x = x + a
    if cross_blk is not None and enc_kv is not None:
        ca = cross_attention(ctx, cross_blk["attn"],
                             rms_norm(x, cross_blk["ln"], eps), enc_kv, cfg)
        x = x + ca
    h = rms_norm(x, blk["ln2"], eps)
    f = (moe_mod.moe_apply(ctx, blk["ffn"], h, cfg.ffn_act, cfg.moe)
         if isinstance(blk["ffn"], MoEParams)
         else ffn_apply(ctx, blk["ffn"], h, cfg.ffn_act))
    if "post_ln2" in blk:
        f = rms_norm(f, blk["post_ln2"], eps)
    return x + f, kv


def cross_attention(ctx: ParallelCtx, p: AttnParams, x: jax.Array,
                    enc_out: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Decoder cross-attention; K/V projected from the encoder output."""
    B, S, D = x.shape
    n = ctx.cluster_size
    q_loc, hd_seg = p.wq.shape[1], p.wq.shape[2]
    kv_loc = p.wk.shape[1]
    hd = hd_seg * n
    qpk = q_loc // kv_loc
    q = jnp.einsum("bsd,dqh->bsqh", x, p.wq)
    k = jnp.einsum("bpd,dkh->bpkh", enc_out, p.wk)
    v = jnp.einsum("bpd,dkh->bpkh", enc_out, p.wv)
    q = ctx.gather_cluster(q, axis=3)
    k = ctx.gather_cluster(k, axis=3)
    v = ctx.gather_cluster(v, axis=3)
    if n > 1:
        s_blk = S // n
        q_off = ctx.cluster_index() * s_blk
        q = lax.dynamic_slice_in_dim(q, q_off, s_blk, axis=1)
    else:
        s_blk = S
    qg = q.reshape(B, s_blk, kv_loc, qpk, hd)
    out = attn_mod._flash(qg, k, v, q_offset=0, causal=False, window=0,
                          cap=0.0, scale=1.0 / math.sqrt(hd))
    y = out.reshape(B, s_blk, q_loc * hd) @ p.wo
    y = ctx.psum_heads(y)
    if n > 1:
        y = ctx.gather_cluster(y, axis=1)
    return y


def _enc_view(cfg: ModelConfig) -> ModelConfig:
    """Config view for encoder blocks (bidirectional, no softcaps)."""
    import dataclasses
    e = cfg.encoder
    return dataclasses.replace(cfg, n_heads=e.n_heads, n_kv_heads=e.n_kv_heads,
                               attn_softcap=0.0, qkv_bias=False, mla=None,
                               head_dim=cfg.d_model // e.n_heads)


def encode(ctx: ParallelCtx, cfg: ModelConfig, params: Dict[str, Any],
           frontend_embeds: jax.Array, *, remat: bool = True,
           fsdp=None) -> jax.Array:
    """Encoder stack over (stub-)frontend embeddings → [B, P, D]."""
    x = frontend_embeds.astype(params["frontend_proj"].dtype) \
        @ params["frontend_proj"]
    ecfg = _enc_view(cfg)

    def enc_body(h, blk):
        if fsdp is not None:
            ax, dpa = fsdp
            blk = fsdp_gather(blk, ax["encoder"], dpa, in_scan=True)
        a, _ = attn_mod.attention_train(
            ctx, blk["attn"], rms_norm(h, blk["ln1"], cfg.norm_eps),
            ecfg, ATTN_GLOBAL, causal=False)
        h = h + a
        f = ffn_apply(ctx, blk["ffn"], rms_norm(h, blk["ln2"], cfg.norm_eps),
                      cfg.ffn_act)
        return h + f, None

    body = _remat(enc_body) if remat else enc_body
    x, _ = lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _remat(fn):
    return jax.checkpoint(fn,
                          policy=jax.checkpoint_policies.nothing_saveable)


def forward(ctx: ParallelCtx, cfg: ModelConfig, params: Dict[str, Any],
            tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None,
            *, remat: bool = True, fsdp=None) -> jax.Array:
    """Token (+frontend) → final hidden states [B, S, D].

    VLM: frontend embeddings replace the first ``num_positions`` token
    embeddings.  Enc-dec: frontend feeds the encoder; decoder cross-attends.

    ``fsdp=(ax_tree, dp_axes)``: scanned-group params arrive dp-sliced and
    are all-gathered per group inside the scan (ZeRO-3); non-stacked
    leaves must be pre-gathered by the caller (``fsdp_gather_top``).
    """
    kinds = cfg.layer_kinds
    period = len(cfg.block_pattern)
    n_groups = cfg.n_layers // period
    x = embed_lookup(ctx, EmbedParams(params["embed"]), tokens)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.frontend is not None and cfg.encoder is None:
        # VLM: splice patch embeddings into the prefix
        fe = frontend_embeds.astype(x.dtype) @ params["frontend_proj"]
        npos = fe.shape[1]
        x = jnp.concatenate([fe, x[:, npos:]], axis=1)

    if cfg.encoder is not None:
        enc_out = encode(ctx, cfg, params, frontend_embeds, remat=remat,
                         fsdp=fsdp)

        def group_body_cross(h, inp):
            blks, ca = inp
            if fsdp is not None:
                ax, dpa = fsdp
                blks = tuple(fsdp_gather(b, a, dpa, in_scan=True)
                             for b, a in zip(blks, ax["blocks"]))
                ca = fsdp_gather(ca, ax["cross_attn"], dpa, in_scan=True)
            for p_i in range(period):
                h, _ = apply_block(ctx, cfg, kinds[p_i], blks[p_i], h,
                                   enc_kv=enc_out, cross_blk=ca)
            return h, None

        body = _remat(group_body_cross) if remat else group_body_cross
        x, _ = lax.scan(body, x, (tuple(params["blocks"]),
                                  params["cross_attn"]))
    else:
        def group_body(h, blks):
            if fsdp is not None:
                ax, dpa = fsdp
                blks = tuple(fsdp_gather(b, a, dpa, in_scan=True)
                             for b, a in zip(blks, ax["blocks"]))
            for p_i in range(period):
                h, _ = apply_block(ctx, cfg, kinds[p_i], blks[p_i], h)
            return h, None

        body = _remat(group_body) if remat else group_body
        if params["blocks"]:
            x, _ = lax.scan(body, x, tuple(params["blocks"]))
    for t_i, blk in enumerate(params["tail"]):
        x, _ = apply_block(ctx, cfg, kinds[n_groups * period + t_i], blk, x)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(ctx: ParallelCtx, cfg: ModelConfig, params: Dict[str, Any],
            batch: Dict[str, jax.Array], *, remat: bool = True,
            fsdp=None) -> Tuple[jax.Array, jax.Array]:
    """Next-token loss.  batch: tokens [B,S], targets [B,S], valid [B,S]
    (+ frontend_embeds for audio/vlm).  Returns local (sum_nll, sum_valid)."""
    if fsdp is not None:
        params = fsdp_gather_top(params, *fsdp)
    h = forward(ctx, cfg, params, batch["tokens"],
                batch.get("frontend_embeds"), remat=remat, fsdp=fsdp)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits_loc = lm_head_logits(ctx, table, h)
    if cfg.logit_softcap:
        logits_loc = softcap(logits_loc, cfg.logit_softcap)
    return vocab_parallel_xent(ctx, logits_loc, batch["targets"],
                               batch.get("valid"))


# ===========================================================================
# Gradient synchronization spec (Megatron's "allreduce layernorm grads",
# generalized to the heads × cluster sub-axis layout)
# ===========================================================================
# A leaf whose copies are replicated over some device subgroup receives only
# a *partial* gradient on each copy (the loss flows through each rank's own
# path); the true gradient is the subgroup sum.  Markers:
#   None       — fully sharded, no sync
#   "model"    — replicated over the whole model axis
#   "heads"    — replicated across head groups (MLA latent projection)
#   "cluster"  — replicated across the cluster sub-axis (W_O tiles, RWKV
#                head params, MLA up-projections)
#   ("copies", r) — GQA KV weights replicated r× along the heads sub-axis
_MODEL_SYNC_NAMES = frozenset({
    "ln1", "ln2", "post_ln1", "post_ln2", "ln", "final_norm",
    "enc_final_norm", "frontend_proj", "router", "mu", "mu_c", "lora_a",
    "cm_r",
})


def _attn_sync(cfg: ModelConfig, lay: Layout, encoder: bool):
    n_kv = cfg.encoder.n_kv_heads if encoder else cfg.n_kv_heads
    kv_repl = max(1, lay.heads_sub // n_kv)
    kv = ("copies", kv_repl) if kv_repl > 1 else None
    return AttnParams(wq=None, wk=kv, wv=kv, wo="cluster",
                      bq=None, bk=kv, bv=kv)


def _block_sync(blk: Dict[str, Any], cfg: ModelConfig, lay: Layout,
                encoder: bool = False) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for name, val in blk.items():
        if name in _MODEL_SYNC_NAMES:
            out[name] = "model"
        elif name == "attn":
            if isinstance(val, MLAAttnParams):
                out[name] = MLAAttnParams(wq=None, wdkv="heads",
                                          wuk="cluster", wuv="cluster",
                                          wo="cluster")
            else:
                a = _attn_sync(cfg, lay, encoder)
                if val.bq is None:
                    a = a._replace(bq=None, bk=None, bv=None)
                out[name] = a
        elif name == "rglru":
            out[name] = jax.tree.map(lambda _: None, val)
        elif name == "rwkv":
            out[name] = RWKV6Params(
                mu="model", w_r="cluster", w_k="cluster", w_v="cluster",
                w_g="cluster", w_out="cluster", w_base="cluster",
                lora_a="model", lora_b="cluster", u="cluster",
                ln_scale="cluster", mu_c="model", cm_k=None, cm_v=None,
                cm_r="model")
        elif name == "ffn":
            if isinstance(val, MoEParams):
                out[name] = MoEParams(
                    router="model", w_in=None, w_out=None,
                    w_gate=None if val.w_gate is not None else None,
                    dense=None if val.dense is None
                    else jax.tree.map(lambda _: None, val.dense))
            else:
                out[name] = jax.tree.map(lambda _: None, val)
        else:
            raise KeyError(name)
    return out


def grad_sync_tree(cfg: ModelConfig, lay: Layout, params: PyTree) -> PyTree:
    """Marker tree matching ``params`` (device-major) structure."""
    def blocks_like(blk_tree, encoder=False):
        # markers are shape-independent: reuse the block structure
        names = {k: v for k, v in blk_tree.items()}
        return _block_sync(names, cfg, lay, encoder)

    out: Dict[str, Any] = {}
    for k, v in params.items():
        if k == "blocks":
            out[k] = [blocks_like(b) for b in v]
        elif k == "tail":
            out[k] = [blocks_like(b) for b in v]
        elif k == "encoder":
            out[k] = blocks_like(v, encoder=True)
        elif k == "cross_attn":
            out[k] = {"ln": "model",
                      "attn": _attn_sync(cfg, lay, encoder=False)._replace(
                          bq=None, bk=None, bv=None)
                      if v["attn"].bq is None
                      else _attn_sync(cfg, lay, encoder=False)}
        elif k in ("embed", "lm_head"):
            out[k] = None
        elif k in ("final_norm", "enc_final_norm", "frontend_proj"):
            out[k] = "model"
        else:
            raise KeyError(k)
    return out


def sync_grads(ctx: ParallelCtx, grads: PyTree, sync: PyTree) -> PyTree:
    """Apply the subgroup psums prescribed by ``grad_sync_tree``."""
    if ctx.model is None:
        return grads
    from repro.core import primitives as prim
    from repro.core.primitives import SubAxis
    model_name = (ctx.model.name if isinstance(ctx.model, SubAxis)
                  else ctx.model)
    cluster_size = ctx.cluster_size

    def one(g, mark):
        if mark is None or g is None:
            return g
        if mark == "model":
            return ctx.psum_model(g)
        if mark == "heads":
            return prim.cluster_reduce(g, ctx.heads, "sum")
        if mark == "cluster":
            return (prim.cluster_reduce(g, ctx.cluster, "sum")
                    if cluster_size > 1 else g)
        if isinstance(mark, tuple) and mark[0] == "copies":
            sub = SubAxis(model_name, mark[1], minor_size=cluster_size)
            return prim.cluster_reduce(g, sub, "sum")
        raise ValueError(mark)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(sync)
    return treedef.unflatten([one(g, m) for g, m in zip(flat_g, flat_m)])


# ===========================================================================
# FSDP (ZeRO-3): params sharded over the data axes, gathered at use
# ===========================================================================
# fsdp_axes marks, per leaf, which LOCAL axis is sliced over data (None =
# replicated / not sliceable).  Stacked (scanned) leaves are sliced on an
# axis AFTER the group dim so the scan can consume groups whole; the gather
# then happens inside the scan body — peak memory holds one group's full
# params plus 1/dp of everything else.  jax.grad through the gather
# produces reduce-scattered (pre-sliced, dp-summed) gradients for free.
_STACKED_KEYS = ("blocks", "encoder", "cross_attn")


def _fsdp_ax_of(shape, dp: int, skip: int) -> Optional[int]:
    for ax in range(skip, len(shape)):
        if shape[ax] >= dp and shape[ax] % dp == 0:
            return ax
    return None


def fsdp_axes(params: PyTree, dp: int) -> PyTree:
    """Axis markers relative to the unwrapped-local leaf ([G, …] for
    stacked leaves, [...] otherwise)."""
    out = {}
    for k, v in params.items():
        skip = 1 if k in _STACKED_KEYS else 0
        out[k] = jax.tree.map(
            lambda l, s=skip: _fsdp_ax_of(tuple(l.shape[1:]), dp, s), v)
    return out


def fsdp_shard_abstract(params_abs: PyTree, ax_tree: PyTree, dp: int
                        ) -> PyTree:
    """Shrink abstract (device-major global) leaves by the dp slice."""
    def one(l, ax):
        if ax is None:
            return l
        g_ax = ax + 1                       # global leaf has the model dim
        shape = list(l.shape)
        shape[g_ax] //= dp
        return jax.ShapeDtypeStruct(tuple(shape), l.dtype)

    flat, td = jax.tree.flatten(params_abs)
    axf = td.flatten_up_to(ax_tree)
    return td.unflatten([one(l, a) for l, a in zip(flat, axf)])


def fsdp_param_specs(cfg: ModelConfig, params_abs: PyTree, ax_tree: PyTree,
                     dp_axes, model_axis: str = "model") -> PyTree:
    from jax.sharding import PartitionSpec as P

    def one(l, ax):
        entries = [model_axis] + [None] * (l.ndim - 1)
        if ax is not None:
            entries[ax + 1] = dp_axes
        return P(*entries)

    flat, td = jax.tree.flatten(params_abs)
    axf = td.flatten_up_to(ax_tree)
    return td.unflatten([one(l, a) for l, a in zip(flat, axf)])


def fsdp_gather(tree: PyTree, ax_tree: PyTree, dp_axes, *,
                in_scan: bool = False) -> PyTree:
    """All-gather sliced leaves back to full local shape.  ``in_scan``:
    the leading group dim has been consumed by the scan ⇒ axes shift −1."""
    def one(l, ax):
        if ax is None:
            return l
        a = ax - 1 if in_scan else ax
        return lax.all_gather(l, dp_axes, axis=a, tiled=True)

    flat, td = jax.tree.flatten(tree)
    axf = td.flatten_up_to(ax_tree)
    return td.unflatten([one(l, a) for l, a in zip(flat, axf)])


def fsdp_slice(tree: PyTree, ax_tree: PyTree, dp: int, rank,
               *, in_scan: bool = False) -> PyTree:
    def one(l, ax):
        if ax is None:
            return l
        a = ax - 1 if in_scan else ax
        size = l.shape[a] // dp
        return lax.dynamic_slice_in_dim(l, rank * size, size, axis=a)

    flat, td = jax.tree.flatten(tree)
    axf = td.flatten_up_to(ax_tree)
    return td.unflatten([one(l, a) for l, a in zip(flat, axf)])


def fsdp_gather_top(params: PyTree, ax_tree: PyTree, dp_axes) -> PyTree:
    """Gather the non-stacked subtrees (embed / lm_head / tail / norms);
    stacked groups gather lazily inside the scans."""
    out = {}
    for k, v in params.items():
        if k in _STACKED_KEYS:
            out[k] = v
        else:
            out[k] = fsdp_gather(v, ax_tree[k], dp_axes)
    return out
