"""Training / prefill attention (GQA + MLA), manual-SPMD.

Sharding layout (train):

* q heads sharded over the ``heads`` sub-axis (size H);
* head_dim sharded over the ``cluster`` sub-axis (size N) for the QKV
  projection — segments are ClusterGather'd before the attention proper
  (paper Alg. 3 applied to training; with N=1 this is plain Megatron TP);
* attention compute is *query-sequence* split over the cluster sub-axis
  (each rank attends a contiguous block of query rows against the full
  KV) — sequence parallelism inside the attention block;
* W_O rows sharded over heads, outputs psum'd over the heads sub-axis.

KV weights are stored replicated when ``n_kv < heads_sub`` (GQA/MQA), so
every heads-rank holds the KV heads its local q heads need.

The chunked flash attention below (``_flash``) is the pure-jnp oracle the
Pallas kernels are validated against; it is differentiable and
memory-bounded (online softmax over KV chunks).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.ctx import ParallelCtx
from repro.models.layers import apply_rope, rope_cos_sin, softcap


class AttnParams(NamedTuple):
    """Local shapes (leading device dim stripped by the unwrapper):

    wq [D, q_loc, hd_seg]; wk/wv [D, kv_loc, hd_seg]; wo [q_loc*hd, D]
    (hd_seg = head_dim / cluster_size).  Optional biases [*_loc, hd_seg].
    """

    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None


class MLAAttnParams(NamedTuple):
    """Train-time MLA params (local): wq [D, q_loc, nope+rope];
    wdkv [D, l+rope]; wuk [q_loc... see mla_attention_train]."""

    wq: jax.Array
    wdkv: jax.Array
    wuk: jax.Array          # [q_loc, nope, l]
    wuv: jax.Array          # [q_loc, l, v_dim]
    wo: jax.Array           # [q_loc*v_dim, D]


# ---------------------------------------------------------------------------
# Chunked flash attention (jnp oracle, differentiable)
# ---------------------------------------------------------------------------
def _flash(q: jax.Array, k: jax.Array, v: jax.Array, *,
           q_offset: jax.Array | int, causal: bool, window: int,
           cap: float, scale: float, kv_valid_len: Optional[jax.Array] = None,
           chunk: int = 512) -> jax.Array:
    """q: [B, Sq, KV, QPK, hd]; k/v: [B, Sk, KV, hd] → [B, Sq, KV, QPK, hd].

    Online-softmax scan over KV chunks.  ``q_offset`` maps local q rows to
    global positions (sequence-split attention); ``window > 0`` restricts
    keys to ``(pos_q − window, pos_q]``.
    """
    B, Sq, KV, QPK, hd = q.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    n_chunks = (Sk + chunk - 1) // chunk
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, k.shape[-1])
    vc = v.reshape(B, n_chunks, chunk, KV, v.shape[-1])
    q32 = q.astype(jnp.float32) * scale
    q_pos = (jnp.arange(Sq) + q_offset)[:, None]            # [Sq, 1]

    def body(carry, inp):
        m, l, o = carry
        kblk, vblk, cidx = inp                               # [B,chunk,KV,hd]
        s = jnp.einsum("bqkgh,bckh->bqkgc", q32, kblk.astype(jnp.float32))
        s = softcap(s, cap)
        k_pos = cidx * chunk + jnp.arange(chunk)[None, :]    # [1, chunk]
        valid = jnp.ones((Sq, chunk), bool)
        if causal:
            valid &= k_pos <= q_pos
        if window > 0:
            valid &= k_pos > q_pos - window
        if kv_valid_len is not None:
            valid &= k_pos < kv_valid_len
        valid &= k_pos < Sk                                   # padding
        s = jnp.where(valid[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(m), corr, 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p, vblk.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    hd_v = v.shape[-1]                                   # may differ (MLA)
    m0 = jnp.full((B, Sq, KV, QPK), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, QPK), jnp.float32)
    o0 = jnp.zeros((B, Sq, KV, QPK, hd_v), jnp.float32)
    kcs = jnp.moveaxis(kc, 1, 0)
    vcs = jnp.moveaxis(vc, 1, 0)
    (m, l, o), _ = lax.scan(body, (m0, l0, o0),
                            (kcs, vcs, jnp.arange(n_chunks)))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (train / prefill)
# ---------------------------------------------------------------------------
def attention_train(
    ctx: ParallelCtx,
    p: AttnParams,
    x: jax.Array,                 # [B, S, D] (replicated over model)
    cfg: ModelConfig,
    kind: str,                    # "attn_global" | "attn_local"
    *,
    causal: bool = True,
    return_kv: bool = False,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    B, S, D = x.shape
    n = ctx.cluster_size
    q_loc, hd_seg = p.wq.shape[1], p.wq.shape[2]
    kv_loc = p.wk.shape[1]
    hd = hd_seg * n
    qpk = q_loc // kv_loc
    window = cfg.sliding_window if kind == "attn_local" else 0

    # (1) head-dim *segments* of q/k/v (paper Alg. 3 line 2, batched form)
    q = jnp.einsum("bsd,dqh->bsqh", x, p.wq)
    k = jnp.einsum("bsd,dkh->bskh", x, p.wk)
    v = jnp.einsum("bsd,dkh->bskh", x, p.wv)
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv

    # (2) ClusterGather the full head dim (no-op when cluster==1)
    q = ctx.gather_cluster(q, axis=3)
    k = ctx.gather_cluster(k, axis=3)
    v = ctx.gather_cluster(v, axis=3)

    cos, sin = rope_cos_sin(jnp.arange(S), hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    kv_out = (k, v) if return_kv else None

    # (3) sequence-split attention over the cluster sub-axis
    if n > 1:
        s_blk = S // n
        c_rank = ctx.cluster_index()
        q_off = c_rank * s_blk
        q_blk = lax.dynamic_slice_in_dim(q, q_off, s_blk, axis=1)
    else:
        s_blk, q_off, q_blk = S, 0, q

    qg = q_blk.reshape(B, s_blk, kv_loc, qpk, hd)
    out = _flash(qg, k, v, q_offset=q_off, causal=causal, window=window,
                 cap=cfg.attn_softcap, scale=1.0 / math.sqrt(hd))
    out = out.reshape(B, s_blk, q_loc * hd)

    # (4) O-projection (rows over heads) + heads-axis reduction
    y = out @ p.wo
    y = ctx.psum_heads(y)

    # re-assemble the sequence (inverse of the seq split)
    if n > 1:
        y = ctx.gather_cluster(y, axis=1)
    return y, kv_out


# ---------------------------------------------------------------------------
# MLA attention (train / prefill) — DeepSeek-V2, non-absorbed form
# ---------------------------------------------------------------------------
def mla_attention_train(
    ctx: ParallelCtx,
    p: MLAAttnParams,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    return_kv: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Training-time MLA: materialize per-head K/V from the latent (the
    standard non-absorbed formulation; absorption is a decode-time
    optimization — paper Fig. 14)."""
    B, S, D = x.shape
    m = cfg.mla
    nope, rope_d, l_rank, v_dim = (m.nope_head_dim, m.rope_head_dim,
                                   m.kv_lora_rank, m.v_head_dim)
    q_loc = p.wq.shape[1]

    q = jnp.einsum("bsd,dqh->bsqh", x, p.wq)            # [B,S,q,(nope+rope)]
    c = x @ p.wdkv                                       # [B,S,l+rope]
    q = ctx.gather_cluster(q, axis=3)
    c = ctx.gather_cluster(c, axis=2)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    c_lat, c_rope = c[..., :l_rank], c[..., l_rank:]

    cos, sin = rope_cos_sin(jnp.arange(S), rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    c_rope = apply_rope(c_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    kv_out = jnp.concatenate([c_lat, c_rope], axis=-1) if return_kv else None

    # latent-space attention (absorbed q, as in the fused decode dataflow —
    # mathematically identical to materializing K)
    q_lat = jnp.einsum("bsqn,qnl->bsql", q_nope, p.wuk)
    n = ctx.cluster_size
    if n > 1:
        s_blk = S // n
        q_off = ctx.cluster_index() * s_blk
        q_lat = lax.dynamic_slice_in_dim(q_lat, q_off, s_blk, axis=1)
        q_rope_b = lax.dynamic_slice_in_dim(q_rope, q_off, s_blk, axis=1)
    else:
        s_blk, q_off, q_rope_b = S, 0, q_rope

    kk = jnp.concatenate([c_lat, c_rope], axis=-1)       # [B,S,l+rope]
    qq = jnp.concatenate([q_lat, q_rope_b], axis=-1)     # [B,s_blk,q,l+rope]
    out = _flash(qq[:, :, None, :, :],                   # KV groups = 1
                 kk[:, :, None, :], c_lat[:, :, None, :],
                 q_offset=q_off, causal=True, window=0, cap=0.0,
                 scale=1.0 / math.sqrt(nope + rope_d))
    a_lat = out[:, :, 0]                                 # [B,s_blk,q,l]
    o_head = jnp.einsum("bsql,qlv->bsqv", a_lat, p.wuv)
    y = o_head.reshape(B, s_blk, q_loc * v_dim) @ p.wo
    y = ctx.psum_heads(y)
    if n > 1:
        y = ctx.gather_cluster(y, axis=1)
    return y, kv_out


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, heads_sub: int, cluster: int,
              dtype=jnp.bfloat16, *, cross: bool = False) -> AttnParams:
    """LOCAL attention params for one (heads-rank, cluster-rank).

    Used under vmap-over-shards by the global param builder; shapes are
    identical on every rank (KV heads replicated when n_kv < heads_sub).
    """
    d, hd = cfg.d_model, cfg.resolved_head_dim
    q_loc = cfg.n_heads // heads_sub
    kv_loc = max(1, cfg.n_kv_heads // heads_sub)
    hd_seg = hd // cluster
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(q_loc * hd * heads_sub)
    bias = cfg.qkv_bias
    return AttnParams(
        wq=(jax.random.normal(ks[0], (d, q_loc, hd_seg)) * s_in).astype(dtype),
        wk=(jax.random.normal(ks[1], (d, kv_loc, hd_seg)) * s_in).astype(dtype),
        wv=(jax.random.normal(ks[2], (d, kv_loc, hd_seg)) * s_in).astype(dtype),
        wo=(jax.random.normal(ks[3], (q_loc * hd, d)) * s_out).astype(dtype),
        bq=jnp.zeros((q_loc, hd_seg), dtype) if bias else None,
        bk=jnp.zeros((kv_loc, hd_seg), dtype) if bias else None,
        bv=jnp.zeros((kv_loc, hd_seg), dtype) if bias else None,
    )


def mla_init(key, cfg: ModelConfig, heads_sub: int, cluster: int,
             dtype=jnp.bfloat16) -> MLAAttnParams:
    m = cfg.mla
    d = cfg.d_model
    q_loc = cfg.n_heads // heads_sub
    hr = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    return MLAAttnParams(
        wq=(jax.random.normal(ks[0], (d, q_loc, hr // cluster)) * s).astype(dtype),
        wdkv=(jax.random.normal(ks[1], (d, (m.kv_lora_rank + m.rope_head_dim)
                                        // cluster)) * s).astype(dtype),
        wuk=(jax.random.normal(ks[2], (q_loc, m.nope_head_dim,
                                       m.kv_lora_rank)) * 0.05).astype(dtype),
        wuv=(jax.random.normal(ks[3], (q_loc, m.kv_lora_rank,
                                       m.v_head_dim)) * 0.05).astype(dtype),
        wo=(jax.random.normal(ks[4], (q_loc * m.v_head_dim, d))
            * (1.0 / math.sqrt(cfg.n_heads * m.v_head_dim))).astype(dtype),
    )
