"""Mixture-of-Experts FFN with expert parallelism (manual SPMD).

Experts are sharded over the *whole* model axis (EP); activations at the
FFN input are replicated across the model axis (they just came out of an
attention psum), so each rank can locally dispatch the tokens routed to
ITS experts and a single psum combines expert outputs — the same
communication volume as a dense TP FFN.  Dispatch is capacity-based
(GShard-style token dropping) with a sort-free scatter build:

  token-slot (t, k) → expert e, weight p
  position-in-expert via a one-hot running count (exact GShard semantics)
  slots with position ≥ capacity are dropped
  gather  x[slot_token]  → [E_loc, C, D]   (static shapes, differentiable)
  expert GEMMs via batched einsum over the local expert dim
  scatter-combine with the routing weights, then psum over the model axis

Arctic's dense-residual branch runs a normal TP FFN in parallel and sums.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MoEConfig
from repro.models.ctx import ParallelCtx
from repro.models.layers import FFNParams, activation, ffn_apply, softcap


class MoEParams(NamedTuple):
    """Local shapes: router [D, E] (replicated); w_in/w_gate [E_loc, D, F];
    w_out [E_loc, F, D]; dense residual FFN params optional."""

    router: jax.Array
    w_in: jax.Array
    w_out: jax.Array
    w_gate: Optional[jax.Array] = None
    dense: Optional[FFNParams] = None


def _capacity(tokens: int, moe: MoEConfig) -> int:
    c = int(math.ceil(tokens * moe.top_k / moe.num_experts
                      * moe.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)            # pad to 8 for TPU layout


def route(moe: MoEConfig, router: jax.Array, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """Top-k routing. x: [T, D] → (expert_idx [T,k], weight [T,k])."""
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    logits = softcap(logits, moe.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, moe.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return idx, w


def moe_apply(ctx: ParallelCtx, p: MoEParams, x: jax.Array, act: str,
              moe: MoEConfig) -> jax.Array:
    """x: [B, S, D] (replicated over model) → [B, S, D]."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    e_loc = p.w_in.shape[0]
    n_shards = max(1, moe.num_experts // e_loc)
    shard = ctx.model_index()
    C = _capacity(T, moe)

    idx, w = route(moe, p.router, xt)                     # [T,k]
    # GShard position-in-expert, sort-based (O(Tk·logTk) and O(Tk) memory —
    # the one-hot-cumsum formulation would materialize [Tk, E]): a stable
    # argsort by expert preserves slot order, so earlier tokens win
    # capacity exactly as in GShard.
    flat_e = idx.reshape(-1)                              # [T*k]
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(moe.num_experts))
    pos_sorted = jnp.arange(tk) - start[sorted_e]
    pos_in_e = jnp.zeros((tk,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = pos_in_e < C

    # keep only slots owned by this shard's experts
    local_e = flat_e - shard * e_loc
    mine = (local_e >= 0) & (local_e < e_loc) & keep
    local_e_c = jnp.clip(local_e, 0, e_loc - 1)
    slot_addr = local_e_c * C + jnp.clip(pos_in_e, 0, C - 1)

    # scatter token ids into the [E_loc*C] dispatch table; dropped / foreign
    # slots all write the sentinel row (GShard position assignment makes
    # every kept (e, pos) unique, so real writes never collide)
    tok_ids = jnp.repeat(jnp.arange(T), moe.top_k)
    addr = jnp.where(mine, slot_addr, e_loc * C)
    table = jnp.full((e_loc * C + 1,), T, jnp.int32)      # T ⇒ empty slot
    table = table.at[addr].set(jnp.where(mine, tok_ids, T))
    table = table[: e_loc * C]
    x_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    xe = jnp.take(x_pad, table, axis=0).reshape(e_loc, C, D)

    # expert FFN (batched over local experts)
    h = jnp.einsum("ecd,edf->ecf", xe, p.w_in)
    if p.w_gate is not None:
        h = activation(act)(jnp.einsum("ecd,edf->ecf", xe, p.w_gate)) * h
    else:
        h = activation(act)(h)
    ye = jnp.einsum("ecf,efd->ecd", h, p.w_out)           # [E_loc, C, D]

    # combine: route expert outputs back to their tokens with weights
    flat_w = w.reshape(-1).astype(ye.dtype)
    gathered = jnp.take(ye.reshape(e_loc * C, D),
                        jnp.clip(slot_addr, 0, e_loc * C - 1), axis=0)
    contrib = jnp.where(mine[:, None], gathered * flat_w[:, None], 0)
    y = jnp.zeros((T, D), ye.dtype).at[tok_ids].add(contrib)
    y = ctx.psum_model(y)
    y = y.astype(x.dtype).reshape(B, S, D)

    if p.dense is not None:                               # Arctic residual
        y = y + ffn_apply(ctx, p.dense, x, act)
    return y


def aux_load_balance_loss(moe: MoEConfig, router: jax.Array, x: jax.Array
                          ) -> jax.Array:
    """Switch-Transformer auxiliary loss (fraction·probability balance)."""
    T = x.shape[0]
    logits = x.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(idx, moe.num_experts), axis=0)
    prob = jnp.mean(probs, axis=0)
    return moe.num_experts * jnp.sum(frac * prob)


def moe_init(key, d_model: int, moe: MoEConfig, n_shards: int, gated: bool,
             dtype=jnp.bfloat16) -> MoEParams:
    e_loc = max(1, moe.num_experts // n_shards)
    f = moe.expert_d_ff
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(f)
    dense = None
    if moe.dense_ff_residual:
        from repro.models.layers import ffn_init
        dense = ffn_init(ks[4], d_model,
                         max(1, moe.dense_residual_d_ff // n_shards), gated,
                         dtype)
    return MoEParams(
        router=(jax.random.normal(ks[0], (d_model, moe.num_experts))
                * s_in).astype(jnp.float32),
        w_in=(jax.random.normal(ks[1], (e_loc, d_model, f)) * s_in).astype(dtype),
        w_out=(jax.random.normal(ks[2], (e_loc, f, d_model)) * s_out).astype(dtype),
        w_gate=(jax.random.normal(ks[3], (e_loc, d_model, f)) * s_in).astype(dtype)
        if gated else None,
        dense=dense,
    )


def moe_apply_dff(ctx: ParallelCtx, p: MoEParams, x_rep: jax.Array,
                  act: str, moe: MoEConfig, dff_axes) -> jax.Array:
    """Decode-path MoE for models whose expert weights exceed per-device
    HBM under model-axis EP alone (kimi-1T, arctic-480B): each expert's
    d_ff is additionally sliced over the data axis, so weights spread over
    (model × data) = 256 ranks.  ``x_rep`` [T, D] must be replicated over
    ``dff_axes``; the output psum runs over (dff_axes + model) — partial
    d_ff products sum exactly like a row-sharded TP FFN.

    Dense-residual branch (arctic) is sliced the same way.
    """
    T, D = x_rep.shape
    e_loc = p.w_in.shape[0]
    shard = ctx.model_index()
    C = _capacity(T, moe)

    idx, w = route(moe, p.router, x_rep)
    flat_e = idx.reshape(-1)
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(moe.num_experts))
    pos_sorted = jnp.arange(tk) - start[sorted_e]
    pos_in_e = jnp.zeros((tk,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = pos_in_e < C
    local_e = flat_e - shard * e_loc
    mine = (local_e >= 0) & (local_e < e_loc) & keep
    local_e_c = jnp.clip(local_e, 0, e_loc - 1)
    slot_addr = local_e_c * C + jnp.clip(pos_in_e, 0, C - 1)
    tok_ids = jnp.repeat(jnp.arange(T), moe.top_k)
    addr = jnp.where(mine, slot_addr, e_loc * C)
    table = jnp.full((e_loc * C + 1,), T, jnp.int32)
    table = table.at[addr].set(jnp.where(mine, tok_ids, T))
    table = table[: e_loc * C]
    x_pad = jnp.concatenate([x_rep, jnp.zeros((1, D), x_rep.dtype)], axis=0)
    xe = jnp.take(x_pad, table, axis=0).reshape(e_loc, C, D)

    # expert GEMMs over the LOCAL d_ff slice; partial products sum via the
    # (dff_axes + model) psum below
    h = jnp.einsum("ecd,edf->ecf", xe, p.w_in)
    if p.w_gate is not None:
        h = activation(act)(jnp.einsum("ecd,edf->ecf", xe, p.w_gate)) * h
    else:
        h = activation(act)(h)
    ye = jnp.einsum("ecf,efd->ecd", h, p.w_out)

    flat_w = w.reshape(-1).astype(ye.dtype)
    gathered = jnp.take(ye.reshape(e_loc * C, D),
                        jnp.clip(slot_addr, 0, e_loc * C - 1), axis=0)
    contrib = jnp.where(mine[:, None], gathered * flat_w[:, None], 0)
    y = jnp.zeros((T, D), ye.dtype).at[tok_ids].add(contrib)
    y = jax.lax.psum(y, dff_axes)
    y = ctx.psum_model(y)
    y = y.astype(x_rep.dtype)

    if p.dense is not None:
        h = x_rep @ p.dense.w_in
        if p.dense.w_gate is not None:
            h = activation(act)(x_rep @ p.dense.w_gate) * h
        else:
            h = activation(act)(h)
        yd = h @ p.dense.w_out
        yd = jax.lax.psum(yd, dff_axes)
        yd = ctx.psum_model(yd)
        y = y + yd.astype(y.dtype)
    return y
