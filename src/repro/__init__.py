"""ClusterFusion reproduction package.

Importing the package installs the JAX version-compat shims
(:mod:`repro.compat`) so the rest of the codebase — and inline test
bodies — can target one API surface regardless of the pinned JAX.
"""
from repro import compat  # noqa: F401  (side effect: compat.install())
