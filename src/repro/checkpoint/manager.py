"""Sharded checkpointing with atomic commits, keep-k retention, async
save, and elastic re-shard on restore.

Format: one ``.npz``-style directory per step —
``step_000123/ leaf_00000.npy … manifest.json`` — with the pytree
structure and per-leaf metadata in the manifest.  Writes go to
``step_X.tmp`` and are atomically renamed (a crashed save never corrupts
the latest checkpoint; restart resumes from the last committed step).

Elastic restore: the data-parallel degree may change between runs.
Parameters are stored replicated-over-data (device-major over model), so
DP changes are free; ZeRO-sliced optimizer state is stored *gathered*
(full) and re-sliced by the new run's ranks.  Model-axis size is fixed
per layout (re-layout via ``models.transformer.to_device_major`` when it
must change — offline tool, see relayout()).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_BF16 = np.dtype(ml_dtypes.bfloat16)


def _is_engine_pair(d: dict) -> bool:
    """An engine-params pair from launch/serve.py: train/serve views of
    one weight tree (both dicts carrying the block lists)."""
    return (set(d) == {"train", "serve"}
            and all(isinstance(v, dict) and "blocks" in v and "tail" in v
                    for v in d.values()))


def strip_derived(tree: PyTree) -> PyTree:
    """Serving engines pair training-layout weights with a DERIVED
    prepacked decode layout (``{"train": …, "serve": …}`` —
    launch/serve.py).  Only the training layout is checkpointed; the
    serve layout is rebuilt from it at load time
    (``serving.prepack.prepack_for_serving``), so checkpoints round-trip
    training-layout weights untouched regardless of the serving plan.
    Recursive over dicts/lists/plain tuples, so an engine-params pair
    nested inside a larger snapshot (e.g. ``{"model": …, "opt": …}``)
    is stripped too.  Only dicts that actually LOOK like an engine pair
    (both entries are param trees with "blocks"/"tail") are collapsed —
    an unrelated ``{"train": …, "serve": …}`` metrics dict is left
    alone."""
    if isinstance(tree, dict):
        if _is_engine_pair(tree):
            return strip_derived(tree["train"])
        return {k: strip_derived(v) for k, v in tree.items()}
    if isinstance(tree, list):
        return [strip_derived(v) for v in tree]
    if type(tree) is tuple:                    # plain tuples only — named
        return tuple(strip_derived(v) for v in tree)   # tuples are leaves
    return tree


def _to_storable(arr: np.ndarray):
    """np.save can't represent bfloat16 — store as uint16 view + tag."""
    if arr.dtype == _BF16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _from_storable(arr: np.ndarray, dtype_tag: str) -> np.ndarray:
    if dtype_tag == "bfloat16":
        return arr.view(_BF16)
    return arr


def _leaf_paths(tree: PyTree) -> List[str]:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(p) for p in kp) for kp, _ in paths]


class CheckpointMismatch(RuntimeError):
    """A checkpoint failed restore-time validation (missing/corrupt leaf
    file, or shape/dtype drift vs the target tree).  The message names
    the first offending leaf path."""


def _dtype_tag(dt) -> str:
    """Canonical dtype name for validation (bfloat16-aware)."""
    return "bfloat16" if np.dtype(dt) == _BF16 else str(np.dtype(dt))


def _load_leaf(d: str, i: int, manifest: Dict, path: str) -> np.ndarray:
    """Load one leaf file, converting IO/parse failures into a
    :class:`CheckpointMismatch` that names the leaf — a truncated or
    bit-rotted checkpoint must fail loudly, never unflatten garbage."""
    fn = os.path.join(d, f"leaf_{i:05d}.npy")
    try:
        arr = np.load(fn)
    except FileNotFoundError:
        raise CheckpointMismatch(
            f"leaf {path!r} (index {i}): file {fn} is missing — "
            "truncated checkpoint") from None
    except (ValueError, OSError, EOFError) as e:
        raise CheckpointMismatch(
            f"leaf {path!r} (index {i}): file {fn} is unreadable "
            f"({e}) — corrupted checkpoint") from None
    return _from_storable(arr, manifest["leaves"][i]["dtype"])


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- discovery ----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        """Snapshot ``tree`` at ``step``.  Device→host transfer happens
        synchronously (consistent snapshot); disk IO is backgrounded.
        Derived serving state (prepacked decode layouts) is stripped —
        see :func:`strip_derived`."""
        tree = strip_derived(tree)
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(l) for l in leaves]      # sync copy
        if self._thread is not None:
            self._thread.join()                     # one in flight max

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "n_leaves": len(host),
                "extra": extra or {},
                "leaves": [],
            }
            for i, arr in enumerate(host):
                stor, tag = _to_storable(arr)
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), stor)
                manifest["leaves"].append(
                    {"shape": list(arr.shape), "dtype": tag})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                   # atomic commit
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for name in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)", name)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(self, like: PyTree, step: Optional[int] = None
                ) -> Tuple[PyTree, Dict]:
        """Restore into the structure of ``like`` (shapes AND dtypes must
        match leaf by leaf — same layout).  Returns (tree, extra).
        ``like`` is stripped of derived serving state the same way
        :meth:`save` strips the snapshot, so save/restore stay symmetric
        when handed an engine's ``{"train", "serve"}`` params pair.

        Validation is loud on purpose: a truncated directory, a corrupt
        leaf file, or a layout drift between the saving and restoring
        run raises :class:`CheckpointMismatch` naming the first offending
        leaf PATH (not just its flat index) — silently unflattening a
        wrong-shaped buffer into params is how garbage weights reach a
        serving fleet."""
        like = strip_derived(like)
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(like)
        paths = _leaf_paths(like)
        if manifest["n_leaves"] != len(leaves):
            raise CheckpointMismatch(
                f"checkpoint {d} holds {manifest['n_leaves']} leaves but "
                f"the target tree has {len(leaves)} — structure drift "
                "between the saving and restoring run")
        out = []
        for i, ref in enumerate(leaves):
            arr = _load_leaf(d, i, manifest, paths[i])
            if tuple(arr.shape) != tuple(ref.shape):
                raise CheckpointMismatch(
                    f"leaf {paths[i]!r} (index {i}) in {d}: stored shape "
                    f"{tuple(arr.shape)} != target shape "
                    f"{tuple(ref.shape)}")
            if _dtype_tag(arr.dtype) != _dtype_tag(ref.dtype):
                raise CheckpointMismatch(
                    f"leaf {paths[i]!r} (index {i}) in {d}: stored dtype "
                    f"{_dtype_tag(arr.dtype)} != target dtype "
                    f"{_dtype_tag(ref.dtype)}")
            out.append(arr)
        return treedef.unflatten(out), manifest.get("extra", {})

    def restore_elastic(self, like: PyTree, step: Optional[int] = None,
                        ) -> Tuple[PyTree, Dict]:
        """Restore allowing the *data-parallel* degree to change: leaves
        whose stored first-divisible axis differs by an integer factor are
        re-sliced/tiled (ZeRO state saved gathered ⇒ plain restore; this
        handles legacy per-rank saves and future re-shards)."""
        like = strip_derived(like)
        step = step if step is not None else self.latest_step()
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(like)
        paths = _leaf_paths(like)
        out = []
        for i, ref in enumerate(leaves):
            arr = _load_leaf(d, i, manifest, paths[i])
            if tuple(arr.shape) != tuple(ref.shape):
                arr = _reshard_leaf(arr, tuple(ref.shape))
            out.append(arr)
        return treedef.unflatten(out), manifest.get("extra", {})


def _reshard_leaf(arr: np.ndarray, target: Tuple[int, ...]) -> np.ndarray:
    """Best-effort axis-0 re-shard (DP elasticity)."""
    if arr.ndim != len(target):
        raise ValueError(f"rank mismatch {arr.shape} -> {target}")
    for ax, (a, t) in enumerate(zip(arr.shape, target)):
        if a == t:
            continue
        rest_ok = arr.shape[:ax] + arr.shape[ax + 1:] \
            == target[:ax] + target[ax + 1:]
        if not rest_ok:
            raise ValueError(f"cannot reshard {arr.shape} -> {target}")
        if a % t == 0 or t % a == 0:
            reps = [1] * arr.ndim
            if t > a:
                reps[ax] = t // a
                return np.tile(arr, reps)
            idx = [slice(None)] * arr.ndim
            idx[ax] = slice(0, t)
            return arr[tuple(idx)]
    raise ValueError(f"cannot reshard {arr.shape} -> {target}")
