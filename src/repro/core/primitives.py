"""Cluster-level collective primitives (paper Alg. 1 / Alg. 2), TPU edition.

The paper defines two collectives over Hopper DSMEM with a binary-tree
schedule: in round ``r`` (stride ``2**r``), block ``b`` sends to
``(b + stride) % N`` and receives from ``(b - stride + N) % N``;
``ClusterReduce`` applies ``⊕`` each round at constant message size, while
``ClusterGather`` doubles the message each round.

On TPU the "cluster" is a mesh sub-axis connected by ICI, and the per-round
exchange is a ``jax.lax.ppermute``.  The schedules below are *faithful* to
Alg. 1/2 — same ranks, same stride progression, same message growth — and
are validated against XLA-native ``psum`` / ``all_gather`` (the reference
path) in tests.

All functions must be called inside ``shard_map`` with ``axis_name`` bound.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import tracecount

PyTree = Any

# ---------------------------------------------------------------------------
# Logical sub-axes of a physical mesh axis
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SubAxis:
    """A logical sub-axis of a physical mesh axis.

    The production mesh exposes one ``model`` axis; the paper's dataflow
    needs it factored as ``heads × cluster`` (cluster minor).  A ``SubAxis``
    names the physical axis, its logical ``size``, and the product of the
    sizes of all sub-axes *minor* to it (``minor_size`` — the stride between
    consecutive logical ranks on the physical axis).  All collectives below
    accept either a plain axis name (whole axis) or a ``SubAxis``; for
    sub-axes the per-round exchange becomes a ``ppermute`` whose pairs only
    connect ranks within the same logical group — exactly the paper's
    "cluster" scoping of DSMEM traffic.
    """

    name: str
    size: int
    minor_size: int = 1

    def index(self) -> jax.Array:
        return (lax.axis_index(self.name) // self.minor_size) % self.size


Axis = Union[str, SubAxis]

# ---------------------------------------------------------------------------
# Reduction operators
# ---------------------------------------------------------------------------
_REDUCE_OPS: dict[str, Callable] = {
    "sum": lambda a, b: a + b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def _axis_size(axis: Axis) -> int:
    return axis.size if isinstance(axis, SubAxis) else lax.axis_size(axis)


def _axis_name(axis: Axis) -> str:
    return axis.name if isinstance(axis, SubAxis) else axis


def axis_index(axis: Axis) -> jax.Array:
    return axis.index() if isinstance(axis, SubAxis) else lax.axis_index(axis)


def _ring_perm(axis: Axis, stride: int) -> list[Tuple[int, int]]:
    """Paper's send pattern: rank b sends to (b + stride) mod N.

    For a ``SubAxis`` the permutation is generated over the *physical* axis
    but only pairs ranks within the same logical group.
    """
    if not isinstance(axis, SubAxis):
        n = lax.axis_size(axis)
        return [(b, (b + stride) % n) for b in range(n)]
    n, ms = axis.size, axis.minor_size
    phys = lax.axis_size(axis.name)
    perm = []
    for r in range(phys):
        b = (r // ms) % n
        peer_b = (b + stride) % n
        perm.append((r, r + (peer_b - b) * ms))
    return perm


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# ClusterReduce — Alg. 1
# ---------------------------------------------------------------------------
def cluster_reduce(x: PyTree, axis_name: Axis, op: str | Callable = "sum") -> PyTree:
    """All-reduce ``x`` over ``axis_name`` with the paper's tree schedule.

    ``log2(N)`` rounds; message size constant (= size of ``x``); after the
    last round every rank holds the full reduction (ring-ordered, so the
    result is exact for associative+commutative ops and deterministic —
    identical summation order on every rank — for plain associative ops).
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    if not _is_pow2(n):
        raise ValueError(f"cluster axis size must be 2**k (paper Alg. 1); got {n}")
    tracecount.bump("tree_reduce")
    fn = _REDUCE_OPS[op] if isinstance(op, str) else op
    phys = _axis_name(axis_name)

    def reduce_leaf(leaf):
        d = leaf
        stride = 1
        while stride < n:                      # log2(N) rounds
            recv = lax.ppermute(d, phys, perm=_ring_perm(axis_name, stride))
            d = fn(d, recv)                    # D_b <- D_b ⊕ B_b
            stride *= 2                        # exponential stride
        return d

    return jax.tree.map(reduce_leaf, x)


def cluster_reduce_pairs(x: PyTree, axis_name: Axis,
                         merge: Callable[[PyTree, PyTree], PyTree]) -> PyTree:
    """ClusterReduce with a *structured* operator ``merge(mine, theirs)``.

    Used for the fused flash-decoding combine (online-softmax merge is an
    associative operator over (m, l, o) triples) — a beyond-paper variant
    that replaces the paper's two back-to-back ClusterReduce calls (stats,
    then outputs) with a single tree, halving the number of rounds.
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    if not _is_pow2(n):
        raise ValueError(f"cluster axis size must be 2**k; got {n}")
    tracecount.bump("tree_reduce")
    phys = _axis_name(axis_name)
    d = x
    stride = 1
    while stride < n:
        recv = jax.tree.map(
            lambda leaf: lax.ppermute(leaf, phys, perm=_ring_perm(axis_name, stride)), d)
        d = merge(d, recv)
        stride *= 2
    return d


# ---------------------------------------------------------------------------
# ClusterGather — Alg. 2
# ---------------------------------------------------------------------------
def cluster_gather(x: jax.Array, axis_name: Axis) -> jax.Array:
    """All-gather ``x`` over ``axis_name`` with the paper's tree schedule.

    Message size doubles every round (round r moves ``size * 2**r``); after
    ``log2(N)`` rounds every rank holds all N segments.  The paper's buffer
    fills in *reverse ring order* ``[b, b-1, ..., b-N+1]``; we restore the
    canonical ``[0..N-1]`` order with a rank-dependent gather so the result
    matches ``jax.lax.all_gather`` (stacked along a new leading axis).
    """
    n = _axis_size(axis_name)
    if n == 1:
        return jnp.expand_dims(x, 0)
    if not _is_pow2(n):
        raise ValueError(f"cluster axis size must be 2**k (paper Alg. 2); got {n}")
    tracecount.bump("tree_gather")

    phys = _axis_name(axis_name)
    # D_b[0] = local segment
    buf = jnp.expand_dims(x, 0)                        # [segments, ...]
    stride = 1
    while stride < n:
        # send D_b[0 : stride] -> peer's D[stride : 2*stride]
        recv = lax.ppermute(buf[:stride], phys, perm=_ring_perm(axis_name, stride))
        buf = jnp.concatenate([buf, recv], axis=0)
        stride *= 2
    # buf[i] = segment of rank (b - i) mod N; restore canonical order:
    # out[j] = buf[(b - j) mod N]
    b = axis_index(axis_name)
    idx = (b - jnp.arange(n)) % n
    return jnp.take(buf, idx, axis=0)


def cluster_gather_tiled(x: jax.Array, axis_name: Axis, axis: int = 0) -> jax.Array:
    """``cluster_gather`` concatenating segments along ``axis``."""
    n = _axis_size(axis_name)
    if n == 1:
        return x
    out = cluster_gather(x, axis_name)                   # [N, ...]
    out = jnp.moveaxis(out, 0, axis)                     # segments at `axis`
    new_shape = x.shape[:axis] + (n * x.shape[axis],) + x.shape[axis + 1:]
    return out.reshape(new_shape)


# ---------------------------------------------------------------------------
# XLA-native reference path (used for validation and as a fallback)
# ---------------------------------------------------------------------------
def cluster_reduce_xla(x: PyTree, axis_name: str, op: str = "sum") -> PyTree:
    if op == "sum":
        return lax.psum(x, axis_name)
    if op == "max":
        return jax.tree.map(lambda l: lax.pmax(l, axis_name), x)
    if op == "min":
        return jax.tree.map(lambda l: lax.pmin(l, axis_name), x)
    raise ValueError(op)


def cluster_gather_xla(x: jax.Array, axis_name: str, axis: int = 0,
                       tiled: bool = True) -> jax.Array:
    return lax.all_gather(x, axis_name, axis=axis, tiled=tiled)


# ---------------------------------------------------------------------------
# "Off-chip" emulation (ablation: paper Fig. 13 / Table 1 'without DSMEM')
# ---------------------------------------------------------------------------
def offchip_reduce(x: jax.Array, axis_name: str, op: str = "sum") -> jax.Array:
    """The global-memory pattern the paper ablates against: every rank
    materializes *all* N buffers (an all-gather of the full tensor — the
    moral equivalent of writing partials to HBM and re-reading all of them),
    then reduces locally.  Traffic: ``size * N`` per rank vs the tree's
    ``size * log2 N``."""
    n = _axis_size(axis_name)
    allbuf = lax.all_gather(x, axis_name, axis=0, tiled=False)   # [N, ...]
    if op == "sum":
        return jnp.sum(allbuf, axis=0)
    if op == "max":
        return jnp.max(allbuf, axis=0)
    raise ValueError(op)


# ---------------------------------------------------------------------------
# DSMEM-traffic analytical model (paper §3.2) — exact formulas
# ---------------------------------------------------------------------------
def traffic_reduce(size: float, n: int) -> float:
    """``Traffic_Reduce(size, N) = size · log2(N) · N`` (bytes·hops over the
    cluster fabric; constant message size, log2 N rounds, N ranks)."""
    if n <= 1:
        return 0.0
    return float(size) * math.log2(n) * n


def traffic_gather(size: float, n: int) -> float:
    """``Traffic_Gather(size, N) = size · (2^(log2(N/2)+1) − 1) · N``
    — message doubles each round: size·(1+2+…+N/2) = size·(N−1) per rank."""
    if n <= 1:
        return 0.0
    return float(size) * (2 ** (math.log2(n / 2) + 1) - 1) * n


# ---------------------------------------------------------------------------
# Online-softmax (FlashDecoding) combine — associative merge over (m, l, o)
# ---------------------------------------------------------------------------
def flash_merge(a: Tuple[jax.Array, jax.Array, jax.Array],
                b: Tuple[jax.Array, jax.Array, jax.Array]):
    """Merge two flash-attention partials.

    Each partial is ``(m, l, o)`` with ``m`` the running max of logits,
    ``l = Σ exp(s − m)`` and ``o = Σ exp(s − m) · v`` (unnormalized).
    Associative and commutative ⇒ valid ClusterReduce operator.
    """
    m_a, l_a, o_a = a
    m_b, l_b, o_b = b
    m = jnp.maximum(m_a, m_b)
    ca = jnp.exp(m_a - m)
    cb = jnp.exp(m_b - m)
    l = l_a * ca + l_b * cb
    o = o_a * ca[..., None] + o_b * cb[..., None]
    return m, l, o


def cluster_flash_combine(m: jax.Array, l: jax.Array, o: jax.Array,
                          axis_name: Axis, *, fused: bool = True):
    """Combine per-rank FlashDecoding partials across a cluster axis.

    ``fused=True``: single ClusterReduce tree with the flash-merge operator
    (beyond-paper; half the rounds / one traffic pass).
    ``fused=False``: the paper-faithful Alg. 3 sequence — ClusterReduce the
    softmax stats (max for m, sum for rescaled l), rescale locally, then
    ClusterReduce the rescaled outputs.
    """
    if fused:
        return cluster_reduce_pairs((m, l, o), axis_name,
                                    lambda x, y: flash_merge(x, y))
    # Paper Alg. 3, lines 5–7:
    g_max = cluster_reduce(m, axis_name, "max")           # S_max
    scale = jnp.exp(m - g_max)                            # exp(Reg_max − S_max)
    l_scaled = l * scale
    g_sum = cluster_reduce(l_scaled, axis_name, "sum")    # S_sum
    o_scaled = o * scale[..., None]
    o_sum = cluster_reduce(o_scaled, axis_name, "sum")    # ClusterReduce(A_b)
    return g_max, g_sum, o_sum
