"""Cluster-centric fused decode dataflows (paper §3.2, Alg. 3/4/5).

These functions run *inside* ``shard_map`` and implement the paper's
dataflows with the cluster collectives from :mod:`repro.core.primitives`.
The physical ``model`` mesh axis is factored into two logical sub-axes:

* ``heads`` — partitions (grouped) attention heads across head-groups;
  independent work, combined only by the Output-Projection reduction
  (the paper's ``atomicAdd`` across clusters).
* ``cluster`` — the paper's thread-block cluster: N ranks that cooperate
  on ONE head-group via ClusterGather / ClusterReduce.

Dataflows:

* :func:`split_token_attention` — paper Alg. 3 ("SplitToken", the main
  dataflow): head-dim partitioned QKV-Projection → ClusterGather; KV-cache
  *sequence* partitioned FlashDecoding → ClusterReduce of softmax stats and
  partial outputs; output-dim partitioned Output-Projection.
* :func:`split_head_attention` — paper Alg. 5 (App. B.2): head-dim
  partitioned everywhere; reduces the full score vector (traffic ∝ S) —
  implemented for the paper's dataflow-comparison experiments.
* :func:`mla_attention` — paper Alg. 4 (App. B.1): fused weight-absorbed
  DeepSeek MLA decode.

All three keep every intermediate inside the shard_map body — under jit
the whole fused block lowers to one XLA computation with only the
cluster collectives between stages, i.e. the TPU analogue of the paper's
single fused kernel (see DESIGN.md §2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import primitives as prim
from repro.core import tracecount
from repro.core.primitives import Axis, SubAxis


# ---------------------------------------------------------------------------
# Cluster specification
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ClusterSpec:
    """How the model axis is factored for the cluster-centric dataflow."""

    heads: Axis                  # head-group sub-axis (size H)
    cluster: Axis                # intra-head cluster sub-axis (size N)
    fused_combine: bool = False  # beyond-paper single-tree flash merge;
                                 # applies to the adapter paths only — the
                                 # prepacked partial_o paths ALWAYS use the
                                 # single-tree (m, l, o) merge, which is
                                 # constitutive of their one-ClusterReduce
                                 # contract, not an option
    use_xla: bool = False        # XLA-native collectives (reference path)
    # -- local-stage compute backend (DESIGN.md §2) ------------------------
    backend: str = "xla"         # "xla" | "pallas": QKV-proj + RoPE + flash
                                 # partial as XLA ops vs ONE fused Pallas
                                 # kernel per rank (collectives in between)
    interpret: bool = False      # Pallas interpret mode (CPU tests)
    block_s: int = 256           # KV block granularity for the attention
                                 # inner loop (both backends)

    @property
    def n_cluster(self) -> int:
        return prim._axis_size(self.cluster)

    @property
    def n_heads_axis(self) -> int:
        return prim._axis_size(self.heads)

    # -- collective dispatch (faithful tree vs XLA-native reference) -------
    def reduce(self, x, op="sum"):
        if self.use_xla and not isinstance(self.cluster, SubAxis):
            return prim.cluster_reduce_xla(x, self.cluster, op)
        return prim.cluster_reduce(x, self.cluster, op)

    def gather_tiled(self, x, axis):
        if self.use_xla and not isinstance(self.cluster, SubAxis):
            return lax.all_gather(x, self.cluster, axis=axis, tiled=True)
        return prim.cluster_gather_tiled(x, self.cluster, axis=axis)

    def heads_reduce(self, x):
        if self.use_xla and not isinstance(self.heads, SubAxis):
            return lax.psum(x, self.heads)
        return prim.cluster_reduce(x, self.heads, "sum")

    def flash_combine(self, m, l, o):
        return prim.cluster_flash_combine(m, l, o, self.cluster,
                                          fused=self.fused_combine)


# ---------------------------------------------------------------------------
# KV cache block (per layer, per shard)
# ---------------------------------------------------------------------------
class KVBlock(NamedTuple):
    """One rank's slice of a layer's KV cache.

    ``k``/``v``: [S_blk, kv_heads_local, head_dim] — *sequence*-partitioned
    across the cluster (SplitToken / MLA) or *head-dim*-partitioned
    (SplitHead).  ``pos``: [S_blk] int32 global position of each slot
    (−1 ⇒ empty); storing positions makes full, sliding-window and ring
    caches uniform and keeps masking exact after wrap-around.
    """

    k: jax.Array
    v: jax.Array
    pos: jax.Array


def init_kv_block(s_blk: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVBlock:
    return KVBlock(
        k=jnp.zeros((s_blk, kv_heads, head_dim), dtype),
        v=jnp.zeros((s_blk, kv_heads, head_dim), dtype),
        pos=jnp.full((s_blk,), -1, jnp.int32),
    )


def _insert_kv(cache: KVBlock, k_new: jax.Array, v_new: jax.Array,
               slot_owner: jax.Array, local_slot: jax.Array,
               my_rank: jax.Array, position: jax.Array) -> KVBlock:
    """Predicated insert: only the owning cluster rank writes the new KV.

    ``k_new``/``v_new``: [kv_heads_local, head_dim] (batch handled by vmap
    or by the batch=1-per-step decode convention of the caller).
    """
    own = (slot_owner == my_rank)
    idx = jnp.clip(local_slot, 0, cache.k.shape[0] - 1)
    cur_k = lax.dynamic_slice_in_dim(cache.k, idx, 1, axis=0)
    cur_v = lax.dynamic_slice_in_dim(cache.v, idx, 1, axis=0)
    cur_p = lax.dynamic_slice_in_dim(cache.pos, idx, 1, axis=0)
    new_k = jnp.where(own, k_new[None].astype(cache.k.dtype), cur_k)
    new_v = jnp.where(own, v_new[None].astype(cache.v.dtype), cur_v)
    new_p = jnp.where(own, position[None].astype(jnp.int32), cur_p)
    return KVBlock(
        k=lax.dynamic_update_slice_in_dim(cache.k, new_k, idx, axis=0),
        v=lax.dynamic_update_slice_in_dim(cache.v, new_v, idx, axis=0),
        pos=lax.dynamic_update_slice_in_dim(cache.pos, new_p, idx, axis=0),
    )


def _insert_kv_ragged(cache: KVBlock, k_new: jax.Array, v_new: jax.Array,
                      slot_owner: jax.Array, local_slot: jax.Array,
                      my_rank: jax.Array, position: jax.Array) -> KVBlock:
    """Per-slot predicated insert for ragged decode.

    ``k_new``/``v_new``: [B, …] one new entry per batch slot;
    ``slot_owner``/``local_slot``/``position``: [B].  ``cache.k``/``v``
    carry the batch at axis 1 after a ``[S, B, -1]`` view (the GQA
    layout folds kv-heads into that view's trailing dim), ``cache.pos``
    is [S, B].  A slot only writes when (a) this rank owns its append
    slot and (b) the slot is ACTIVE (``position >= 0`` — retired /
    free scheduler slots carry ``cache_len = −1`` and must leave the
    cache untouched, ring wrap would otherwise alias them onto a live
    owner).
    """
    S = cache.k.shape[0]
    B = position.shape[0]
    own = (slot_owner == my_rank) & (position >= 0)
    idx = jnp.clip(local_slot, 0, S - 1)
    b = jnp.arange(B)

    def upd(full, new):
        f3 = full.reshape(S, B, -1)
        n2 = new.reshape(B, -1).astype(full.dtype)
        put = jnp.where(own[:, None], n2, f3[idx, b])
        return f3.at[idx, b].set(put).reshape(full.shape)

    new_p = jnp.where(own, position.astype(jnp.int32), cache.pos[idx, b])
    return KVBlock(k=upd(cache.k, k_new), v=upd(cache.v, v_new),
                   pos=cache.pos.at[idx, b].set(new_p))


def _apply_rope(x: jax.Array, position: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding at ``position`` — a scalar (lockstep decode) or a
    per-slot ``[B]`` vector (ragged decode; x leads with the batch dim).
    x: [..., head_dim]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.asarray(position, jnp.float32)
    ang = pos[..., None] * freqs                 # [half] or [B, half]
    if pos.ndim:                                 # [B, 1, …, 1, half]
        ang = ang.reshape(ang.shape[:1] + (1,) * (x.ndim - 2) + (half,))
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def _fit_block_s(S: int, block_s: int) -> int:
    """Largest divisor of ``S`` that is ≤ ``block_s``.

    Keeps bucketing alive when the tuned block doesn't divide the local
    cache (e.g. s_blk = 320 with block_s = 256 ⇒ 160), instead of
    silently collapsing to one full-cache bucket.  Falls back to ``S``
    only when the best divisor is degenerately small (> 8× shrink —
    near-prime lengths), where per-bucket overhead would exceed the
    skipped work.
    """
    b = min(block_s, S)
    while b > 1 and S % b:
        b -= 1
    return b if b * 8 > min(block_s, S) else S


class _AppendSlot(NamedTuple):
    """Where this decode step's new KV entry lands on the cluster-sharded
    cache, plus the kernel gating derived from it.  With a per-slot
    ``cache_lens [B]`` (ragged decode) ``owner``/``local_slot``/
    ``include_new`` are [B] vectors; ``rank``/``pos_base`` stay scalar."""

    rank: jax.Array          # this rank's cluster index
    owner: jax.Array         # cluster rank owning the append slot
    local_slot: jax.Array    # slot within the owner's shard
    include_new: jax.Array   # 1 iff this rank owns the slot (the new
                             # token is counted exactly once per cluster)
    pos_base: jax.Array      # pos[i] = pos_base + i when the shard is
                             # position-linear; −1 ⇒ masked path


def _append_slot(spec: ClusterSpec, s_blk: int, cache_len,
                 *, window: int = 0) -> _AppendSlot:
    """THE slot/owner/gating formula, shared by every dataflow path.

    Sliding-window layers use a ring of ``n·s_blk`` slots (the slot
    index wraps, so offsets stop being positions ⇒ ``pos_base = −1``
    forces the stored-pos masked path and forbids offset culling);
    linear caches fill in position order (``pos_base = rank·s_blk``
    enables the mask-free fast path and rank-local live-span culling).
    One definition on purpose: this formula is where the ring-wrap and
    owner-gating hardening landed, and a divergent copy is a silent
    cross-backend mismatch.

    Elementwise over ``cache_len``, so a per-slot ``cache_lens [B]``
    vector yields per-slot owners/gates.  INACTIVE slots (scheduler
    convention: ``cache_len = −1``) never own their append slot — the
    ring modulus would otherwise map −1 onto the last live ring slot
    and a real rank would overwrite it.
    """
    n = spec.n_cluster
    rank = prim.axis_index(spec.cluster)
    cache_len = jnp.asarray(cache_len, jnp.int32)
    slot = cache_len % (n * s_blk) if window > 0 else cache_len
    owner, local_slot = slot // s_blk, slot % s_blk
    include_new = ((owner == rank) & (cache_len >= 0)).astype(jnp.int32)
    if window > 0:
        pos_base = jnp.int32(-1)
    else:
        pos_base = (rank * s_blk).astype(jnp.int32)
    return _AppendSlot(rank, owner, local_slot, include_new, pos_base)


def bucketed_flash_attention(qf: jax.Array, kc: jax.Array, vc: jax.Array,
                             valid: jax.Array, *, scale: float,
                             softcap: float = 0.0, block_s: int = 256):
    """Online-softmax attention over **live** KV blocks only.

    The seed dataflow attended over the entire allocated cache every step
    (masked), so decode FLOPs/bytes scaled with ``max_seq``.  Here the
    local sequence axis is cut into ``block_s``-sized buckets and each
    bucket runs under a ``lax.cond`` on its liveness (any valid slot) —
    dead buckets (beyond the live prefix, or wholly outside a sliding
    window) are skipped at runtime, making per-step cost proportional to
    ``cache_len`` (DESIGN.md §3).  Per-bucket partials merge with the
    usual flash rescale, so the result equals the single masked pass.

    ``qf [B,K,Q,hd]``, ``kc/vc [S,B,K,hd]`` (``vc``'s trailing dim may
    differ — MLA latent values), ``valid [S]`` bool — or ``[S, B]`` for
    ragged decode (per-slot live spans; a bucket runs when ANY slot has
    a live entry in it, and each slot sees only its own mask).  Returns
    ``(m, l, o, blocks_run)`` with the ``-1e30``-masked ``m`` convention
    of :func:`repro.core.primitives.cluster_flash_combine`;
    ``blocks_run`` counts executed buckets (proportionality evidence in
    tests; dead code under ``jit`` when unused).
    """
    S = kc.shape[0]
    ab = _fit_block_s(S, block_s)
    nb = S // ab
    B, K, Q = qf.shape[0], qf.shape[1], qf.shape[2]
    hd_v = vc.shape[-1]
    init = (jnp.full((B, K, Q), -1e30, jnp.float32),
            jnp.zeros((B, K, Q), jnp.float32),
            jnp.zeros((B, K, Q, hd_v), jnp.float32),
            jnp.int32(0))

    def bucket_mask(bv):
        if bv.ndim == 2:                         # ragged: [ab, B] per-slot
            return jnp.moveaxis(bv, 0, 1)[:, None, None, :]   # [B,1,1,ab]
        return bv[None, None, None, :]

    def body(i, carry):
        start = i * ab
        bv = lax.dynamic_slice_in_dim(valid, start, ab)

        def live(c):
            m, l, o, cnt = c
            kb = lax.dynamic_slice_in_dim(kc, start, ab, axis=0)
            vb = lax.dynamic_slice_in_dim(vc, start, ab, axis=0)
            s = jnp.einsum("bkqh,sbkh->bkqs", qf, kb,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            s = jnp.where(bucket_mask(bv), s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(bucket_mask(bv), p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bkqs,sbkh->bkqh", p.astype(vc.dtype), vb,
                preferred_element_type=jnp.float32)
            return m_new, l_new, o_new, cnt + 1

        return lax.cond(jnp.any(bv), live, lambda c: c, carry)

    return lax.fori_loop(0, nb, body, init)


# ---------------------------------------------------------------------------
# Paper Alg. 3 — SplitToken dataflow (the main contribution)
# ---------------------------------------------------------------------------
class SplitTokenWeights(NamedTuple):
    """Per-(heads-rank, cluster-rank) weight shards for Alg. 3.

    ``wq``  [D, q_local, hd/N]  — head-dim segment of the Q projection
    ``wk``  [D, kv_local, hd/N]
    ``wv``  [D, kv_local, hd/N]
    ``bq``/``bk``/``bv`` optional bias segments (Qwen-2), same trailing dims
    ``wo``  [q_local*hd, D/N]   — output-dim segment of the O projection
    """

    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    bq: Optional[jax.Array] = None
    bk: Optional[jax.Array] = None
    bv: Optional[jax.Array] = None


class PackedSplitTokenWeights(NamedTuple):
    """Serve-layout (prepacked) per-rank weights for the fully fused
    Pallas SplitToken path (serving/prepack.py, DESIGN.md §2).

    Materialized ONCE at weight-load time — the decode step performs no
    weight-segment ClusterGather and no ``dynamic_slice`` weight slicing.

    ``wqkv`` [D, (q_loc + 2·kv_loc)·hd] — cluster-gathered q/k/v head-dim
              segments concatenated so the kernel runs ONE projection
              matmul (replicated over the cluster sub-axis).
    ``wo``   [q_loc, hd, D] — full-width Output-Projection rows of this
              rank's heads, per-head, consumed by ``fuse_out="partial_o"``.
              Full width keeps every cluster rank's in-kernel partial in
              the SAME output basis, so the flash merge sums them exactly
              and no post-combine cluster gather remains.
    ``bqkv`` [(q_loc + 2·kv_loc)·hd] fused bias, or None.
    ``ln1``  [D] pre-attention RMSNorm scale, fused into the kernel's
             projection phase (the raw residual stream crosses HBM, the
             normed copy exists only in VMEM — DESIGN.md §7); None keeps
             the legacy caller-normalizes contract.
    """

    wqkv: jax.Array
    wo: jax.Array
    bqkv: Optional[jax.Array] = None
    ln1: Optional[jax.Array] = None


class PackedMLAWeights(NamedTuple):
    """Serve-layout (prepacked) per-rank weights for the fully fused
    Pallas MLA path (serving/prepack.py).

    ``wq``    [D, q_loc·(nope+rope)] — cluster-gathered Q projection.
    ``wdkv``  [D, l_rank+rope]       — cluster-gathered latent Down-Proj.
    ``wuk``   [q_loc, nope, l_rank]  — K-up absorption (full latent).
    ``wproj`` [q_loc, l_rank, D]     — fused W_UV·W_O rows: value
              Up-Projection and Output-Projection folded into one
              full-width per-head matrix at load time, extending the
              paper's weight-absorption trick one stage further (and
              keeping all cluster partials in one output basis).
    """

    wq: jax.Array
    wdkv: jax.Array
    wuk: jax.Array
    wproj: jax.Array
    # [D] fused pre-attention RMSNorm scale (None = caller normalizes)
    ln1: Optional[jax.Array] = None


class PackedFFNWeights(NamedTuple):
    """Serve-layout dense-FFN bundle for the fused block-tail megakernel
    (kernels/fused_ffn, DESIGN.md §7).

    The Megatron training layout is ALREADY the serve layout — gate/up
    column tiles ``[D, F_loc]`` and full-width down rows ``[F_loc, D]``
    (one output basis per rank, so down-projection partials sum exactly
    under one fused ClusterReduce — the same invariant as
    :class:`PackedSplitTokenWeights`.wo) — so the pack is pure aliasing:
    every weight field references the training tree's buffer, and only
    the fused norm scales ride along.  Zero extra HBM residency.

    ``w_in``  [D, F_loc] up columns · ``w_gate`` [D, F_loc] or None ·
    ``w_out`` [F_loc, D] full-width down rows · ``ln2`` [D] pre-FFN
    norm scale · ``post_ln1`` [D] post-attention norm scale (Gemma-2
    sandwich) or None.
    """

    w_in: jax.Array
    w_out: jax.Array
    ln2: jax.Array
    w_gate: Optional[jax.Array] = None
    post_ln1: Optional[jax.Array] = None


class PackedHeadWeights(NamedTuple):
    """Serve-layout LM-head/sampling-tail bundle for the fused head
    kernel (kernels/fused_head, DESIGN.md §7).

    PURE aliasing, like :class:`PackedFFNWeights`: ``table`` IS the
    training tree's vocab-sharded ``embed`` buffer (``tie_embeddings``)
    or ``lm_head`` buffer, and ``ln`` IS the ``final_norm`` scale — the
    bundle binds them for the fused tail without materializing a byte
    (``serving/prepack.py:bundle_head`` runs outside the jitted
    attention pack).  The kernel streams ``[block_v, D]`` tiles of
    ``table``, normalizes the raw residual stream in VMEM, and emits
    only per-slot ``(max, argmax)`` greedy partials — the ``[B, V]``
    logits never touch HBM.

    ``table`` [V_loc, D] vocab shard · ``ln`` [D] final RMSNorm scale.
    """

    table: jax.Array
    ln: jax.Array


def split_token_attention(
    spec: ClusterSpec,
    x: jax.Array,                 # [B, D] full hidden states (paper: every
                                  # block reads the entire input)
    w: SplitTokenWeights,
    cache: KVBlock,               # sequence-partitioned across the cluster
    cache_len: jax.Array,         # tokens already in the cache (scalar int32)
    *,
    window: int = 0,              # >0 => sliding-window (ring) cache
    attn_softcap: float = 0.0,
    rope_theta: float = 10000.0,
    scale: Optional[float] = None,
    norm_eps: float = 1e-6,       # fused pre-attention RMSNorm eps (packed
                                  # serve layout with ``ln1`` only)
) -> Tuple[jax.Array, KVBlock]:
    """One decode step of fused QKV-Projection → Attention → Output-Projection.

    Returns ``(o_segment [B, D/N], updated cache)``; the output is
    partitioned over the cluster axis along the model dim (the paper's
    atomicAdd tile).  Callers gather with ``spec.gather_tiled`` when the
    next op needs the full hidden vector.

    ``spec.backend`` selects the local-stage compute: ``"xla"`` runs the
    stages as XLA ops (block-bucketed attention over the live prefix);
    ``"pallas"`` fuses QKV-Projection + RoPE + flash partial into one
    Pallas kernel per rank (:mod:`repro.kernels.fused_decode`) with the
    ClusterGather/ClusterReduce collectives kept between kernel
    invocations — the paper's Level-2 fusion on TPU (DESIGN.md §2).

    ``w`` may also be :class:`PackedSplitTokenWeights` (the serve layout
    from serving/prepack.py): the local stage then runs the fully fused
    ``fuse_out="partial_o"`` kernel with NO per-step weight movement —
    one kernel + one fused ClusterReduce per layer — and the return is
    the FULL ``[B, D]`` output (no cluster gather needed).

    **Ragged decode**: ``cache_len`` may be a per-slot ``[B]`` vector
    (with ``cache.pos`` then ``[S_blk, B]``) — every sequence in the
    batch advances independently (RoPE position, append slot, live-span
    masking and the Pallas index-map clamp are all per-slot; inactive
    slots carry ``cache_len = −1`` and do no work).  A scalar
    ``cache_len`` with 1-D ``pos`` keeps the lockstep semantics.
    """
    if isinstance(w, PackedSplitTokenWeights):
        assert spec.backend == "pallas", \
            "prepacked serve-layout weights require backend='pallas'"
        return _split_token_attention_pallas_packed(
            spec, x, w, cache, cache_len, window=window,
            attn_softcap=attn_softcap, rope_theta=rope_theta, scale=scale,
            norm_eps=norm_eps)
    if spec.backend == "pallas":
        return _split_token_attention_pallas(
            spec, x, w, cache, cache_len, window=window,
            attn_softcap=attn_softcap, rope_theta=rope_theta, scale=scale)
    n = spec.n_cluster
    B = x.shape[0]
    q_local, hd_n = w.wq.shape[1], w.wq.shape[2]
    kv_local = w.wk.shape[1]
    hd = hd_n * n
    qpk = q_local // kv_local
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # (1) Segment results of QKV Projection (paper Alg. 3 line 2).
    q_seg = jnp.einsum("bd,dqh->bqh", x, w.wq)
    k_seg = jnp.einsum("bd,dkh->bkh", x, w.wk)
    v_seg = jnp.einsum("bd,dkh->bkh", x, w.wv)
    if w.bq is not None:
        q_seg = q_seg + w.bq
        k_seg = k_seg + w.bk
        v_seg = v_seg + w.bv

    # (2) ClusterGather the complete q/k/v (line 3).
    q = spec.gather_tiled(q_seg, axis=2)       # [B, q_local, hd]
    k = spec.gather_tiled(k_seg, axis=2)       # [B, kv_local, hd]
    v = spec.gather_tiled(v_seg, axis=2)

    # RoPE needs the complete head vector (rotates across the halves), so it
    # runs post-gather; position = cache_len.
    q = _apply_rope(q, cache_len, rope_theta)
    k = _apply_rope(k, cache_len, rope_theta)

    # (3) Append new KV to the owning rank's cache block.  Sliding-window
    # layers use a ring cache of exactly `window` slots (sharded over the
    # cluster), so the slot index wraps (shared formula: _append_slot).
    s_blk = cache.k.shape[0]
    ragged = jnp.ndim(cache_len) == 1
    ap = _append_slot(spec, s_blk, cache_len, window=window)
    # decode convention: one new token per sequence; B folded into kv head
    # dim via vmap at the serving layer when B > 1 shares a cache.  Here the
    # cache carries B in its kv_heads axis layout: [S, B*kv_local, hd].
    if ragged:
        cache = _insert_kv_ragged(cache, k, v, ap.owner, ap.local_slot,
                                  ap.rank, cache_len)
    else:
        cache = _insert_kv(
            cache,
            k.reshape(B * kv_local, hd), v.reshape(B * kv_local, hd),
            ap.owner, ap.local_slot, ap.rank, cache_len)

    # (4) FlashDecoding partial over the local sequence block (line 4),
    # bucketed so only live blocks execute (cost ∝ cache_len, not S_blk).
    # Scores/outputs accumulate in f32 via preferred_element_type — the
    # bf16 cache is NEVER materialized as an f32 copy (§Perf iter 1: this
    # halves decode HBM bytes vs casting the cache).
    kc = cache.k.reshape(s_blk, B, kv_local, hd)
    vc = cache.v.reshape(s_blk, B, kv_local, hd)
    qf = q.reshape(B, kv_local, qpk, hd).astype(kc.dtype)
    valid = cache.pos >= 0
    valid &= cache.pos <= cache_len
    if window > 0:
        valid &= cache.pos > cache_len - window
    m_safe, l, o, _ = bucketed_flash_attention(
        qf, kc, vc, valid, scale=scale, softcap=attn_softcap,
        block_s=spec.block_s)

    # (5)–(7) ClusterReduce softmax stats, rescale, ClusterReduce outputs.
    _, l_g, o_g = spec.flash_combine(m_safe, l, o)
    att = (o_g / jnp.maximum(l_g[..., None], 1e-30))
    att = att.reshape(B, q_local * hd).astype(x.dtype)

    # (8) Output-Projection tile + cross-cluster (heads) reduction — the
    # paper writes with atomicAdd; on TPU this is the heads-axis tree sum.
    o_seg = att @ w.wo                                        # [B, D/N]
    o_seg = spec.heads_reduce(o_seg)
    return o_seg, cache


def _split_token_attention_pallas(
    spec: ClusterSpec,
    x: jax.Array,
    w: SplitTokenWeights,
    cache: KVBlock,
    cache_len: jax.Array,
    *,
    window: int,
    attn_softcap: float,
    rope_theta: float,
    scale: Optional[float],
) -> Tuple[jax.Array, KVBlock]:
    """SplitToken with the local stage as ONE fused Pallas kernel per rank.

    The paper's Alg. 3 gathers q/k/v *activation* segments across the
    cluster between projection and attention; a Pallas kernel cannot host
    an ICI collective mid-kernel, so the gather is hoisted to the head-dim
    *weight* segments (``q = x·gather(Wq) == gather(x·Wq)``) and the whole
    local stage — QKV projection, RoPE, FlashDecoding partial over this
    rank's KV-sequence shard — runs inside
    :func:`repro.kernels.fused_decode.fused_decode_attention`
    (``fuse_out=False``).  The ClusterReduce flash combine, the
    Output-Projection tile and the heads reduction stay between kernel
    invocations, exactly the Level-2 schedule (DESIGN.md §2).

    Behavior-parity with the XLA path: stored-position masking (ring /
    sliding-window caches), softcap, GQA bias; the new token's own
    attention contribution is counted once — by the rank owning the
    append slot (``include_new``).
    """
    n = spec.n_cluster
    B, D = x.shape
    q_local, hd_n = w.wq.shape[1], w.wq.shape[2]
    kv_local = w.wk.shape[1]
    hd = hd_n * n
    qpk = q_local // kv_local
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    from repro.kernels.fused_decode.fused_decode import fused_decode_attention
    from repro.kernels.fused_decode.ops import rope_at

    # ClusterGather the head-dim weight segments (Alg. 3 line 3, hoisted
    # from activations to weights so the local stage fuses into one kernel).
    # Step-invariant — the prepacked serve layout does this once at load
    # time instead (serving/prepack.py); this adapter path remains for
    # training-layout serving and parity tests.
    tracecount.bump("weight_gather", 3)
    wq = spec.gather_tiled(w.wq, axis=2)                 # [D, q_local, hd]
    wk = spec.gather_tiled(w.wk, axis=2)
    wv = spec.gather_tiled(w.wv, axis=2)
    wqkv = jnp.concatenate([wq.reshape(D, q_local * hd),
                            wk.reshape(D, kv_local * hd),
                            wv.reshape(D, kv_local * hd)], axis=1)
    bqkv = None
    if w.bq is not None:
        tracecount.bump("weight_gather", 3)
        bq = spec.gather_tiled(w.bq, axis=1)             # [q_local, hd]
        bk = spec.gather_tiled(w.bk, axis=1)
        bv = spec.gather_tiled(w.bv, axis=1)
        bqkv = jnp.concatenate([bq.reshape(q_local * hd),
                                bk.reshape(kv_local * hd),
                                bv.reshape(kv_local * hd)])

    cos, sin = rope_at(cache_len, hd, rope_theta)
    s_blk = cache.k.shape[0]
    ragged = jnp.ndim(cache_len) == 1
    ap = _append_slot(spec, s_blk, cache_len, window=window)
    blk = _fit_block_s(s_blk, spec.block_s)
    wo_unused = jnp.zeros((1, 1), x.dtype)   # O-proj runs after the combine

    kc = cache.k.reshape(s_blk, B, kv_local, hd)
    vc = cache.v.reshape(s_blk, B, kv_local, hd)

    def one(xb, kb, vb, cl, cosb, sinb, posb, inc):
        acc, k_new, v_new, m, l = fused_decode_attention(
            xb[None], wqkv, bqkv, wo_unused, kb, vb, cl, cosb, sinb,
            q_heads=q_local, kv_heads=kv_local, scale=scale,
            attn_softcap=attn_softcap, window=window, ring=window > 0,
            block_s=blk, fuse_out=False, interpret=spec.interpret,
            pos=posb, include_new=inc, pos_base=ap.pos_base)
        return acc[0], k_new[0], v_new[0], m[0], l[0]

    # Ragged: the scalar-prefetch operands (cache_len, include_new, RoPE
    # angles, pos column) are vmapped per slot — each batch element's
    # kernel instance gets its OWN index-map clamp and live-span cull.
    kern_axes = (0, 1, 1, 0, 0, 0, 1, 0) if ragged \
        else (0, 1, 1, None, None, None, None, None)
    acc, k_new, v_new, m, l = jax.vmap(one, in_axes=kern_axes)(
        x, kc, vc, cache_len, cos, sin, cache.pos, ap.include_new)

    # Append the kernel-emitted new KV on the owning rank (as in the XLA
    # path; the kernel itself attended the new token via include_new).
    if ragged:
        cache = _insert_kv_ragged(cache, k_new, v_new, ap.owner,
                                  ap.local_slot, ap.rank, cache_len)
    else:
        cache = _insert_kv(cache, k_new.reshape(B * kv_local, hd),
                           v_new.reshape(B * kv_local, hd),
                           ap.owner, ap.local_slot, ap.rank, cache_len)

    # ClusterReduce combine + Output-Projection tile + heads reduction.
    m = m.reshape(B, kv_local, qpk)
    l = l.reshape(B, kv_local, qpk)
    acc = acc.reshape(B, kv_local, qpk, hd)
    _, l_g, o_g = spec.flash_combine(m, l, acc)
    att = (o_g / jnp.maximum(l_g[..., None], 1e-30))
    att = att.reshape(B, q_local * hd).astype(x.dtype)
    o_seg = att @ w.wo                                       # [B, D/N]
    o_seg = spec.heads_reduce(o_seg)
    return o_seg, cache


def _split_token_attention_pallas_packed(
    spec: ClusterSpec,
    x: jax.Array,
    w: PackedSplitTokenWeights,
    cache: KVBlock,
    cache_len: jax.Array,
    *,
    window: int,
    attn_softcap: float,
    rope_theta: float,
    scale: Optional[float],
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, KVBlock]:
    """SplitToken on prepacked serve-layout weights — the full Alg. 3
    fusion scope (DESIGN.md §2).  Returns ``(o [B, D], cache)`` — the
    output is already FULL-width (no cluster gather follows).

    No per-step weight movement remains: ``wqkv`` was gathered once at
    load time, and the Output-Projection runs INSIDE the kernel
    (``fuse_out="partial_o"``) through the rank's full-width ``wo``
    rows, emitting unnormalized per-head projected [B, q_loc, D]
    partials.  The per-head projection is linear and shared across the
    cluster, so the flash-merge operator stays exact on ``(m, l, o)``
    triples and a single fused ClusterReduce completes the softmax
    combine AND the projection sum; all that follows is a local
    normalize + head sum and the heads-axis reduction (the paper's
    atomicAdd analogue).  Trade-off, documented in DESIGN.md §2: for
    cluster N > 1 the reduce payload grows from ``q_loc·hd`` to
    ``q_loc·D`` per token — bought back by deleting the per-step weight
    gathers (∝ D·heads·hd), the output gather, and one collective.
    """
    B, D = x.shape
    q_local, hd, d_out = w.wo.shape
    kv_local = (w.wqkv.shape[1] // hd - q_local) // 2
    qpk = q_local // kv_local
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    from repro.kernels.fused_decode.fused_decode import fused_decode_attention
    from repro.kernels.fused_decode.ops import rope_at

    cos, sin = rope_at(cache_len, hd, rope_theta)
    s_blk = cache.k.shape[0]
    ragged = jnp.ndim(cache_len) == 1
    ap = _append_slot(spec, s_blk, cache_len, window=window)
    blk = _fit_block_s(s_blk, spec.block_s)

    kc = cache.k.reshape(s_blk, B, kv_local, hd)
    vc = cache.v.reshape(s_blk, B, kv_local, hd)

    def one(xb, kb, vb, cl, cosb, sinb, posb, inc):
        acc, k_new, v_new, m, l = fused_decode_attention(
            xb[None], w.wqkv, w.bqkv, w.wo, kb, vb, cl, cosb, sinb,
            q_heads=q_local, kv_heads=kv_local, scale=scale,
            attn_softcap=attn_softcap, window=window, ring=window > 0,
            block_s=blk, fuse_out="partial_o", interpret=spec.interpret,
            pos=posb, include_new=inc, pos_base=ap.pos_base,
            norm_scale=w.ln1, norm_eps=norm_eps)
        return acc[0], k_new[0], v_new[0], m[0], l[0]

    kern_axes = (0, 1, 1, 0, 0, 0, 1, 0) if ragged \
        else (0, 1, 1, None, None, None, None, None)
    acc, k_new, v_new, m, l = jax.vmap(one, in_axes=kern_axes)(
        x, kc, vc, cache_len, cos, sin, cache.pos, ap.include_new)

    if ragged:
        cache = _insert_kv_ragged(cache, k_new, v_new, ap.owner,
                                  ap.local_slot, ap.rank, cache_len)
    else:
        cache = _insert_kv(cache, k_new.reshape(B * kv_local, hd),
                           v_new.reshape(B * kv_local, hd),
                           ap.owner, ap.local_slot, ap.rank, cache_len)

    # ONE fused ClusterReduce over (m, l, projected partials), then a
    # local normalize + sum over this rank's heads.
    tracecount.bump("cluster_combine")
    m = m.reshape(B, kv_local, qpk)
    l = l.reshape(B, kv_local, qpk)
    p_o = acc.reshape(B, kv_local, qpk, d_out)
    _, l_g, p_g = prim.cluster_flash_combine(m, l, p_o, spec.cluster,
                                             fused=True)
    o_full = (p_g / jnp.maximum(l_g[..., None], 1e-30)).sum(axis=(1, 2))
    o_full = spec.heads_reduce(o_full.astype(x.dtype))       # [B, D]
    return o_full, cache


# ---------------------------------------------------------------------------
# Paper Alg. 5 — SplitHead dataflow (App. B.2, comparison variant)
# ---------------------------------------------------------------------------
class SplitHeadWeights(NamedTuple):
    """``wq/wk/wv`` [D, q|kv_local, hd/N]; ``wo`` [q_local*hd/N, D]."""

    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array


def split_head_attention(
    spec: ClusterSpec,
    x: jax.Array,                 # [B, D]
    w: SplitHeadWeights,
    cache: KVBlock,               # HEAD-DIM-partitioned: [S, B*kv_local, hd/N]
    cache_len: jax.Array,
    *,
    rope_theta: float = 10000.0,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, KVBlock]:
    """Alg. 5: partition the head dim in all three stages; ClusterReduce the
    full score matrix (traffic ∝ S — the paper shows this loses at long S).

    NOTE: RoPE with a split head dim would rotate across ranks; we follow
    the paper (no RoPE in Alg. 5 exposition) but emulate positionality by
    rotating *within* each segment — documented deviation, exercised only in
    the dataflow-comparison benchmark, not in production serving.
    """
    n = spec.n_cluster
    b_rank = prim.axis_index(spec.cluster)
    B = x.shape[0]
    q_local, hd_n = w.wq.shape[1], w.wq.shape[2]
    kv_local = w.wk.shape[1]
    qpk = q_local // kv_local
    scale = scale if scale is not None else 1.0 / math.sqrt(hd_n * n)

    # (2) QKV segments stay in "registers" (no gather — Alg. 5 line 2).
    q_seg = jnp.einsum("bd,dqh->bqh", x, w.wq)
    k_seg = jnp.einsum("bd,dkh->bkh", x, w.wk)
    v_seg = jnp.einsum("bd,dkh->bkh", x, w.wv)
    q_seg = _apply_rope(q_seg, cache_len, rope_theta)
    k_seg = _apply_rope(k_seg, cache_len, rope_theta)

    # (3) Append to local (head-dim-sharded) cache: every rank owns slot.
    s_max = cache.k.shape[0]
    cache = _insert_kv(cache, k_seg.reshape(B * kv_local, hd_n),
                       v_seg.reshape(B * kv_local, hd_n),
                       b_rank, cache_len, b_rank, cache_len)

    kc = cache.k.reshape(s_max, B, kv_local, hd_n).astype(jnp.float32)
    vc = cache.v.reshape(s_max, B, kv_local, hd_n).astype(jnp.float32)
    qf = q_seg.reshape(B, kv_local, qpk, hd_n).astype(jnp.float32)

    # Partial scores over the FULL sequence, then ClusterReduce (Alg. 5 l.3).
    s_part = jnp.einsum("bkqh,sbkh->bkqs", qf, kc) * scale
    s_full = spec.reduce(s_part, "sum")                       # traffic ∝ S
    valid = (cache.pos >= 0) & (cache.pos <= cache_len)
    s_full = jnp.where(valid[None, None, None, :], s_full, -jnp.inf)
    p = jax.nn.softmax(s_full, axis=-1)
    a_seg = jnp.einsum("bkqs,sbkh->bkqh", p, vc)              # [B,kv,q,hd/N]
    a_seg = a_seg.reshape(B, q_local * hd_n).astype(x.dtype)

    # (4)–(6) partial Output-Projection over full D, ClusterReduce + heads.
    o_part = a_seg @ w.wo                                     # [B, D]
    o_full = spec.reduce(o_part, "sum")
    o_full = spec.heads_reduce(o_full)
    return o_full, cache


# ---------------------------------------------------------------------------
# Paper Alg. 4 — fused weight-absorbed MLA dataflow (App. B.1)
# ---------------------------------------------------------------------------
class MLAWeights(NamedTuple):
    """Weight shards for the fused MLA decode (DeepSeek-V2).

    ``wq``    [D, q_local, (nope+rope)/N] — Q-Projection head-dim segment
    ``wdkv``  [D, (l+rope)/N]             — Down-Projection (latent) segment
    ``wuk``   [q_local, nope, l/N]        — K-up, absorbed into Q (out-seg)
    ``wuv``   [q_local, l/N, v]           — V-up, row (l) segment
    ``wo``    [q_local*v, D/N]            — Output-Projection segment
    """

    wq: jax.Array
    wdkv: jax.Array
    wuk: jax.Array
    wuv: jax.Array
    wo: jax.Array


def mla_attention(
    spec: ClusterSpec,
    x: jax.Array,                 # [B, D]
    w: MLAWeights,
    cache: KVBlock,               # latent cache: k=[S_blk, B, l+rope], v unused
    cache_len: jax.Array,
    *,
    nope_dim: int,
    rope_dim: int,
    rope_theta: float = 10000.0,
    norm_eps: float = 1e-6,       # fused pre-attention RMSNorm eps (packed
                                  # serve layout with ``ln1`` only)
) -> Tuple[jax.Array, KVBlock]:
    """Fused MLA decode per paper Alg. 4 (weight-absorbed, Fig. 14 right).

    Schedule (faithful): 3 ClusterGathers (q segments, latent-kv segments,
    up-projected q) + 3 ClusterReduces (flash stats/outputs in latent space,
    value-up partial sums, output tiles via the heads reduction).

    ``spec.backend == "pallas"`` routes the local stage (projections,
    K-up absorption, RoPE, latent flash partial) through the fused MLA
    kernel instead (:func:`_mla_attention_pallas`); the collectives and
    the value-up / Output-Projection tail are shared.

    ``w`` may also be :class:`PackedMLAWeights` (serving/prepack.py):
    the fully fused ``fuse_out="partial_o"`` path with the W_UV·W_O fold
    — one kernel + one fused ClusterReduce per layer, returning the
    FULL ``[B, D]`` output (no cluster gather needed).
    """
    if isinstance(w, PackedMLAWeights):
        assert spec.backend == "pallas", \
            "prepacked serve-layout weights require backend='pallas'"
        return _mla_attention_pallas_packed(
            spec, x, w, cache, cache_len, nope_dim=nope_dim,
            rope_dim=rope_dim, rope_theta=rope_theta, norm_eps=norm_eps)
    if spec.backend == "pallas":
        return _mla_attention_pallas(
            spec, x, w, cache, cache_len, nope_dim=nope_dim,
            rope_dim=rope_dim, rope_theta=rope_theta)
    n = spec.n_cluster
    b_rank = prim.axis_index(spec.cluster)
    B = x.shape[0]
    q_local = w.wq.shape[1]
    l_n = w.wuk.shape[2]
    l_rank = l_n * n
    v_dim = w.wuv.shape[2]
    scale = 1.0 / math.sqrt(nope_dim + rope_dim)

    # (2)–(4): segment Q and latent-KV projections, ClusterGather both.
    q_seg = jnp.einsum("bd,dqh->bqh", x, w.wq)         # [B,q,(nope+rope)/N]
    c_seg = x @ w.wdkv                                  # [B,(l+rope)/N]
    q_full = spec.gather_tiled(q_seg, axis=2)           # [B,q,nope+rope]
    c_full = spec.gather_tiled(c_seg, axis=1)           # [B,l+rope]
    q_nope, q_rope = q_full[..., :nope_dim], q_full[..., nope_dim:]
    c_lat, c_rope = c_full[..., :l_rank], c_full[..., l_rank:]

    # (5)–(6): Up-Projection segments (weight-absorbed q→latent), gather Q.
    q_lat_seg = jnp.einsum("bqn,qnl->bql", q_nope, w.wuk)   # [B,q,l/N]
    q_lat = spec.gather_tiled(q_lat_seg, axis=2)            # [B,q,l]

    q_rope = _apply_rope(q_rope, cache_len, rope_theta)
    c_rope = _apply_rope(c_rope, cache_len, rope_theta)

    # Append latent+rope entry to the owning rank's cache block.
    s_blk = cache.k.shape[0]
    ragged = jnp.ndim(cache_len) == 1
    ap = _append_slot(spec, s_blk, cache_len)
    entry = jnp.concatenate([c_lat, c_rope], axis=-1)       # [B, l+rope]
    ins = _insert_kv_ragged if ragged else _insert_kv
    cache = ins(cache, entry, entry[:, :1],                  # v-side unused
                ap.owner, ap.local_slot, ap.rank, cache_len)

    # (7): FlashDecoding partial in latent space over the local block,
    # bucketed over live blocks only (cost ∝ cache_len — DESIGN.md §3).
    # The score contracts the concatenated (latent ++ rope) dim; values
    # are the latent part, so o comes out in latent space.
    cc = cache.k.reshape(s_blk, B, l_rank + rope_dim).astype(jnp.float32)
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1).astype(jnp.float32)
    valid = (cache.pos >= 0) & (cache.pos <= cache_len)
    m_safe, l_stat, o, _ = bucketed_flash_attention(
        q_cat[:, None], cc[:, :, None, :], cc[:, :, None, :l_rank], valid,
        scale=scale, block_s=spec.block_s)
    m_safe, l_stat, o = m_safe[:, 0], l_stat[:, 0], o[:, 0]  # [B,q,(l)]

    # (8)–(10): ClusterReduce stats + outputs (online-softmax rescale).
    _, l_g, o_g = spec.flash_combine(m_safe, l_stat, o)
    a_lat = o_g / jnp.maximum(l_g[..., None], 1e-30)        # [B,q,l]

    # (11)–(12): value Up-Projection partial sums over l segments.
    a_seg = lax.dynamic_slice_in_dim(a_lat, b_rank * l_n, l_n, axis=2)
    o_head_part = jnp.einsum("bql,qlv->bqv", a_seg, w.wuv)
    o_head = spec.reduce(o_head_part, "sum")                # [B,q,v]

    # (13): Output-Projection tile + heads reduction (atomicAdd analogue).
    o_seg = o_head.reshape(B, q_local * v_dim).astype(x.dtype) @ w.wo
    o_seg = spec.heads_reduce(o_seg)                        # [B, D/N]
    return o_seg, cache


def _mla_attention_pallas(
    spec: ClusterSpec,
    x: jax.Array,
    w: MLAWeights,
    cache: KVBlock,
    cache_len: jax.Array,
    *,
    nope_dim: int,
    rope_dim: int,
    rope_theta: float,
) -> Tuple[jax.Array, KVBlock]:
    """Alg. 4 with the local stage as one fused Pallas kernel per rank.

    As in :func:`_split_token_attention_pallas`, the three activation
    ClusterGathers of Alg. 4 (q segments, latent-kv segments, absorbed q)
    hoist to their weight segments, so Q-Projection, Down-Projection,
    K-up absorption, RoPE and the latent-space flash partial all run in
    :func:`repro.kernels.fused_mla_decode.fused_mla_decode_attention`
    (``fuse_out=False``).  The ClusterReduce combine, the value
    Up-Projection partial sums and the Output-Projection tile stay
    between kernel invocations (paper Alg. 4 lines 8–13).
    """
    n = spec.n_cluster
    b_rank = prim.axis_index(spec.cluster)
    B, D = x.shape
    q_local = w.wq.shape[1]
    l_n = w.wuk.shape[2]
    l_rank = l_n * n
    v_dim = w.wuv.shape[2]
    from repro.kernels.fused_mla_decode.fused_mla_decode import (
        fused_mla_decode_attention)
    from repro.kernels.fused_decode.ops import rope_at

    # Weight-segment gathers replacing Alg. 4's activation gathers —
    # step-invariant; the prepacked serve layout hoists them to load time.
    tracecount.bump("weight_gather", 3)
    wq = spec.gather_tiled(w.wq, axis=2)      # [D, q_local, nope+rope]
    wdkv = spec.gather_tiled(w.wdkv, axis=1)  # [D, l_rank+rope]
    wuk = spec.gather_tiled(w.wuk, axis=2)    # [q_local, nope, l_rank]
    wq2 = wq.reshape(D, q_local * (nope_dim + rope_dim))

    cos, sin = rope_at(cache_len, rope_dim, rope_theta)
    s_blk = cache.k.shape[0]
    ragged = jnp.ndim(cache_len) == 1
    ap = _append_slot(spec, s_blk, cache_len)       # latent cache is linear
    blk = _fit_block_s(s_blk, spec.block_s)
    wo_unused = jnp.zeros((1, 1), x.dtype)   # value-up + O-proj after combine

    def one(xb, cb, cl, cosb, sinb, posb, inc):
        acc, c_new, m, l = fused_mla_decode_attention(
            xb[None], wq2, wdkv, wuk, w.wuv, wo_unused, cb, cl,
            cosb, sinb, q_heads=q_local, nope=nope_dim, rope_d=rope_dim,
            l_rank=l_rank, v_dim=v_dim, block_s=blk, fuse_out=False,
            interpret=spec.interpret, pos=posb,
            include_new=inc, pos_base=ap.pos_base)
        return acc[0], c_new[0], m[0], l[0]

    kern_axes = (0, 1, 0, 0, 0, 1, 0) if ragged \
        else (0, 1, None, None, None, None, None)
    acc, c_new, m, l = jax.vmap(one, in_axes=kern_axes)(
        x, cache.k, cache_len, cos, sin, cache.pos, ap.include_new)

    # Append the kernel-emitted latent entry on the owning rank.
    ins = _insert_kv_ragged if ragged else _insert_kv
    cache = ins(cache, c_new, c_new[:, :1],              # v-side unused
                ap.owner, ap.local_slot, ap.rank, cache_len)

    # (8)–(13): combine, value Up-Projection partials, O-Projection tile.
    _, l_g, o_g = spec.flash_combine(m, l, acc)
    a_lat = o_g / jnp.maximum(l_g[..., None], 1e-30)     # [B,q,l]
    a_seg = lax.dynamic_slice_in_dim(a_lat, b_rank * l_n, l_n, axis=2)
    o_head_part = jnp.einsum("bql,qlv->bqv", a_seg, w.wuv)
    o_head = spec.reduce(o_head_part, "sum")             # [B,q,v]
    o_seg = o_head.reshape(B, q_local * v_dim).astype(x.dtype) @ w.wo
    o_seg = spec.heads_reduce(o_seg)                     # [B, D/N]
    return o_seg, cache


def _mla_attention_pallas_packed(
    spec: ClusterSpec,
    x: jax.Array,
    w: PackedMLAWeights,
    cache: KVBlock,
    cache_len: jax.Array,
    *,
    nope_dim: int,
    rope_dim: int,
    rope_theta: float,
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, KVBlock]:
    """Alg. 4 on prepacked serve-layout weights — fully fused.  Returns
    ``(o [B, D], cache)``; no cluster gather follows.

    All of Alg. 4's weight-segment gathers happened at load time, the
    value Up-Projection and Output-Projection are folded into one
    full-width per-head matrix (``wproj = W_UV · W_O``) applied INSIDE
    the kernel on the unnormalized latent accumulator, and Alg. 4's
    value-up partial-sum ClusterReduce (lines 11–12) vanishes.  Per
    layer: one kernel + one fused ClusterReduce + local
    normalize/head-sum + the heads-axis reduction.
    """
    B, D = x.shape
    q_local, _, l_rank = w.wuk.shape
    d_out = w.wproj.shape[-1]
    from repro.kernels.fused_mla_decode.fused_mla_decode import (
        fused_mla_decode_attention)
    from repro.kernels.fused_decode.ops import rope_at

    cos, sin = rope_at(cache_len, rope_dim, rope_theta)
    s_blk = cache.k.shape[0]
    ragged = jnp.ndim(cache_len) == 1
    ap = _append_slot(spec, s_blk, cache_len)       # latent cache is linear
    blk = _fit_block_s(s_blk, spec.block_s)
    wo_unused = jnp.zeros((1, 1), x.dtype)

    def one(xb, cb, cl, cosb, sinb, posb, inc):
        acc, c_new, m, l = fused_mla_decode_attention(
            xb[None], w.wq, w.wdkv, w.wuk, w.wproj, wo_unused, cb,
            cl, cosb, sinb, q_heads=q_local, nope=nope_dim,
            rope_d=rope_dim, l_rank=l_rank, v_dim=d_out, block_s=blk,
            fuse_out="partial_o", interpret=spec.interpret, pos=posb,
            include_new=inc, pos_base=ap.pos_base,
            norm_scale=w.ln1, norm_eps=norm_eps)
        return acc[0], c_new[0], m[0], l[0]

    kern_axes = (0, 1, 0, 0, 0, 1, 0) if ragged \
        else (0, 1, None, None, None, None, None)
    acc, c_new, m, l = jax.vmap(one, in_axes=kern_axes)(
        x, cache.k, cache_len, cos, sin, cache.pos, ap.include_new)

    ins = _insert_kv_ragged if ragged else _insert_kv
    cache = ins(cache, c_new, c_new[:, :1],              # v-side unused
                ap.owner, ap.local_slot, ap.rank, cache_len)

    # ONE fused ClusterReduce over (m, l, projected tiles); normalize per
    # head and sum over this rank's heads.
    tracecount.bump("cluster_combine")
    _, l_g, p_g = prim.cluster_flash_combine(m, l, acc, spec.cluster,
                                             fused=True)
    o_full = (p_g / jnp.maximum(l_g[..., None], 1e-30)).sum(axis=1)
    o_full = spec.heads_reduce(o_full.astype(x.dtype))   # [B, D]
    return o_full, cache


# ---------------------------------------------------------------------------
# DSMEM-traffic totals per dataflow (paper §3.2 + App. B) — bytes
# ---------------------------------------------------------------------------
def traffic_split_token(head_dim: int, model_dim: int, n: int,
                        bytes_per_el: int = 2, batch: int = 1) -> float:
    """Alg. 3 total: Reduce(3h… — paper text) — we follow the corrected
    App. B formula ``Traffic_Reduce(H, N) + Traffic_Gather(3h, N)`` with
    h = head_dim/N segments and H = head_dim (the per-head attention output
    reduced across the cluster)."""
    h_seg = head_dim / n * 3 * bytes_per_el * batch
    red = head_dim * bytes_per_el * batch
    return prim.traffic_gather(h_seg, n) + prim.traffic_reduce(red, n)


def traffic_split_head(seq_len: int, model_dim: int, n: int,
                       bytes_per_el: int = 4, batch: int = 1) -> float:
    """Alg. 5 total: ``Traffic_Reduce(S, N) + Traffic_Reduce(D, N)``."""
    return (prim.traffic_reduce(seq_len * bytes_per_el * batch, n)
            + prim.traffic_reduce(model_dim * bytes_per_el * batch, n))


def traffic_mla(head_dim: int, l_rank: int, total_head_dim: int, n: int,
                bytes_per_el: int = 2, batch: int = 1) -> float:
    """Alg. 4 total: ``Gather(h) + 2·Gather(l) + Reduce(l) + Reduce(H)``."""
    b = bytes_per_el * batch
    return (prim.traffic_gather(head_dim / n * b, n)
            + 2 * prim.traffic_gather(l_rank / n * b, n)
            + prim.traffic_reduce(l_rank * b, n)
            + prim.traffic_reduce(total_head_dim * b, n))
