"""Cluster-size / dataflow selection (paper §4.1 Fig. 11 + App. B).

The paper's conclusion: *"the optimal cluster size varies across workloads
… cluster size should be tuned accordingly"* (they measure 4 best for
32–64 heads, 2 for 128 heads on H100).  On H100 the trade-off is DSMEM
latency/bandwidth vs active SMs; on TPU the analogous trade-off is:

* larger N ⇒ more chips cooperate on one head ⇒ shorter per-chip KV scan
  (good: decode is KV-bandwidth-bound) but more ICI rounds (log2 N) and
  more gather/reduce traffic (paper's traffic model, linear-to-N·log N);
* larger N also shrinks the head-group axis H = model_axis / N ⇒ fewer
  heads resident per chip ⇒ more weight replication for GQA KV weights.

We pick N by minimizing an analytical per-token latency model built from
the paper's traffic formulas plus v5e roofline constants.  This is the
same *structure* as the paper's Appendix-B analysis, with DSMEM constants
replaced by ICI/HBM constants.
"""
from __future__ import annotations

import json
import math
import os
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig
from repro.core import dataflow as df
from repro.core import primitives as prim

# v5e hardware constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LAT = 1e-6               # seconds per hop (round latency floor)
GRID_STEP_OVH = 1e-6         # per-Pallas-grid-step fixed overhead (s)
VMEM_BUDGET = 8 * 2**20      # bytes for double-buffered KV blocks


@dataclass(frozen=True)
class TunePoint:
    cluster_size: int
    dataflow: str               # "split_token" | "split_head" | "mla"
    est_seconds: float
    terms: Dict[str, float]


def _attn_decode_time(cfg: ModelConfig, seq_len: int, batch: int,
                      model_axis: int, n: int, flow: str) -> Tuple[float, Dict[str, float]]:
    """Per-layer decode-step latency estimate for cluster size n."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    heads_axis = model_axis // n
    q_local = max(1, cfg.n_heads // heads_axis)
    kv_local = max(1, cfg.n_kv_heads // heads_axis)
    bpe = 2  # bf16

    if cfg.mla is not None and flow == "mla":
        l_rank = cfg.mla.kv_lora_rank
        kv_bytes = batch * seq_len * (l_rank + cfg.mla.rope_head_dim) * bpe
        traffic = df.traffic_mla(hd, l_rank, cfg.n_heads * hd, n,
                                 bytes_per_el=bpe, batch=batch) * q_local
        flops = 2 * batch * q_local * seq_len * (l_rank + cfg.mla.rope_head_dim) * 2
    elif flow == "split_head":
        kv_bytes = batch * seq_len * kv_local * hd * 2 * bpe  # full S per rank
        traffic = df.traffic_split_head(seq_len, d, n, batch=batch) * q_local
        flops = 2 * batch * q_local * seq_len * hd * 2 / n
    else:  # split_token
        kv_bytes = batch * seq_len * kv_local * hd * 2 * bpe / n  # S split
        traffic = df.traffic_split_token(hd, d, n, bytes_per_el=bpe,
                                         batch=batch) * q_local
        flops = 2 * batch * q_local * seq_len * hd * 2 / n

    # weight bytes per chip for the fused block (QKV + O slices)
    w_bytes = (d * (q_local + 2 * kv_local) * hd / (1 if flow == "split_head" else n)
               + q_local * hd * d / n) * bpe
    t_mem = (kv_bytes + w_bytes) / HBM_BW
    t_comp = flops / PEAK_FLOPS
    t_ici = traffic / (n * ICI_BW) + math.log2(max(n, 2)) * ICI_LAT * (0 if n == 1 else 1)
    total = max(t_mem, t_comp) + t_ici
    return total, {"mem": t_mem, "comp": t_comp, "ici": t_ici,
                   "traffic_bytes": traffic}


def tune_cluster(cfg: ModelConfig, *, seq_len: int, batch: int,
                 model_axis: int = 16,
                 flows: Optional[List[str]] = None) -> TunePoint:
    """Pick (cluster_size, dataflow) minimizing the analytical latency.

    Mirrors the paper's tuning conclusion: larger N helps long sequences
    (KV split) until ICI rounds dominate; SplitHead only competes at short
    S; MLA uses its own fused dataflow.
    """
    if flows is None:
        flows = ["mla"] if cfg.mla is not None else ["split_token", "split_head"]
    best: Optional[TunePoint] = None
    n = 1
    while n <= model_axis:
        heads_axis = model_axis // n
        if cfg.n_heads % heads_axis == 0 or heads_axis <= cfg.n_heads:
            for flow in flows:
                t, terms = _attn_decode_time(cfg, seq_len, batch,
                                             model_axis, n, flow)
                pt = TunePoint(n, flow, t, terms)
                if best is None or t < best.est_seconds:
                    best = pt
        n *= 2
    assert best is not None
    return best


def sweep(cfg: ModelConfig, *, seq_len: int, batch: int,
          model_axis: int = 16) -> List[TunePoint]:
    """Full (N × dataflow) sweep — used by the Fig. 11 benchmark."""
    flows = ["mla"] if cfg.mla is not None else ["split_token", "split_head"]
    pts = []
    n = 1
    while n <= model_axis:
        for flow in flows:
            t, terms = _attn_decode_time(cfg, seq_len, batch, model_axis, n, flow)
            pts.append(TunePoint(n, flow, t, terms))
        n *= 2
    return pts


# ===========================================================================
# Serving plan: (cluster, dataflow, backend, block_s) per seq-length bucket,
# with a persisted table so repeated launches skip the search.
# ===========================================================================
@dataclass(frozen=True)
class ServePlan:
    cluster_size: int
    dataflow: str                # "split_token" | "mla"
    backend: str                 # "xla" | "pallas"
    block_s: int                 # KV block granularity (both backends)
    # serve-layout weight prepack (serving/prepack.py): weights are
    # re-laid out once at load time so the decode step performs zero
    # weight-segment ICI gathers and zero dynamic-slice weight slicing
    prepack: bool
    # d_ff tile of the fused-FFN block-tail megakernel (kernels/fused_ffn,
    # DESIGN.md §7); fitted down to a divisor of F_loc at the call site.
    # Pre-fused-FFN table entries lack this field and self-heal by
    # re-tuning (same schema-drift path as the prepack field).
    block_f: int
    # vocab tile of the fused LM-head/sampling kernel (kernels/fused_head,
    # DESIGN.md §7); fitted down to a divisor of V_loc at the call site.
    # Pre-fused-head table entries lack this field and self-heal by
    # re-tuning through the same TypeError path.
    block_v: int
    est_seconds: float


def seq_bucket(seq_len: int) -> int:
    """Power-of-two sequence-length bucket (≥ 256) — plans are tuned and
    persisted per bucket, not per exact length.  Ragged serving buckets
    on the expected MAX LIVE length, not the allocated capacity
    (``build_engine_full(plan_seq_len=…)`` — continuous batching
    allocates slack slots whose spans never reach ``max_seq``, and
    block_s/cluster should follow the spans the kernels actually
    stream; DESIGN.md §6)."""
    b = 256
    while b < seq_len:
        b *= 2
    return b


_BLOCK_CANDIDATES = (128, 256, 512, 1024, 2048)


def pick_block_s(cfg: ModelConfig, seq_len: int, cluster_size: int,
                 batch: int = 1) -> int:
    """KV block size for the decode inner loop.

    Per-rank live span is ``seq_len / N``; each block pays a fixed grid-
    step overhead plus its HBM bytes, so the model prefers the largest
    block whose double-buffered K+V tiles fit the VMEM budget and that
    doesn't exceed the span (smaller blocks only add overhead).
    """
    hd = cfg.resolved_head_dim
    if cfg.mla is not None:
        row = (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim) * 2 * batch
    else:
        row = max(1, cfg.n_kv_heads) * hd * 2 * 2 * batch    # K+V rows, bf16
    span = max(1, seq_len // max(cluster_size, 1))
    best = _BLOCK_CANDIDATES[0]
    for b in _BLOCK_CANDIDATES:
        if b * row * 2 > VMEM_BUDGET:      # ×2: double-buffered pipeline
            break
        best = b
        if b >= span:
            break
    # wide-KV configs: even the smallest candidate can blow the budget —
    # halve until the double-buffered tiles fit (floor 8)
    while best > 8 and best * row * 2 > VMEM_BUDGET:
        best //= 2
    return best


_BLOCK_F_CANDIDATES = (256, 512, 1024, 2048, 4096)


def pick_block_f(cfg: ModelConfig) -> int:
    """d_ff tile for the fused-FFN megakernel (kernels/fused_ffn).

    Each grid step streams an up tile [D, bf], an optional gate tile
    [D, bf] and a down-row tile [bf, D]; prefer the largest tile whose
    double-buffered weights fit the VMEM budget (fewer grid steps ⇒
    less fixed per-step overhead; the [B, D] activation scratch is
    batch-small and deliberately outside the model).  The call site
    fits the pick down to a divisor of the local ``d_ff`` shard
    (``_fit_block_s``).
    """
    d = cfg.d_model
    bpe = 2
    tiles = 3 if cfg.ffn_gated else 2      # up (+gate) cols + down rows
    best = _BLOCK_F_CANDIDATES[0]
    for b in _BLOCK_F_CANDIDATES:
        if b * d * tiles * bpe * 2 > VMEM_BUDGET:   # ×2: double-buffered
            break
        best = b
    while best > 8 and best * d * tiles * bpe * 2 > VMEM_BUDGET:
        best //= 2
    return best


_BLOCK_V_CANDIDATES = (512, 1024, 2048, 4096)


def pick_block_v(cfg: ModelConfig, *, batch: int = 1, k: int = 8) -> int:
    """Vocab tile for the fused LM-head/sampling kernel (kernels/fused_head).

    Each grid step streams one ``[bv, D]`` tile of the (possibly tied)
    embedding table in the model dtype; prefer the largest tile whose
    double-buffered weight stream fits the VMEM budget (fewer grid
    steps ⇒ less fixed per-step overhead).  The residency model also
    charges the ``[B, D]`` normed-input scratch (f32) and the
    ``[B, k]`` running top-k partials (f32 value + int32 index — the
    k-wide streaming selection the sampled tail folds per tile); both
    are batch-small but no longer negligible at large B × k, so they
    join the budget instead of living outside it.  The call site fits
    the pick down to a divisor of the local vocab shard
    (``_fit_block_s``)."""
    d = cfg.d_model
    bpe = 2
    fixed = batch * d * 4 + batch * k * 8    # h scratch + (val, idx) topk
    best = _BLOCK_V_CANDIDATES[0]
    for b in _BLOCK_V_CANDIDATES:
        if b * d * bpe * 2 + fixed > VMEM_BUDGET:   # ×2: double-buffered
            break
        best = b
    while best > 8 and best * d * bpe * 2 + fixed > VMEM_BUDGET:
        best //= 2
    return best


def _backend_for(cfg: ModelConfig, backend: str) -> str:
    """Resolve ``"auto"``: attention layers take the fused Pallas kernels
    (no intermediate materialization, length-clamped HBM traffic);
    attention-free architectures keep the XLA dataflow (the fusion scope
    the paper targets does not apply — DESIGN.md §4)."""
    if backend != "auto":
        return backend
    return "xla" if cfg.is_attention_free else "pallas"


def _prepack_for(backend_resolved: str, prepack) -> bool:
    """Resolve the prepack knob: ``"auto"`` (default) enables the serve
    layout whenever the Pallas backend is in play — the fully fused
    ``partial_o`` path requires it; explicit on/off is honored for both
    backends (the XLA serve layout still hoists the rank slices).
    Unknown strings raise instead of silently disabling the fast path."""
    if prepack in ("auto", None):
        return backend_resolved == "pallas"
    if isinstance(prepack, str):
        if prepack in ("on", "true", "1"):
            return True
        if prepack in ("off", "false", "0"):
            return False
        raise ValueError(f"prepack must be auto/on/off, got {prepack!r}")
    return bool(prepack)


def weight_gather_bytes_per_step(cfg: ModelConfig, *, model_axis: int,
                                 cluster_size: int, backend: str,
                                 prepack: bool,
                                 bytes_per_el: int = 2) -> float:
    """Modeled per-token ICI bytes spent on *weight-segment* gathers.

    The Level-2 Pallas path hoists Alg. 3/4's activation gathers to the
    step-invariant weight segments (DESIGN.md §2); without prepack these
    re-run every decode step.  The XLA path gathers activations instead
    (O(B·heads·hd), not counted here), and the prepacked serve layout
    gathers once at load — both read 0.  Tracked in BENCH_tpot.json so
    the perf trajectory is auditable across PRs.
    """
    if backend != "pallas" or prepack:
        return 0.0
    n = cluster_size
    if n <= 1:
        return 0.0
    hs = max(1, model_axis // n)
    d = cfg.d_model
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind not in (ATTN_GLOBAL, ATTN_LOCAL):
            continue
        q_loc = max(1, cfg.n_heads // hs)
        if cfg.mla is not None:
            m = cfg.mla
            seg = (d * q_loc * (m.nope_head_dim + m.rope_head_dim) / n
                   + d * (m.kv_lora_rank + m.rope_head_dim) / n
                   + q_loc * m.nope_head_dim * m.kv_lora_rank / n)
        else:
            kv_loc = max(1, cfg.n_kv_heads // hs)
            hd = cfg.resolved_head_dim
            seg = d * (q_loc + 2 * kv_loc) * (hd / n)
            if cfg.qkv_bias:       # bq/bk/bv segments gather too
                seg += (q_loc + 2 * kv_loc) * (hd / n)
        total += prim.traffic_gather(seg * bytes_per_el, n)
    return total


def _n_dense_ffn_layers(cfg: ModelConfig) -> int:
    """Attention layers whose dense FFN the fused block tail covers
    (MoE layers keep the XLA expert dispatch; enc-dec interleaves
    cross-attention — DESIGN.md §7)."""
    if cfg.moe is not None or cfg.encoder is not None:
        return 0
    return sum(1 for k in cfg.layer_kinds if k in (ATTN_GLOBAL, ATTN_LOCAL))


def _fused_ffn_reduce_active(model_axis: int, backend: str,
                             prepack: bool) -> bool:
    """Mirror of the runtime dispatch in ``engine._fused_ffn_tail``: the
    fused tree ClusterReduce runs only on the prepacked Pallas path AND
    only for power-of-two model axes (the tree schedule's validity
    condition); otherwise the layer pays the ``psum_model`` all-reduce."""
    return (backend == "pallas" and prepack
            and model_axis > 1 and not (model_axis & (model_axis - 1)))


def ffn_psum_bytes_per_step(cfg: ModelConfig, *, model_axis: int,
                            batch: int, backend: str, prepack: bool,
                            bytes_per_el: int = 2) -> float:
    """Modeled per-step ICI bytes of the per-layer FFN activation
    all-reduce (``ctx.psum_model`` on the ``[B, D]`` down-projection
    partials; XLA's bandwidth-optimal schedule moves ``2·(N−1)·size``
    over the fabric).  The fused full-block path replaces it with ONE
    fused tree ClusterReduce per layer — this column reads 0 there and
    :func:`ffn_cluster_reduce_bytes_per_step` carries the replacement's
    traffic, so the trade stays auditable in BENCH_tpot.json.  Non-pow2
    model axes keep the psum even when prepacked (the runtime fallback
    in ``engine._fused_ffn_tail``)."""
    if model_axis <= 1 or _fused_ffn_reduce_active(model_axis, backend,
                                                   prepack):
        return 0.0
    size = batch * cfg.d_model * bytes_per_el
    return _n_dense_ffn_layers(cfg) * 2.0 * (model_axis - 1) * size


def ffn_cluster_reduce_bytes_per_step(cfg: ModelConfig, *, model_axis: int,
                                      batch: int, backend: str,
                                      prepack: bool,
                                      bytes_per_el: int = 2) -> float:
    """Modeled per-step ICI bytes of the fused ClusterReduce that
    replaces the FFN ``psum_model`` on the full-block path (the paper's
    tree schedule: ``size · log2 N · N``)."""
    if not _fused_ffn_reduce_active(model_axis, backend, prepack):
        return 0.0
    size = batch * cfg.d_model * bytes_per_el
    return (_n_dense_ffn_layers(cfg)
            * prim.traffic_reduce(size, model_axis))


def _fused_head_active(backend: str, prepack: bool) -> bool:
    """Mirror of the runtime dispatch in ``engine.decode_step``: the
    fused LM-head/sampling tail runs whenever the serve tree carries the
    head bundle — the prepacked Pallas path (``prepack.bundle_head``).
    Assumes ``build_engine_full``'s default ``fuse_head=True``; an
    ablation engine built with ``fuse_head=False`` runs the loose tail
    and pays the logits bytes this model would report as 0."""
    return backend == "pallas" and prepack


def head_hbm_logits_bytes_per_step(cfg: ModelConfig, *, model_axis: int,
                                   batch: int, backend: str, prepack: bool,
                                   bytes_per_el: int = 4) -> float:
    """Modeled per-chip HBM bytes of the ``[B, V_loc]`` logits tensor
    the unfused LM-head tail materializes every decode step — the
    single largest activation the step writes, and the one the fused
    head kernel deletes (greedy only ever needed the per-slot (max,
    argmax)).  Reads 0 on the fused path; ``bytes_per_el`` defaults to
    4 (``lm_head_logits`` pins f32 logits).  Tracked per variant in
    BENCH_tpot.json and gated against the committed baseline by
    ``scripts/check_bench.py``."""
    if _fused_head_active(backend, prepack):
        return 0.0
    v_loc = (cfg.vocab_size + model_axis - 1) // model_axis
    return float(batch * v_loc * bytes_per_el)


def head_ici_bytes_per_step(cfg: ModelConfig, *, model_axis: int,
                            batch: int, backend: str, prepack: bool,
                            bytes_per_el: int = 4, k: int = 8) -> float:
    """Modeled per-step ICI bytes of the k-wide (value, index) candidate
    tree reduce over the vocab shards (paper tree schedule; k f32
    values + k int32 indices per slot — ``k`` is the fused tail's
    candidate width ``sampling.CAND_K``; k=1 recovers the PR-5 greedy
    pair).  Identical on the fused and unfused tails by construction —
    the fused head changes WHERE the partials come from (streaming VMEM
    tiles vs an HBM logits tensor), not the collective — so a
    regression in this column means the reduce schedule or the
    candidate width itself changed."""
    if model_axis <= 1:
        return 0.0
    pair = batch * k * bytes_per_el * 2      # k × (f32 value, int32 index)
    return prim.traffic_reduce(float(pair), model_axis)


def tune_serving(cfg: ModelConfig, *, seq_len: int, batch: int,
                 model_axis: int = 16, backend: str = "auto",
                 prepack="auto",
                 table_path: Optional[str] = None) -> ServePlan:
    """Pick the full serving plan for a (config, bucket) cell.

    Consults/updates the persisted JSON table at ``table_path`` (or
    ``$REPRO_AUTOTUNE_TABLE``) keyed by
    ``name|model_axis|batch|seq_bucket|backend|prepack`` — with prepack
    RESOLVED to its boolean, so ``prepack="auto"`` and an explicit
    ``"on"`` that resolve identically share one cell — so repeated
    launches pay zero search cost.  Entries whose schema has drifted
    (e.g. a pre-prepack table) self-heal by re-tuning.
    """
    bucket = seq_bucket(seq_len)
    backend_resolved = _backend_for(cfg, backend)
    pp = _prepack_for(backend_resolved, prepack)
    key = (f"{cfg.name}|ms{model_axis}|b{batch}|s{bucket}|{backend}"
           f"|pp{int(pp)}")
    path = table_path or os.environ.get("REPRO_AUTOTUNE_TABLE")
    table = load_table(path)
    if key in table:
        try:
            return ServePlan(**table[key])
        except TypeError:          # schema drift / hand-edited entry
            pass                   # fall through and re-tune (self-heals)
    best = tune_cluster(cfg, seq_len=bucket, batch=batch,
                        model_axis=model_axis)
    plan = ServePlan(
        cluster_size=best.cluster_size,
        dataflow=best.dataflow if best.dataflow != "split_head"
        else "split_token",            # split_head is bench-only
        backend=backend_resolved,
        block_s=pick_block_s(cfg, bucket, best.cluster_size, batch),
        prepack=pp,
        block_f=pick_block_f(cfg),
        block_v=pick_block_v(cfg, batch=batch),
        est_seconds=best.est_seconds,
    )
    table[key] = asdict(plan)
    save_table(path, table)
    return plan


def load_table(path: Optional[str]) -> Dict[str, dict]:
    if not path or not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def save_table(path: Optional[str], table: Dict[str, dict]) -> None:
    if not path:
        return
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
