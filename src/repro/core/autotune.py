"""Cluster-size / dataflow selection (paper §4.1 Fig. 11 + App. B).

The paper's conclusion: *"the optimal cluster size varies across workloads
… cluster size should be tuned accordingly"* (they measure 4 best for
32–64 heads, 2 for 128 heads on H100).  On H100 the trade-off is DSMEM
latency/bandwidth vs active SMs; on TPU the analogous trade-off is:

* larger N ⇒ more chips cooperate on one head ⇒ shorter per-chip KV scan
  (good: decode is KV-bandwidth-bound) but more ICI rounds (log2 N) and
  more gather/reduce traffic (paper's traffic model, linear-to-N·log N);
* larger N also shrinks the head-group axis H = model_axis / N ⇒ fewer
  heads resident per chip ⇒ more weight replication for GQA KV weights.

We pick N by minimizing an analytical per-token latency model built from
the paper's traffic formulas plus v5e roofline constants.  This is the
same *structure* as the paper's Appendix-B analysis, with DSMEM constants
replaced by ICI/HBM constants.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import dataflow as df

# v5e hardware constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
ICI_LAT = 1e-6               # seconds per hop (round latency floor)


@dataclass(frozen=True)
class TunePoint:
    cluster_size: int
    dataflow: str               # "split_token" | "split_head" | "mla"
    est_seconds: float
    terms: Dict[str, float]


def _attn_decode_time(cfg: ModelConfig, seq_len: int, batch: int,
                      model_axis: int, n: int, flow: str) -> Tuple[float, Dict[str, float]]:
    """Per-layer decode-step latency estimate for cluster size n."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    heads_axis = model_axis // n
    q_local = max(1, cfg.n_heads // heads_axis)
    kv_local = max(1, cfg.n_kv_heads // heads_axis)
    bpe = 2  # bf16

    if cfg.mla is not None and flow == "mla":
        l_rank = cfg.mla.kv_lora_rank
        kv_bytes = batch * seq_len * (l_rank + cfg.mla.rope_head_dim) * bpe
        traffic = df.traffic_mla(hd, l_rank, cfg.n_heads * hd, n,
                                 bytes_per_el=bpe, batch=batch) * q_local
        flops = 2 * batch * q_local * seq_len * (l_rank + cfg.mla.rope_head_dim) * 2
    elif flow == "split_head":
        kv_bytes = batch * seq_len * kv_local * hd * 2 * bpe  # full S per rank
        traffic = df.traffic_split_head(seq_len, d, n, batch=batch) * q_local
        flops = 2 * batch * q_local * seq_len * hd * 2 / n
    else:  # split_token
        kv_bytes = batch * seq_len * kv_local * hd * 2 * bpe / n  # S split
        traffic = df.traffic_split_token(hd, d, n, bytes_per_el=bpe,
                                         batch=batch) * q_local
        flops = 2 * batch * q_local * seq_len * hd * 2 / n

    # weight bytes per chip for the fused block (QKV + O slices)
    w_bytes = (d * (q_local + 2 * kv_local) * hd / (1 if flow == "split_head" else n)
               + q_local * hd * d / n) * bpe
    t_mem = (kv_bytes + w_bytes) / HBM_BW
    t_comp = flops / PEAK_FLOPS
    t_ici = traffic / (n * ICI_BW) + math.log2(max(n, 2)) * ICI_LAT * (0 if n == 1 else 1)
    total = max(t_mem, t_comp) + t_ici
    return total, {"mem": t_mem, "comp": t_comp, "ici": t_ici,
                   "traffic_bytes": traffic}


def tune_cluster(cfg: ModelConfig, *, seq_len: int, batch: int,
                 model_axis: int = 16,
                 flows: Optional[List[str]] = None) -> TunePoint:
    """Pick (cluster_size, dataflow) minimizing the analytical latency.

    Mirrors the paper's tuning conclusion: larger N helps long sequences
    (KV split) until ICI rounds dominate; SplitHead only competes at short
    S; MLA uses its own fused dataflow.
    """
    if flows is None:
        flows = ["mla"] if cfg.mla is not None else ["split_token", "split_head"]
    best: Optional[TunePoint] = None
    n = 1
    while n <= model_axis:
        heads_axis = model_axis // n
        if cfg.n_heads % heads_axis == 0 or heads_axis <= cfg.n_heads:
            for flow in flows:
                t, terms = _attn_decode_time(cfg, seq_len, batch,
                                             model_axis, n, flow)
                pt = TunePoint(n, flow, t, terms)
                if best is None or t < best.est_seconds:
                    best = pt
        n *= 2
    assert best is not None
    return best


def sweep(cfg: ModelConfig, *, seq_len: int, batch: int,
          model_axis: int = 16) -> List[TunePoint]:
    """Full (N × dataflow) sweep — used by the Fig. 11 benchmark."""
    flows = ["mla"] if cfg.mla is not None else ["split_token", "split_head"]
    pts = []
    n = 1
    while n <= model_axis:
        for flow in flows:
            t, terms = _attn_decode_time(cfg, seq_len, batch, model_axis, n, flow)
            pts.append(TunePoint(n, flow, t, terms))
        n *= 2
    return pts
