"""Trace-time operation counters.

Decode hot-loop structure is asserted by counting *call sites as they
trace* (one trace = one compiled step, so trace-time counts are exact
per-step op counts under ``jit``).  The counters are free in production:
``bump`` is a no-op unless a :func:`counting` context is active, and the
instrumented sites only pay a dict lookup at trace time, never at run
time.

Labels used across the codebase:

* ``tree_reduce`` / ``tree_gather`` — cluster-collective tree schedules
  (:mod:`repro.core.primitives`); counted once per collective call, not
  per round.
* ``weight_gather`` — per-step ClusterGather of *weight* segments (the
  Level-2 hoisted gathers the prepack layout eliminates — DESIGN.md §2).
* ``weight_slice`` — per-layer ``lax.dynamic_slice`` weight slicing in
  the train-layout adapters (``_split_token_weights``/``_mla_weights``).
* ``weight_slice_hoisted`` — the once-per-step rank slices hoisted out
  of the layer scan (non-prepacked fast path).
* ``pallas_kernel`` — fused decode kernel invocations.

Evidence target (tests/test_prepack.py): the prepacked Pallas path
traces with ``weight_gather == weight_slice == 0`` and exactly one
``pallas_kernel`` + one ``tree_reduce`` on the cluster axis per
attention layer.
"""
from __future__ import annotations

from collections import Counter
from contextlib import contextmanager

_COUNTS: Counter = Counter()
_ACTIVE: int = 0


def bump(name: str, n: int = 1) -> None:
    """Increment ``name`` when a :func:`counting` context is active."""
    if _ACTIVE:
        _COUNTS[name] += n


@contextmanager
def counting():
    """Enable counters; yields the live Counter (read totals inside or
    right after the block).  Entering the outermost context resets the
    counts; nested contexts share the same Counter."""
    global _ACTIVE
    if _ACTIVE == 0:
        _COUNTS.clear()
    _ACTIVE += 1
    try:
        yield _COUNTS
    finally:
        _ACTIVE -= 1
