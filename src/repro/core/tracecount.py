"""Trace-time operation counters.

Decode hot-loop structure is asserted by counting *call sites as they
trace* (one trace = one compiled step, so trace-time counts are exact
per-step op counts under ``jit``).  The counters are free in production:
``bump`` is a no-op unless a :func:`counting` context is active, and the
instrumented sites only pay a dict lookup at trace time, never at run
time.

Labels used across the codebase:

* ``tree_reduce`` / ``tree_gather`` — cluster-collective tree schedules
  (:mod:`repro.core.primitives`); counted once per collective call, not
  per round.
* ``weight_gather`` — per-step ClusterGather of *weight* segments (the
  Level-2 hoisted gathers the prepack layout eliminates — DESIGN.md §2).
* ``weight_slice`` — per-layer ``lax.dynamic_slice`` weight slicing in
  the train-layout adapters (``_split_token_weights``/``_mla_weights``).
* ``weight_slice_hoisted`` — the once-per-step rank slices hoisted out
  of the layer scan (non-prepacked fast path).
* ``pallas_kernel`` — ``pallas_call`` launch counter: one bump per
  kernel invocation as it traces (a vmapped kernel traces once, so this
  is the per-step launch count under ``jit``).
* ``ffn_pallas_kernel`` — the fused-FFN block-tail megakernel's own
  launches (a subset of ``pallas_kernel``).
* ``psum_model`` — per-step activation all-reduces over the model axis
  (``ParallelCtx.psum_model``: embedding assembly + the per-layer FFN
  combine on the unfused path).
* ``ffn_cluster_reduce`` — the fused ClusterReduce that replaces the
  per-layer FFN ``psum_model`` on the full-block path (DESIGN.md §7).
* ``head_pallas_kernel`` — the fused LM-head/sampling tail kernel's
  launches (a subset of ``pallas_kernel``; kernels/fused_head).
* ``head_cluster_reduce`` — the single (value, index) pair tree reduce
  that merges the fused head's per-shard greedy partials.
* ``lm_head_logits`` — materializations of the ``[B, V_loc]`` logits
  tensor (``models.layers.lm_head_logits``).  The fused head path must
  trace ZERO of these: the logits exist only as VMEM tiles inside the
  kernel, never in HBM.

Evidence targets (tests/test_prepack.py, tests/test_fused_head.py):
the prepacked Pallas path traces with ``weight_gather == weight_slice
== 0`` and exactly one ``pallas_kernel`` + one ``tree_reduce`` on the
cluster axis per attention layer; the FULL-block path (fused FFN)
traces with exactly TWO ``pallas_kernel`` per dense-FFN attention
layer and ``psum_model == 1`` per decode step (the embedding lookup —
zero per-layer activation psums); the fused-head step adds exactly ONE
``head_pallas_kernel`` + ONE ``head_cluster_reduce`` and ZERO
``lm_head_logits`` — embed psum + 2 launches/layer + 1 head launch +
1 head reduce is the complete dense decode step.

* ``finite_guard`` — the per-step integrity sentinel traced into the
  decode/admit steps when ``ServeConfig.check_finite`` is on
  (``serving/engine._finite_violations``): one bump per guarded program,
  proof the guard is IN the compiled step (and absent when the flag is
  off — the bench path must trace zero of these).
* ``kv_fp_update`` — the incremental KV-cache checksum update traced
  into the decode/admit steps when ``ServeConfig.kv_fingerprint`` is on
  (serving/integrity.py): one bump per fingerprinting program, proof
  the SDC accumulator is IN the compiled step (and absent when off).

Besides the trace-time counters, this module hosts the RUNTIME work
counters for ragged decode (:func:`live_attend_blocks`): a pure-jnp
mirror of the kernels' live-block formula
(``fused_decode._live_block_bounds`` + the per-step liveness guard)
that the engine accumulates per slot into ``state["work_blocks"]``
when ``ServeConfig.track_work`` is on.  Trace-time counts prove the
*structure* of a step; these prove the *amount* of attend-step work a
slot actually paid — the scheduler tests assert a retired slot's
counter stops moving while its batch neighbors keep streaming.

A third family, the DETECTION-SIGNAL counters (:func:`record_signal`),
is host-side and always on: the fleet router (serving/router.py) records
one count per integrity probe that fires — labels:

* ``detect_nonfinite`` — the ``check_finite`` sentinel leaf reported a
  non-finite residual/head output for an active slot.
* ``detect_lens_bounds`` — ``cache_lens`` left ``[−1, max_seq]`` or the
  shards disagreed on it.
* ``detect_journal_stale`` — the device ``cache_lens`` diverged from the
  scheduler's host-side journal model (dropped/duplicated admit,
  blackholed replica echoing stale tokens).
* ``detect_journal_mismatch`` — a recovery replay re-emitted a token
  that differs from the journaled stream (divergent replica weights —
  out of the fault model, asserted zero in tests; DESIGN.md §9).
* ``detect_heartbeat`` — the replica raised (killed) inside its step.
* ``replica_failed`` — one per replica the router drained.
* ``detect_kv_fingerprint`` — a KV-cache bit-pattern checksum diverged
  from the device fingerprint leaf (serving/integrity.py): silent data
  corruption in cached K/V, below the non-finite floor.
* ``detect_weight_fingerprint`` — a serve-tree leaf's checksum diverged
  from its prepack-time reference (rotating spot-check).
* ``detect_shadow_recompute`` — the host shadow recompute of a slot's
  winning logit disagreed with the device's ``head_val`` beyond
  tolerance (head-path SDC the checksums cannot see).
* ``replica_healed`` — a weight-SDC replica re-materialized its serve
  layout from the train view, re-verified every fingerprint, and
  rejoined the fleet (serving/router.py).
* ``request_failed`` — a request hit the router's ``max_requeues`` cap
  and was terminally FAILED instead of re-queued (requeue-storm guard).

These are plain host counters (no trace interaction) so chaos tests can
assert detection latency in *scheduler ticks* without parsing events.

A fourth family, the PROBE-OVERHEAD counters (:func:`record_probe`),
accounts what the SDC probes themselves cost: ``probe_ticks`` (one per
monitor probe call) and ``probe_bytes_kv`` / ``probe_bytes_weights`` /
``probe_bytes_shadow`` (host bytes pulled per probe family) — the
bench's ``sdc_sweep.fault_free.probe_bytes_per_tick`` column divides
these out, so per-tick probe overhead is a gated, tracked number.
"""
from __future__ import annotations

from collections import Counter
from contextlib import contextmanager

import jax.numpy as jnp

_COUNTS: Counter = Counter()
_ACTIVE: int = 0


def bump(name: str, n: int = 1) -> None:
    """Increment ``name`` when a :func:`counting` context is active."""
    if _ACTIVE:
        _COUNTS[name] += n


def live_attend_blocks(cache_lens, *, s_blk: int, block_s: int, rank,
                       window: int = 0, ring: bool = False):
    """Per-slot attend-step (KV-block) count for one attention layer.

    Mirrors the Pallas index-map clamp / ``@pl.when`` liveness and the
    XLA path's bucket liveness, per slot: a slot whose rank-local live
    span is empty (``cache_len ≤ pos_base``, including retired slots at
    ``cache_len = −1``) counts ZERO blocks.  ``rank`` is this rank's
    cluster index (traced inside shard_map); ``ring=True`` is the
    wrapped sliding-window layout where only the fill-order upper bound
    applies.  Returns int32 [B] (or a scalar for lockstep input).
    """
    cl = jnp.asarray(cache_lens, jnp.int32)
    blk = min(block_s, s_blk)
    n_blocks = max(1, s_blk // max(blk, 1))
    if ring:
        eff = cl
    else:
        eff = cl - jnp.asarray(rank, jnp.int32) * s_blk
    hi = jnp.clip((eff + blk - 1) // blk - 1, 0, n_blocks - 1)
    if window > 0 and not ring:
        lo = jnp.clip((eff - window) // blk, 0, hi)
    else:
        lo = jnp.zeros_like(hi)
    return jnp.where(eff > 0, hi - lo + 1, 0).astype(jnp.int32)


_SIGNALS: Counter = Counter()


def record_signal(name: str, n: int = 1) -> None:
    """Record a detection-signal firing (always on, host-side — see the
    label list in the module docstring)."""
    _SIGNALS[name] += n


def signal_totals() -> Counter:
    """Snapshot of the detection-signal counters."""
    return Counter(_SIGNALS)


def reset_signals() -> None:
    """Zero the detection-signal counters (test isolation)."""
    _SIGNALS.clear()


_PROBES: Counter = Counter()


def record_probe(name: str, n: int = 1) -> None:
    """Account SDC-probe overhead (always on, host-side — see the
    probe-counter label list in the module docstring)."""
    _PROBES[name] += n


def probe_totals() -> Counter:
    """Snapshot of the probe-overhead counters."""
    return Counter(_PROBES)


def reset_probes() -> None:
    """Zero the probe-overhead counters (test / bench isolation)."""
    _PROBES.clear()


@contextmanager
def counting():
    """Enable counters; yields the live Counter (read totals inside or
    right after the block).  Entering the outermost context resets the
    counts; nested contexts share the same Counter."""
    global _ACTIVE
    if _ACTIVE == 0:
        _COUNTS.clear()
    _ACTIVE += 1
    try:
        yield _COUNTS
    finally:
        _ACTIVE -= 1
