"""ClusterFusion core: cluster collectives, fused dataflows, autotuning."""
from repro.core.primitives import (  # noqa: F401
    SubAxis, axis_index, cluster_flash_combine, cluster_gather,
    cluster_gather_tiled, cluster_reduce, cluster_reduce_pairs,
    cluster_reduce_xla, flash_merge, offchip_reduce, traffic_gather,
    traffic_reduce,
)
from repro.core.dataflow import (  # noqa: F401
    ClusterSpec, KVBlock, MLAWeights, SplitHeadWeights, SplitTokenWeights,
    init_kv_block, mla_attention, split_head_attention, split_token_attention,
    traffic_mla, traffic_split_head, traffic_split_token,
)
from repro.core.autotune import TunePoint, sweep, tune_cluster  # noqa: F401
