"""Deterministic synthetic LM data pipeline with exact-resume semantics.

Stateless index-based generation: batch ``i`` of shard ``d`` is a pure
function of ``(seed, step, shard)`` — restart at step k reproduces the
exact token stream with no pipeline state in the checkpoint (the
fault-tolerance property production pipelines get from tf.data snapshot /
Grain index shuffling; here it is free by construction).

Token distribution: Zipf over the vocab with a repeating-ngram overlay so
tiny models can actually reduce loss (pure iid uniform tokens have no
learnable structure).  A memory-mapped ``.bin`` corpus is used instead
when provided.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_per_shard: int
    seed: int = 0
    zipf_a: float = 1.2
    corpus_path: Optional[str] = None     # memmap uint16/uint32 tokens


class SyntheticLM:
    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self._corpus = None
        if cfg.corpus_path and os.path.exists(cfg.corpus_path):
            dt = np.uint32 if cfg.vocab_size > 65535 else np.uint16
            self._corpus = np.memmap(cfg.corpus_path, dtype=dt, mode="r")
        # precompute zipf cdf once
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(p / p.sum())

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The batch this shard consumes at ``step`` (pure function)."""
        cfg = self.cfg
        if self._corpus is not None:
            n_tok = cfg.batch_per_shard * (cfg.seq_len + 1)
            stride = n_tok * self.num_shards
            off = (step * stride + self.shard * n_tok) \
                % max(1, len(self._corpus) - n_tok)
            flat = np.asarray(self._corpus[off: off + n_tok], np.int32)
        else:
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, self.shard]))
            n_tok = cfg.batch_per_shard * (cfg.seq_len + 1)
            u = rng.random(n_tok)
            flat = np.searchsorted(self._cdf, u).astype(np.int32)
            # learnable overlay: deterministic bigram echo every 4th token
            flat[3::4] = (flat[2::4][: len(flat[3::4])] * 7 + 13) \
                % cfg.vocab_size
        toks = flat.reshape(cfg.batch_per_shard, cfg.seq_len + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def frontend_embeds_at(step: int, shard: int, batch: int, positions: int,
                       feat: int, seed: int = 0) -> np.ndarray:
    """Deterministic stub frontend features (audio frames / ViT patches)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed + 7919, step, shard]))
    return rng.standard_normal((batch, positions, feat)).astype(np.float32)
