"""Llama2-7B — the paper's primary evaluation model (MHA).

[arXiv:2307.09288; hf] 32L d_model=4096 32H (MHA kv=32) d_ff=11008
vocab=32000.
"""
from repro.configs.base import ModelConfig, register


@register("llama2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,
        d_ff=11008,
        vocab_size=32000,
        ffn_act="silu",
        ffn_gated=True,
        source="[arXiv:2307.09288; hf]",
    )
