"""Configuration system for the ClusterFusion-TPU framework.

Every architecture is described by a :class:`ModelConfig`; every workload
shape by a :class:`ShapeConfig`.  The registry maps ``--arch`` ids to config
factories, and every config has a ``reduced()`` variant used by CPU smoke
tests (full configs are only ever lowered via ShapeDtypeStructs in the
dry-run, never allocated).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "attn_global"        # full causal attention
ATTN_LOCAL = "attn_local"          # sliding-window causal attention
RECURRENT = "recurrent"            # RG-LRU (Griffin) block
RWKV6 = "rwkv6"                    # RWKV-6 time-mix block
BLOCK_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV6)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int
    top_k: int
    # d_ff of each expert (the dense d_ff field is ignored for MoE layers
    # unless dense_ff_residual is set, in which case it sizes the dense path).
    expert_d_ff: int
    # Snowflake-Arctic style: a dense FFN residual in parallel with the MoE.
    dense_ff_residual: bool = False
    dense_residual_d_ff: int = 0
    # Router options
    router_softcap: float = 0.0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek Multi-head Latent Attention configuration (paper Alg. 4)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 => full-rank Q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: supplies precomputed embeddings.

    ``input_specs`` yields (num_frames_or_patches, feature_dim) bf16
    embeddings instead of raw audio/pixels — per the assignment contract.
    """

    kind: str                      # "audio" | "vision"
    num_positions: int             # frames / patches fed to the backbone
    feature_dim: int               # frontend output dim (projected to d_model)


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (seamless-m4t)."""

    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads
    # Block pattern, repeated to cover n_layers (remainder truncated from the
    # pattern head).  Dense transformers: (ATTN_GLOBAL,).  Gemma-2:
    # (ATTN_LOCAL, ATTN_GLOBAL).  Griffin: (RECURRENT, RECURRENT, ATTN_LOCAL).
    block_pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    sliding_window: int = 4096     # for ATTN_LOCAL blocks
    # Attention options
    qkv_bias: bool = False         # Qwen-2 style
    logit_softcap: float = 0.0     # Gemma-2 final-logit softcap
    attn_softcap: float = 0.0      # Gemma-2 attention softcap
    rope_theta: float = 10000.0
    # Recurrent (RG-LRU) options
    rglru_d_state: int = 0         # 0 => d_model; Griffin uses d_model
    conv1d_width: int = 4
    # RWKV-6 options
    rwkv_head_dim: int = 64
    # FFN
    ffn_act: str = "silu"          # silu | gelu | gelu_tanh
    ffn_gated: bool = True
    # Extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    frontend: Optional[FrontendConfig] = None
    encoder: Optional[EncoderConfig] = None
    tie_embeddings: bool = False
    use_post_norm: bool = False    # Gemma-2 sandwich norm
    norm_eps: float = 1e-6
    # citation string: [source; verified-tier]
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        reps = math.ceil(self.n_layers / len(pat))
        return tuple((pat * reps)[: self.n_layers])

    @property
    def is_attention_free(self) -> bool:
        return all(k in (RECURRENT, RWKV6) for k in self.layer_kinds)

    @property
    def has_full_attention(self) -> bool:
        return any(k == ATTN_GLOBAL for k in self.layer_kinds)

    @property
    def max_decode_context(self) -> int:
        """Largest KV context any single layer must hold at decode time.

        Attention-free / local-attention layers bound their own context.
        """
        ctx = 0
        for k in self.layer_kinds:
            if k == ATTN_GLOBAL:
                return -1  # unbounded (grows with sequence)
            if k == ATTN_LOCAL:
                ctx = max(ctx, self.sliding_window)
        return ctx

    def param_count(self) -> int:
        """Analytical parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        for kind in self.layer_kinds:
            total += 2 * d  # two RMSNorm scales
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                if self.mla is not None:
                    m = self.mla
                    qdim = nq * (m.nope_head_dim + m.rope_head_dim)
                    total += d * qdim                       # W_Q (full rank)
                    total += d * (m.kv_lora_rank + m.rope_head_dim)  # W_DKV
                    total += m.kv_lora_rank * nq * (m.nope_head_dim + m.v_head_dim)
                    total += nq * m.v_head_dim * d          # W_O
                else:
                    total += d * (nq * hd) + 2 * d * (nkv * hd)  # QKV
                    total += (nq * hd) * d                   # O
                    if self.qkv_bias:
                        total += (nq + 2 * nkv) * hd
            elif kind == RECURRENT:
                ds = self.rglru_d_state or d
                total += 2 * d * ds          # input/gate linear
                total += ds * self.conv1d_width
                total += 2 * ds              # RG-LRU a/gate params
                total += 2 * ds * ds // max(1, ds // ds)  # recurrent gates (approx)
                total += ds * d              # out proj
            elif kind == RWKV6:
                total += 4 * d * d           # r,k,v,g projections
                total += d * d               # output proj
                total += 6 * d               # time-mix/decacy params (approx)
            # FFN
            if self.moe is not None and kind != RECURRENT:
                m = self.moe
                per_expert = (3 if self.ffn_gated else 2) * d * m.expert_d_ff
                total += m.num_experts * per_expert
                total += d * m.num_experts   # router
                if m.dense_ff_residual:
                    total += (3 if self.ffn_gated else 2) * d * m.dense_residual_d_ff
            else:
                total += (3 if self.ffn_gated else 2) * d * self.d_ff
        if self.encoder is not None:
            e = self.encoder
            ehd = d // e.n_heads
            per = 2 * d + d * (e.n_heads * ehd) + 2 * d * (e.n_kv_heads * ehd) \
                + (e.n_heads * ehd) * d + (3 if self.ffn_gated else 2) * d * e.d_ff
            total += e.n_layers * per
            # decoder cross-attention (one per decoder layer)
            total += self.n_layers * (d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d + d)
        if self.frontend is not None:
            total += self.frontend.feature_dim * d  # projection into backbone
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        per_expert = (3 if self.ffn_gated else 2) * self.d_model * m.expert_d_ff
        inactive = (m.num_experts - m.top_k) * per_expert * sum(
            1 for k in self.layer_kinds if k != RECURRENT
        )
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    """The shape cells that apply to an architecture.

    ``long_500k`` requires sub-quadratic context handling: run only when no
    layer keeps an unbounded global-attention KV cache (SSM / hybrid /
    local-attention archs).  Skips are recorded in DESIGN.md §4.
    """
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and cfg.max_decode_context < 0:
            continue  # pure/partial full-attention arch: unbounded KV at 500k
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------
def reduced(cfg: ModelConfig, *, d_model: int = 128, n_layers: int = 0,
            vocab: int = 512) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving its structure.

    Keeps the family, block pattern, GQA ratio, MoE top-k / dense-residual
    topology, MLA/frontend/encoder presence — just with tiny dims.
    """
    pat = cfg.block_pattern
    nl = n_layers or max(len(pat), 2)
    # keep the q:kv ratio
    n_heads = 4
    n_kv = max(1, n_heads // max(1, cfg.q_per_kv))
    head_dim = max(8, d_model // n_heads)
    kw = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        n_layers=nl,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=d_model * 3,
        vocab_size=vocab,
        block_pattern=pat,
        sliding_window=min(cfg.sliding_window, 64),
        qkv_bias=cfg.qkv_bias,
        logit_softcap=cfg.logit_softcap,
        attn_softcap=cfg.attn_softcap,
        ffn_act=cfg.ffn_act,
        ffn_gated=cfg.ffn_gated,
        tie_embeddings=cfg.tie_embeddings,
        rglru_d_state=0,
        conv1d_width=cfg.conv1d_width,
        rwkv_head_dim=16,
        source=cfg.source,
    )
    if cfg.moe is not None:
        # capacity_factor = E ⇒ no token ever drops: capacity dropping is
        # data-layout dependent (per-shard cumsum order), which would break
        # the sharded-vs-oracle equivalence smoke tests.  Dropping semantics
        # get their own dedicated unit test.
        kw["moe"] = MoEConfig(
            num_experts=8, top_k=min(2, cfg.moe.top_k),
            expert_d_ff=d_model * 2,
            dense_ff_residual=cfg.moe.dense_ff_residual,
            dense_residual_d_ff=d_model if cfg.moe.dense_ff_residual else 0,
            capacity_factor=8.0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, rope_head_dim=8,
                              nope_head_dim=16, v_head_dim=16)
    if cfg.frontend is not None:
        kw["frontend"] = FrontendConfig(cfg.frontend.kind, 16, 64)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, n_heads=4, n_kv_heads=4,
                                      d_ff=d_model * 3)
    return ModelConfig(**kw)
