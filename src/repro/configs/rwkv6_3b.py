"""RWKV-6 (Finch) 3B: attention-free, data-dependent decay.

[arXiv:2404.05892; hf] 32L d_model=2560 d_ff=8960 vocab=65536.
The ClusterFusion head-cluster dataflow is inapplicable (no QKV/KV-cache
structure) — see DESIGN.md §4; the WKV recurrence has its own fused
Pallas kernel instead.
"""
from repro.configs.base import RWKV6, ModelConfig, register


@register("rwkv6-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,            # 2560 / rwkv_head_dim(64)
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65536,
        block_pattern=(RWKV6,),
        rwkv_head_dim=64,
        ffn_act="relu2",
        ffn_gated=False,
        source="[arXiv:2404.05892; hf]",
    )
