"""SeamlessM4T-medium: encoder-decoder, multimodal (audio frontend stub).

[arXiv:2308.11596; hf] 12L decoder, d_model=1024 16H (kv=16) d_ff=4096
vocab=256206.  The speech frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (assignment contract).
"""
from repro.configs.base import (EncoderConfig, FrontendConfig, ModelConfig,
                                register)


@register("seamless-m4t-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        ffn_act="relu",
        ffn_gated=False,
        encoder=EncoderConfig(n_layers=12, n_heads=16, n_kv_heads=16,
                              d_ff=4096),
        frontend=FrontendConfig(kind="audio", num_positions=1024,
                                feature_dim=1024),
        source="[arXiv:2308.11596; hf]",
    )
