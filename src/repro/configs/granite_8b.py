"""IBM Granite-8B (code): llama-architecture dense GQA.

[arXiv:2405.04324; hf] 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152.
"""
from repro.configs.base import ModelConfig, register


@register("granite-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=49152,
        ffn_act="silu",
        ffn_gated=True,
        tie_embeddings=True,
        source="[arXiv:2405.04324; hf]",
    )
