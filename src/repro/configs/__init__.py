"""Architecture registry: importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, RWKV6,
    EncoderConfig, FrontendConfig, MLAConfig, MoEConfig, ModelConfig,
    SHAPES, ShapeConfig, get_config, list_archs, reduced, register, shapes_for,
)

# Assigned architectures (public pool) ------------------------------------
from repro.configs import recurrentgemma_9b  # noqa: F401
from repro.configs import kimi_k2_1t_a32b  # noqa: F401
from repro.configs import arctic_480b  # noqa: F401
from repro.configs import seamless_m4t_medium  # noqa: F401
from repro.configs import granite_8b  # noqa: F401
from repro.configs import qwen2_72b  # noqa: F401
from repro.configs import minitron_4b  # noqa: F401
from repro.configs import gemma2_27b  # noqa: F401
from repro.configs import internvl2_2b  # noqa: F401
from repro.configs import rwkv6_3b  # noqa: F401

# The paper's own evaluation models ---------------------------------------
from repro.configs import llama2_7b  # noqa: F401
from repro.configs import deepseek_v2_lite  # noqa: F401
