"""InternVL2-2B: InternViT frontend (stub) + InternLM2-1.8B backbone.

[arXiv:2404.16821; hf] 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  The vision frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (assignment contract).
"""
from repro.configs.base import FrontendConfig, ModelConfig, register


@register("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        ffn_act="silu",
        ffn_gated=True,
        frontend=FrontendConfig(kind="vision", num_positions=256,
                                feature_dim=1024),
        source="[arXiv:2404.16821; hf]",
    )
