"""Snowflake Arctic 480B: 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2 with a dense FFN residual branch.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("arctic-480b")
def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864,
                      dense_ff_residual=True, dense_residual_d_ff=4864,
                      capacity_factor=1.25),
        ffn_act="silu",
        ffn_gated=True,
        source="[hf:Snowflake/snowflake-arctic-base; hf]",
    )
