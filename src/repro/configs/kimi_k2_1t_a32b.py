"""Kimi K2 — trillion-param MoE (paper-table config).

[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8)
expert d_ff=2048 vocab=163840, MoE 384 experts top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("kimi-k2-1t-a32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=112,
        d_ff=2048,
        vocab_size=163840,
        moe=MoEConfig(num_experts=384, top_k=8, expert_d_ff=2048,
                      capacity_factor=1.25),
        ffn_act="silu",
        ffn_gated=True,
        source="[arXiv:2501.kimi2; unverified]",
    )
