"""Gemma-2 27B: local+global alternating attention, logit softcap.

[arXiv:2408.00118; hf] 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000.  Pattern (local, global); attn softcap 50, final logit
softcap 30; GeGLU FFN.
"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig, register


@register("gemma2-27b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        block_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
        sliding_window=4096,
        attn_softcap=50.0,
        logit_softcap=30.0,
        ffn_act="gelu_tanh",
        ffn_gated=True,
        use_post_norm=True,
        tie_embeddings=True,
        source="[arXiv:2408.00118; hf]",
    )
