"""RecurrentGemma-9B (Griffin): RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1 => MQA)
d_ff=12288 vocab=256000.  Block pattern: (recurrent, recurrent, local-attn).
"""
from repro.configs.base import (ATTN_LOCAL, RECURRENT, ModelConfig, register)


@register("recurrentgemma-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        block_pattern=(RECURRENT, RECURRENT, ATTN_LOCAL),
        sliding_window=2048,
        ffn_act="gelu_tanh",
        ffn_gated=True,
        rglru_d_state=4096,
        conv1d_width=4,
        tie_embeddings=True,
        source="[arXiv:2402.19427; unverified]",
    )
