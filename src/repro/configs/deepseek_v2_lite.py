"""DeepSeek-V2-Lite — the paper's MLA evaluation model.

[arXiv:2405.04434; hf] 27L d_model=2048 16H MLA (kv_lora_rank=512,
rope_head_dim=64, nope=128, v=128), MoE 64 experts top-6, expert d_ff=1408,
vocab=102400.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register


@register("deepseek-v2-lite")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,          # MLA: all heads share one latent KV
        head_dim=128,
        d_ff=10944,
        vocab_size=102400,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                      capacity_factor=1.25),
        ffn_act="silu",
        ffn_gated=True,
        source="[arXiv:2405.04434; hf]",
    )
