"""JAX version-compat layer.

The codebase targets the current JAX API surface; the pinned runtime JAX
(0.4.x) predates three renames we rely on:

* ``jax.shard_map``            — lives in ``jax.experimental.shard_map``
  and spells the replication-check kwarg ``check_rep`` (now ``check_vma``);
* ``jax.make_mesh(axis_types=...)`` — the kwarg does not exist yet (all
  meshes are "auto" in 0.4.x, so dropping it is semantics-preserving);
* ``jax.sharding.AxisType``    — the enum the ``axis_types`` callers name.

Everything funnels through this module: import :func:`shard_map` /
:func:`make_mesh` directly, or import the module for its side effect —
:func:`install` patches the missing names onto the ``jax`` namespace so
inline test bodies written against the new API run unchanged on the
pinned version.  On a new-enough JAX every shim is a passthrough.
"""
from __future__ import annotations

import inspect
from enum import Enum
from functools import wraps

import jax

# ---------------------------------------------------------------------------
# shard_map: jax.shard_map + check_vma  ->  experimental + check_rep
# ---------------------------------------------------------------------------
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SM_PARAMS = set(inspect.signature(_shard_map_impl).parameters)


@wraps(_shard_map_impl)
def shard_map(f, /, *args, **kwargs):
    if "check_vma" in kwargs and "check_vma" not in _SM_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if "check_rep" in kwargs and "check_rep" not in _SM_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map_impl(f, *args, **kwargs)


# ---------------------------------------------------------------------------
# make_mesh: tolerate axis_types on JAX versions without the kwarg
# ---------------------------------------------------------------------------
_make_mesh_impl = jax.make_mesh
_MM_HAS_AXIS_TYPES = "axis_types" in inspect.signature(_make_mesh_impl).parameters


@wraps(_make_mesh_impl)
def make_mesh(axis_shapes, axis_names, *args, **kwargs):
    if not _MM_HAS_AXIS_TYPES:
        kwargs.pop("axis_types", None)
    return _make_mesh_impl(axis_shapes, axis_names, *args, **kwargs)


class _AxisTypeStub(Enum):
    """Placeholder for ``jax.sharding.AxisType`` (values are ignored by the
    tolerant :func:`make_mesh` on old JAX)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _axis_size(axis_name):
    """``lax.axis_size`` fallback: psum of a literal folds to the size."""
    return jax.lax.psum(1, axis_name)


def install() -> None:
    """Patch missing new-API names onto the ``jax`` namespace (idempotent)."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    if not _MM_HAS_AXIS_TYPES:
        jax.make_mesh = make_mesh
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisTypeStub
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = _axis_size
    # Sharded-init correctness: the launch paths jit their RNG inits with
    # ``out_shardings`` and rely on values being identical to the eager /
    # single-device oracle.  Partitionable threefry guarantees that; it is
    # the default on current JAX but off on the pinned 0.4.x.
    try:
        jax.config.update("jax_threefry_partitionable", True)
    except Exception:  # removed flag on future JAX (always-on) — fine
        pass


install()
