"""The manual-SPMD training step.

One ``shard_map`` over the whole mesh; inside (per device):

  1. microbatch loop (grad accumulation, ``lax.scan`` over µbatches)
     around ``jax.grad`` of the local loss (model collectives — psum over
     the model axis, ClusterGather over the cluster sub-axis — are *inside*
     the differentiated function, so their transposes are generated
     automatically);
  2. gradient all-reduce over the data axes — plain bf16/f32 psum or int8
     compressed with error feedback (``--grad-compress``);
  3. ZeRO-1 optimizer update: each data-rank updates a 1/D slice of the
     optimizer state (sliced on the leading device-major axis when
     divisible, else replicated), then the updated params are
     ``all_gather``'d back over the data axis.

Loss normalization: global mean over valid tokens (psum'd counts), so
gradient scale is batch-size invariant.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.compression import compressed_psum, init_ef_state
from repro.models.ctx import ParallelCtx
from repro.models.transformer import loss_fn, sync_grads, unwrap_local
from repro.training.optimizer import (OptConfig, clip_by_global_norm,
                                      opt_init, opt_update)

PyTree = Any


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    grad_compress: bool = False
    zero1: bool = True
    remat: bool = True
    fsdp: bool = False             # ZeRO-3: params dp-sliced, gathered at use
    grad_dtype: str = "f32"        # f32 | bf16 (accumulator dtype at scale)


def _split_micro(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    return {k: (v.reshape((n, v.shape[0] // n) + v.shape[1:])
                if v is not None else None)
            for k, v in batch.items()}


def local_loss_and_grad(ctx: ParallelCtx, cfg: ModelConfig,
                        params_dm: PyTree, batch: Dict[str, jax.Array],
                        n_micro: int, remat: bool, fsdp=None,
                        grad_dtype=jnp.float32):
    """Microbatched (sum_nll, sum_cnt, grads) on this device's shard.

    With ``fsdp=(ax_tree, dp_axes)`` the gradients of dp-sliced leaves come
    back sliced AND dp-summed (the transpose of the gather is a
    reduce-scatter)."""

    def loss_of(p_dm, mb):
        local = unwrap_local(p_dm)
        nll, cnt = loss_fn(ctx, cfg, local, mb, remat=remat, fsdp=fsdp)
        return nll, cnt

    def one_micro(carry, mb):
        nll_a, cnt_a, g_a = carry
        (nll, cnt), g = jax.value_and_grad(
            lambda p: loss_of(p, mb), has_aux=True)(params_dm)
        g_a = jax.tree.map(lambda a, b: a + b.astype(grad_dtype), g_a, g)
        return (nll_a + nll, cnt_a + cnt, g_a), None

    if n_micro == 1:
        (nll, cnt), grads = jax.value_and_grad(
            lambda p: loss_of(p, batch), has_aux=True)(params_dm)
        return nll, cnt, jax.tree.map(lambda g: g.astype(grad_dtype), grads)

    micro = _split_micro(batch, n_micro)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params_dm)
    (nll, cnt, grads), _ = lax.scan(
        one_micro, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
                    g0), micro)
    return nll, cnt, grads


# ---------------------------------------------------------------------------
# ZeRO-1 slicing helpers (leading axis = device-major dim, size 1 inside
# shard_map — so we slice on the FIRST dim of size divisible by dp_size)
# ---------------------------------------------------------------------------
def _z_axis(leaf, dp: int) -> int:
    for ax in range(leaf.ndim):
        if leaf.shape[ax] % dp == 0 and leaf.shape[ax] >= dp:
            return ax
    return -1


def zero1_slice(tree: PyTree, dp: int, rank) -> PyTree:
    def sl(leaf):
        ax = _z_axis(leaf, dp)
        if ax < 0:
            return leaf
        size = leaf.shape[ax] // dp
        return lax.dynamic_slice_in_dim(leaf, rank * size, size, axis=ax)

    return jax.tree.map(sl, tree)


def zero1_allgather(tree_sliced: PyTree, full_like: PyTree, dp: int,
                    axes) -> PyTree:
    def ag(s, f):
        ax = _z_axis(f, dp)
        if ax < 0:
            return s
        return lax.all_gather(s, axes, axis=ax, tiled=True)

    return jax.tree.map(ag, tree_sliced, full_like)


def make_train_step(ctx: ParallelCtx, cfg: ModelConfig, tcfg: TrainConfig,
                    dp_axes: Tuple[str, ...], dp_size: int,
                    sync_tree=None, fsdp_ax=None):
    """Returns train_step(params_dm, opt_state, ef_state, batch) →
    (params, opt_state, ef_state, metrics).  Call inside shard_map.

    ``sync_tree`` — output of ``grad_sync_tree``: subgroup psums for
    replicated-leaf gradients (Megatron layernorm-grad sync, generalized).
    """

    fsdp_info = None
    fsdp_mask = None
    if tcfg.fsdp and fsdp_ax is not None:
        fsdp_info = (fsdp_ax, dp_axes)
        flat_p = jax.tree.leaves(
            jax.tree.map(lambda *_: 0, jax.tree.structure(fsdp_ax)))  # unused

    def _is_fsdp_leaf_tree(params_dm):
        flat, td = jax.tree.flatten(params_dm)
        axf = td.flatten_up_to(fsdp_ax)
        return td.unflatten([a is not None for a in axf])

    def step(params_dm, opt_state, ef_state, batch):
        gdt = jnp.bfloat16 if tcfg.grad_dtype == "bf16" else jnp.float32
        nll, cnt, grads = local_loss_and_grad(
            ctx, cfg, params_dm, batch, tcfg.microbatches, tcfg.remat,
            fsdp=fsdp_info, grad_dtype=gdt)
        nll_g = lax.psum(nll, dp_axes)
        cnt_g = lax.psum(cnt, dp_axes)
        # grads currently hold d(sum_nll_local)/dp — convert to global mean
        grads = jax.tree.map(lambda g: g / jnp.maximum(cnt_g, 1.0), grads)
        if fsdp_info is not None:
            is_f = _is_fsdp_leaf_tree(params_dm)
        else:
            is_f = jax.tree.map(lambda _: False, params_dm)
        # dp all-reduce: FSDP leaves are already dp-summed (reduce-scatter
        # from the gather transpose) — only the rest needs the psum
        if tcfg.grad_compress:
            grads_nf, ef_state = compressed_psum(grads, ef_state, dp_axes,
                                                 n_ranks=1)
            flat_g, td = jax.tree.flatten(grads)
            flat_n = td.flatten_up_to(grads_nf)
            flat_f = td.flatten_up_to(is_f)
            grads = td.unflatten([g if f else n for g, n, f
                                  in zip(flat_g, flat_n, flat_f)])
        else:
            flat_g, td = jax.tree.flatten(grads)
            flat_f = td.flatten_up_to(is_f)
            grads = td.unflatten([
                g if f else lax.psum(g, dp_axes)
                for g, f in zip(flat_g, flat_f)])
        if sync_tree is not None:
            grads = sync_grads(ctx, grads, sync_tree)
        # gradient norm: FSDP leaves contribute their dp-summed-slice norm
        # psum'd over dp; the rest once; then psum over model so every rank
        # clips identically
        flat_g, td = jax.tree.flatten(grads)
        flat_f = td.flatten_up_to(is_f)
        sq_f = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                   for g, f in zip(flat_g, flat_f) if f) + 0.0
        sq_n = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                   for g, f in zip(flat_g, flat_f) if not f) + 0.0
        sq = lax.psum(jnp.asarray(sq_f, jnp.float32), dp_axes) + sq_n
        gnorm = jnp.sqrt(ctx.psum_model(sq))
        if tcfg.opt.grad_clip > 0:
            grads = clip_by_global_norm(grads, tcfg.opt.grad_clip, gnorm)

        if dp_size > 1 and (tcfg.zero1 or fsdp_info is not None):
            rank = lax.axis_index(dp_axes)
            g_sl = _mixed_slice(grads, is_f, dp_size, rank, tcfg.zero1)
            p_sl = _mixed_slice(params_dm, is_f, dp_size, rank, tcfg.zero1)
            new_p_sl, new_opt = opt_update(tcfg.opt, g_sl, opt_state, p_sl)
            new_params = _mixed_allgather(new_p_sl, params_dm, is_f,
                                          dp_size, dp_axes, tcfg.zero1)
        else:
            new_params, new_opt = opt_update(tcfg.opt, grads, opt_state,
                                             params_dm)
        metrics = {"loss": nll_g / jnp.maximum(cnt_g, 1.0),
                   "grad_norm": gnorm,
                   "tokens": cnt_g}
        return new_params, new_opt, ef_state, metrics

    return step


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, params_dm: PyTree,
                     dp_size: int, rank=None, fsdp_ax=None):
    """Optimizer state over the ZeRO-1 slice (or full params).

    With FSDP, dp-sliced leaves are already opt-slice-shaped; only the
    rest gets the ZeRO-1 slice."""
    if dp_size > 1 and rank is not None and (tcfg.zero1 or tcfg.fsdp):
        if tcfg.fsdp and fsdp_ax is not None:
            flat, td = jax.tree.flatten(params_dm)
            axf = td.flatten_up_to(fsdp_ax)
            is_f = td.unflatten([a is not None for a in axf])
            params_for_opt = _mixed_slice(params_dm, is_f, dp_size, rank,
                                          tcfg.zero1)
        else:
            params_for_opt = zero1_slice(params_dm, dp_size, rank)
    else:
        params_for_opt = params_dm
    opt_state = opt_init(tcfg.opt, params_for_opt)
    # error-feedback residuals live on the FULL gradient (compression
    # happens before the ZeRO-1 slice)
    ef = init_ef_state(params_dm) if tcfg.grad_compress else None
    return opt_state, ef


def _mixed_slice(tree: PyTree, is_fsdp: PyTree, dp: int, rank,
                 zero1: bool) -> PyTree:
    """FSDP leaves pass through (already sliced); the rest gets the ZeRO-1
    slice (or passes through when zero1 is off)."""
    flat, td = jax.tree.flatten(tree)
    flat_f = td.flatten_up_to(is_fsdp)
    out = []
    for leaf, f in zip(flat, flat_f):
        if f or not zero1:
            out.append(leaf)
        else:
            ax = _z_axis(leaf, dp)
            if ax < 0:
                out.append(leaf)
            else:
                size = leaf.shape[ax] // dp
                out.append(lax.dynamic_slice_in_dim(leaf, rank * size, size,
                                                    axis=ax))
    return td.unflatten(out)


def _mixed_allgather(tree_sliced: PyTree, full_like: PyTree, is_fsdp: PyTree,
                     dp: int, axes, zero1: bool) -> PyTree:
    """FSDP leaves STAY sliced; ZeRO-1 leaves gather back to full."""
    flat_s, td = jax.tree.flatten(tree_sliced)
    flat_full = td.flatten_up_to(full_like)
    flat_f = td.flatten_up_to(is_fsdp)
    out = []
    for s, fl, f in zip(flat_s, flat_full, flat_f):
        if f or not zero1:
            out.append(s)
        else:
            ax = _z_axis(fl, dp)
            out.append(s if ax < 0
                       else lax.all_gather(s, axes, axis=ax, tiled=True))
    return td.unflatten(out)
