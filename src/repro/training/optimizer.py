"""Optimizers (pytree-based, no external deps): AdamW and Adafactor.

Adafactor matters at the top of our architecture pool: kimi-k2's 1T
parameters cannot afford 8 bytes/param of Adam moments on 512 v5e chips
(see EXPERIMENTS.md §Dry-run memory table) — factored second moments cut
optimizer state to ~1.05 copies.

Both optimizers support ZeRO-1 slicing (the train step shards their state
over the data axis; see train_step.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    # adafactor
    decay_offset: int = 0
    min_dim_size_to_factor: int = 128


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree
    step: jax.Array


class AdafactorState(NamedTuple):
    vr: PyTree                     # row second moments (or full v)
    vc: PyTree                     # col second moments (or empty)
    step: jax.Array


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float,
                        pre_norm: Optional[jax.Array] = None) -> PyTree:
    n = pre_norm if pre_norm is not None else global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params: PyTree) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params),
                      step=jnp.zeros((), jnp.int32))


def adamw_update(cfg: OptConfig, grads: PyTree, state: AdamWState,
                 params: PyTree) -> Tuple[PyTree, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    res = [upd(g, m, v, p) for g, m, v, p
           in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([r[0] for r in res])
    new_m = treedef.unflatten([r[1] for r in res])
    new_v = treedef.unflatten([r[2] for r in res])
    return new_p, AdamWState(mu=new_m, nu=new_v, step=step)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------
def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128


def adafactor_init(params: PyTree) -> AdafactorState:
    def vr(p):
        if _factorable(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        if _factorable(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params),
                          step=jnp.zeros((), jnp.int32))


def adafactor_update(cfg: OptConfig, grads: PyTree, state: AdafactorState,
                     params: PyTree) -> Tuple[PyTree, AdafactorState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + 1e-30
        if _factorable(p):
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            v_hat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
        else:
            vr = beta2 * vr + (1 - beta2) * g2
            v_hat = vr
        u = g / jnp.sqrt(v_hat + cfg.eps)
        # update clipping (RMS ≤ 1) per the paper
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype), vr, vc

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.vr)
    flat_c = treedef.flatten_up_to(state.vc)
    flat_p = treedef.flatten_up_to(params)
    res = [upd(g, r, c, p) for g, r, c, p
           in zip(flat_g, flat_r, flat_c, flat_p)]
    new_p = treedef.unflatten([r[0] for r in res])
    new_r = treedef.unflatten([r[1] for r in res])
    new_c = treedef.unflatten([r[2] for r in res])
    return new_p, AdafactorState(vr=new_r, vc=new_c, step=step)


def opt_init(cfg: OptConfig, params: PyTree):
    return (adamw_init if cfg.name == "adamw" else adafactor_init)(params)


def opt_update(cfg: OptConfig, grads, state, params):
    fn = adamw_update if cfg.name == "adamw" else adafactor_update
    return fn(cfg, grads, state, params)
