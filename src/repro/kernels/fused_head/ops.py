"""Jitted public wrapper for the fused LM-head/sampling tail kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fused_head.fused_head import fused_head_block
from repro.kernels.fused_head.ref import fused_head_ref


@partial(jax.jit, static_argnames=("eps", "logit_softcap", "block_v", "k",
                                   "interpret", "use_ref"))
def fused_head(x, table, ln, *, eps=1e-6, logit_softcap=0.0, block_v=1024,
               k=1, interpret=False, use_ref=False):
    if use_ref:
        return fused_head_ref(x, table, ln, eps=eps,
                              logit_softcap=logit_softcap, k=k)
    return fused_head_block(x, table, ln, eps=eps,
                            logit_softcap=logit_softcap, block_v=block_v,
                            k=k, interpret=interpret)
