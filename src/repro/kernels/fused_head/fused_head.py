"""Fused LM-head/sampling tail: final RMSNorm + vocab-tiled logits +
softcap + streaming greedy partials in ONE ``pallas_call`` (DESIGN.md §7).

After the last fused layer, the decode step still ended with a loose XLA
tail: final ``rms_norm``, a full ``[B, V_loc]`` logits tensor
materialized in HBM, ``softcap``, and the local max/argmax feeding
``greedy_sample``'s (value, index) tree reduce.  The logits tensor is
the single largest activation a decode step writes — and it is never
needed: greedy sampling only consumes the per-slot running
``(max_value, argmax_index)``.  This kernel runs the whole tail per
vocab shard:

* grid = (V_loc / block_v,), sequential.  Step 0 additionally computes
  the *prologue* in VMEM scratch: the final RMSNorm of the raw residual
  stream ``h = rms(x, ln)`` with a model-dtype round-trip, so the fused
  value is bit-identical to the unfused ``rms_norm`` (the same contract
  as the in-kernel ``ln1`` of the fused attention kernels).
* every step streams one ``[block_v, D]`` tile of the (possibly tied)
  embedding table, computes the logit tile ``h @ tileᵀ`` in f32 —
  exactly ``lm_head_logits``'s pinned f32 staging, so fused and unfused
  logits are bit-identical — applies ``logit_softcap`` in-tile (f32),
  and folds the tile's ``(max, argmax)`` into ``[B]`` running scratch;
  the ``[B, V]`` logits NEVER exist outside one VMEM tile.
* the last step writes the per-shard ``(max_value, argmax_local_index)``
  partials — two ``[B, 1]`` vectors, the only HBM output.

**Tie-breaking.**  Within a tile the argmax takes the LOWEST index
among equal maxima (``jnp.argmax`` semantics); across tiles the merge
is strictly ``>``, so earlier tiles win ties — together: lowest local
index among the shard's maxima, exactly the unfused
``jnp.argmax(logits)``.  The caller lifts the local index to the
global vocab (``+ shard · V_loc``) and merges shards with ONE tree
ClusterReduce on (value, index) pairs using the same
lowest-index-wins operator (``engine._greedy_pair_merge``), so the
fused tail reproduces ``greedy_sample`` token-exactly.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tracecount
from repro.kernels import tpu_compiler_params

_INT32_MAX = 2 ** 31 - 1


def _kernel(x_ref, tab_ref, ln_ref,
            mx_ref, ix_ref,
            h_s, m_s, i_s,
            *, n_v: int, bv: int, eps: float, cap: float):
    j = pl.program_id(0)

    # ---------------- prologue: final RMSNorm in VMEM -------------------
    @pl.when(j == 0)
    def _prologue():
        xf = x_ref[...].astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        h = xf * jax.lax.rsqrt(var + eps) \
            * (1.0 + ln_ref[...].astype(jnp.float32))
        # model-dtype round-trip: bit-identical to the unfused rms_norm
        h_s[...] = h.astype(x_ref.dtype).astype(jnp.float32)
        m_s[...] = jnp.full_like(m_s[...], -jnp.inf)
        i_s[...] = jnp.zeros_like(i_s[...])

    # ---------------- one vocab tile per grid step ----------------------
    # logits stay in f32, matching `lm_head_logits`'s pinned staging (the
    # rounded-rms h against the f32-upcast table, softcap in f32) — so
    # fused-vs-unfused values are bit-identical and greedy is token-exact
    h = h_s[...]
    lf = jax.lax.dot_general(h, tab_ref[...].astype(jnp.float32),
                             (((1,), (1,)), ((), ())))          # [B, bv]
    if cap > 0:
        lf = jnp.tanh(lf / cap) * cap
    ids = jax.lax.broadcasted_iota(jnp.int32, lf.shape, 1) + j * bv
    t_max = jnp.max(lf, axis=-1, keepdims=True)                 # [B, 1]
    # lowest index among the tile's maxima (jnp.argmax semantics)
    t_arg = jnp.min(jnp.where(lf == t_max, ids, _INT32_MAX),
                    axis=-1, keepdims=True)
    better = t_max > m_s[...]          # strict: earlier tiles win ties
    i_s[...] = jnp.where(better, t_arg, i_s[...])
    m_s[...] = jnp.where(better, t_max, m_s[...])

    # ---------------- epilogue: write the [B] partials once -------------
    @pl.when(j == n_v - 1)
    def _epilogue():
        mx_ref[...] = m_s[...]
        ix_ref[...] = i_s[...]


def fused_head_block(
    x: jax.Array,                     # [B, D] raw residual stream
    table: jax.Array,                 # [V_loc, D] vocab-sharded head table
                                      # (aliases the embed table when tied)
    ln: jax.Array,                    # [D] final RMSNorm scale
    *,
    eps: float = 1e-6,
    logit_softcap: float = 0.0,
    block_v: int = 1024,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns ``(max_value [B] f32, argmax_local_index [B] int32)`` over
    this rank's vocab shard — the streaming greedy partials.  The caller
    adds ``shard · V_loc`` and tree-reduces (value, index) pairs across
    the model axis; ``[B, V]`` logits never touch HBM.
    """
    tracecount.bump("pallas_kernel")
    tracecount.bump("head_pallas_kernel")
    B, D = x.shape
    V_loc = table.shape[0]
    bv = min(block_v, V_loc)
    assert V_loc % bv == 0, (V_loc, bv)
    n_v = V_loc // bv
    ln_op = jnp.asarray(ln, jnp.float32).reshape(1, D)

    kernel = functools.partial(_kernel, n_v=n_v, bv=bv, eps=eps,
                               cap=float(logit_softcap or 0.0))

    out = pl.pallas_call(
        kernel,
        grid=(n_v,),
        in_specs=[
            pl.BlockSpec((B, D), lambda j: (0, 0)),            # x
            pl.BlockSpec((bv, D), lambda j: (j, 0)),           # table tile
            pl.BlockSpec((1, D), lambda j: (0, 0)),            # ln
        ],
        out_specs=[
            pl.BlockSpec((B, 1), lambda j: (0, 0)),
            pl.BlockSpec((B, 1), lambda j: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, D), jnp.float32),                   # h (normed)
            pltpu.VMEM((B, 1), jnp.float32),                   # running max
            pltpu.VMEM((B, 1), jnp.int32),                     # running arg
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, table, ln_op)
    return out[0][:, 0], out[1][:, 0]
