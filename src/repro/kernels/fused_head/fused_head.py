"""Fused LM-head/sampling tail: final RMSNorm + vocab-tiled logits +
softcap + streaming top-k partials in ONE ``pallas_call`` (DESIGN.md §7).

After the last fused layer, the decode step still ended with a loose XLA
tail: final ``rms_norm``, a full ``[B, V_loc]`` logits tensor
materialized in HBM, ``softcap``, and the local max/argmax feeding
``greedy_sample``'s (value, index) tree reduce.  The logits tensor is
the single largest activation a decode step writes — and it is never
needed: sampling only consumes each slot's k best ``(value, index)``
candidates (k = 1 is greedy).  This kernel runs the whole tail per
vocab shard:

* grid = (V_loc / block_v,), sequential.  Step 0 additionally computes
  the *prologue* in VMEM scratch: the final RMSNorm of the raw residual
  stream ``h = rms(x, ln)`` with a model-dtype round-trip, so the fused
  value is bit-identical to the unfused ``rms_norm`` (the same contract
  as the in-kernel ``ln1`` of the fused attention kernels).
* every step streams one ``[block_v, D]`` tile of the (possibly tied)
  embedding table, computes the logit tile ``h @ tileᵀ`` in f32 —
  exactly ``lm_head_logits``'s pinned f32 staging, so fused and unfused
  logits are bit-identical — applies ``logit_softcap`` in-tile (f32),
  and folds the tile into ``[B, k]`` running (value, index) scratch via
  ``select_topk`` over the concatenated carry + tile (k unrolled
  max/min-index passes — sort-free, Pallas-safe); the ``[B, V]`` logits
  NEVER exist outside one VMEM tile.
* the last step writes the per-shard sorted top-k partials — two
  ``[B, k]`` matrices, the only HBM output.

**Tie-breaking.**  ``select_topk`` orders candidates value-descending
with ties to the LOWEST global index — within a tile, across tiles
(earlier tiles carry lower global ids) and across shards alike: the
caller lifts local indices to the global vocab (``+ shard · V_loc``)
and merges shards with ONE tree ClusterReduce using the same operator
(``topk.topk_pair_merge``), so the fused tail reproduces the unfused
full-logits top-k token-exactly, and k = 1 reproduces ``greedy_sample``
(the PR-5 ``_greedy_pair_merge`` contract, verbatim at width k).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tracecount
from repro.kernels import tpu_compiler_params
from repro.kernels.fused_head.topk import _INT32_MAX, select_topk


def _kernel(x_ref, tab_ref, ln_ref,
            mx_ref, ix_ref,
            h_s, m_s, i_s,
            *, n_v: int, bv: int, k: int, eps: float, cap: float):
    j = pl.program_id(0)

    # ---------------- prologue: final RMSNorm in VMEM -------------------
    @pl.when(j == 0)
    def _prologue():
        xf = x_ref[...].astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        h = xf * jax.lax.rsqrt(var + eps) \
            * (1.0 + ln_ref[...].astype(jnp.float32))
        # model-dtype round-trip: bit-identical to the unfused rms_norm
        h_s[...] = h.astype(x_ref.dtype).astype(jnp.float32)
        m_s[...] = jnp.full_like(m_s[...], -jnp.inf)
        i_s[...] = jnp.full_like(i_s[...], _INT32_MAX)

    # ---------------- one vocab tile per grid step ----------------------
    # logits stay in f32, matching `lm_head_logits`'s pinned staging (the
    # rounded-rms h against the f32-upcast table, softcap in f32) — so
    # fused-vs-unfused values are bit-identical and the top-k partials
    # are token-exact
    h = h_s[...]
    lf = jax.lax.dot_general(h, tab_ref[...].astype(jnp.float32),
                             (((1,), (1,)), ((), ())))          # [B, bv]
    if cap > 0:
        lf = jnp.tanh(lf / cap) * cap
    ids = jax.lax.broadcasted_iota(jnp.int32, lf.shape, 1) + j * bv
    # fold the tile into the running [B, k] carry: one select_topk over
    # the concatenated (carry, tile) candidates — the (-inf, INT32_MAX)
    # init rows lose every comparison, so tile 0 is a pure select
    nv, ni = select_topk(jnp.concatenate([m_s[...], lf], axis=-1),
                         jnp.concatenate([i_s[...], ids], axis=-1), k)
    m_s[...] = nv
    i_s[...] = ni

    # ---------------- epilogue: write the [B, k] partials once ----------
    @pl.when(j == n_v - 1)
    def _epilogue():
        mx_ref[...] = m_s[...]
        ix_ref[...] = i_s[...]


def fused_head_block(
    x: jax.Array,                     # [B, D] raw residual stream
    table: jax.Array,                 # [V_loc, D] vocab-sharded head table
                                      # (aliases the embed table when tied)
    ln: jax.Array,                    # [D] final RMSNorm scale
    *,
    eps: float = 1e-6,
    logit_softcap: float = 0.0,
    block_v: int = 1024,
    k: int = 1,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns ``(values [B, k] f32, local_indices [B, k] int32)`` over
    this rank's vocab shard, sorted value-descending (ties to the lowest
    index) — the streaming top-k partials.  The caller adds
    ``shard · V_loc`` and tree-reduces the candidate sets across the
    model axis with ``topk.topk_pair_merge``; ``[B, V]`` logits never
    touch HBM.  ``k = 1`` is the greedy (max, argmax) pair.
    """
    tracecount.bump("pallas_kernel")
    tracecount.bump("head_pallas_kernel")
    B, D = x.shape
    V_loc = table.shape[0]
    bv = min(block_v, V_loc)
    assert V_loc % bv == 0, (V_loc, bv)
    n_v = V_loc // bv
    ln_op = jnp.asarray(ln, jnp.float32).reshape(1, D)

    kernel = functools.partial(_kernel, n_v=n_v, bv=bv, k=k, eps=eps,
                               cap=float(logit_softcap or 0.0))

    out = pl.pallas_call(
        kernel,
        grid=(n_v,),
        in_specs=[
            pl.BlockSpec((B, D), lambda j: (0, 0)),            # x
            pl.BlockSpec((bv, D), lambda j: (j, 0)),           # table tile
            pl.BlockSpec((1, D), lambda j: (0, 0)),            # ln
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda j: (0, 0)),
            pl.BlockSpec((B, k), lambda j: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, D), jnp.float32),                   # h (normed)
            pltpu.VMEM((B, k), jnp.float32),                   # running vals
            pltpu.VMEM((B, k), jnp.int32),                     # running ids
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, table, ln_op)
    return out[0], out[1]
