"""Pure-jnp oracle for the fused LM-head/sampling tail.

This IS the unfused engine composition per vocab shard —
``rms_norm`` → ``lm_head_logits`` (f32 logits) → ``softcap`` → the
local half of ``greedy_sample`` — so kernel-vs-ref equality is exactly
the fused ≡ unfused token-exactness claim.  The full ``[B, V_loc]``
logits the kernel never materializes exist only here.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, softcap


def fused_head_ref(
    x: jax.Array, table: jax.Array, ln: jax.Array, *,
    eps: float = 1e-6, logit_softcap: float = 0.0, **_,
) -> Tuple[jax.Array, jax.Array]:
    """``(max_value [B] f32, argmax_local_index [B] int32)`` over this
    shard.  Mirrors ``lm_head_logits``'s pinned staging: the model-dtype
    rounded ``rms_norm`` output against the f32-upcast table, softcap
    in f32."""
    h = rms_norm(x, ln, eps)
    logits = jnp.matmul(h, table.T.astype(h.dtype),
                        preferred_element_type=jnp.float32)
    if logit_softcap and logit_softcap > 0:
        logits = softcap(logits, logit_softcap)
    return (jnp.max(logits, axis=-1),
            jnp.argmax(logits, axis=-1).astype(jnp.int32))
