"""Pure-jnp oracle for the fused LM-head/sampling tail.

This IS the unfused engine composition per vocab shard —
``rms_norm`` → ``lm_head_logits`` (f32 logits) → ``softcap`` → the
local half of the streaming top-k selection — so kernel-vs-ref equality
is exactly the fused ≡ unfused token-exactness claim.  The full
``[B, V_loc]`` logits the kernel never materializes exist only here,
and the selection is the SAME ``select_topk`` the kernel folds tiles
with (one definition on purpose — DESIGN.md §8 pt 0).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_head.topk import select_topk
from repro.models.layers import rms_norm, softcap


def fused_head_ref(
    x: jax.Array, table: jax.Array, ln: jax.Array, *,
    eps: float = 1e-6, logit_softcap: float = 0.0, k: int = 1, **_,
) -> Tuple[jax.Array, jax.Array]:
    """``(values [B, k] f32, local_indices [B, k] int32)`` over this
    shard, sorted value-descending with ties to the lowest index.
    Mirrors ``lm_head_logits``'s pinned staging: the model-dtype rounded
    ``rms_norm`` output against the f32-upcast table, softcap in f32.
    ``k = 1`` is the greedy ``(max, argmax)`` pair."""
    h = rms_norm(x, ln, eps)
    logits = jnp.matmul(h, table.T.astype(h.dtype),
                        preferred_element_type=jnp.float32)
    if logit_softcap and logit_softcap > 0:
        logits = softcap(logits, logit_softcap)
    ids = jnp.broadcast_to(jnp.arange(logits.shape[-1], dtype=jnp.int32),
                           logits.shape)
    return select_topk(logits, ids, k)
