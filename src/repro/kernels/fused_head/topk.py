"""Shared streaming top-k selection for the fused LM-head/sampling tail.

ONE definition on purpose (the ``_greedy_pair_merge`` lesson, DESIGN.md
§8 pt 0): the Pallas kernel's per-tile fold, the pure-jnp oracle
(``ref.py``), the unfused engine tail and the cross-shard ClusterReduce
operator all select candidates through the SAME total order —
value-descending, tie-break to the LOWEST global index — so fused and
unfused paths agree bit-for-bit on every candidate, and the cross-shard
merge is commutative as well as associative (every rank's tree
association order yields the same k winners).

``select_topk`` is deliberately sort-free: k unrolled passes of
(max, min-index-among-maxima, mask) — pure elementwise ops + lane
reductions, so the identical code runs inside a Pallas TPU kernel body
and in plain jnp.  k = 1 degenerates exactly to the PR-5 greedy
(max, lowest-index argmax) pair.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

_INT32_MAX = 2 ** 31 - 1


def select_topk(vals: jnp.ndarray, ids: jnp.ndarray, k: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k of ``(vals [..., M], ids [..., M])`` under the total order
    (value desc, index asc) → ``(vals [..., k], ids [..., k])``, sorted.

    Indices must be unique along the last axis (they are global vocab
    positions).  When ``M < k`` the tail pads with ``(-inf, ...)``
    entries — strictly smaller than any real logit, so padding never
    survives a merge against real candidates and carries softmax
    probability 0 in the sampling finalize.
    """
    v = vals.astype(jnp.float32)
    i = ids.astype(jnp.int32)
    out_v, out_i = [], []
    for _ in range(k):
        mv = jnp.max(v, axis=-1, keepdims=True)
        mi = jnp.min(jnp.where(v == mv, i, _INT32_MAX),
                     axis=-1, keepdims=True)
        out_v.append(mv)
        out_i.append(mi)
        v = jnp.where((v == mv) & (i == mi), -jnp.inf, v)
    return (jnp.concatenate(out_v, axis=-1),
            jnp.concatenate(out_i, axis=-1))


def topk_pair_merge(a: Tuple[jnp.ndarray, jnp.ndarray],
                    b: Tuple[jnp.ndarray, jnp.ndarray]
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """THE commutative k-merge ClusterReduce operator: fold two sorted
    ``(vals [..., k], ids [..., k])`` candidate sets into their joint
    top-k under the same (value desc, index asc) order.

    Index sets from different vocab shards are disjoint, so the merged
    multiset has a unique top-k and the operator is commutative AND
    associative — every rank's tree association order agrees, the k-wide
    generalization of ``_greedy_pair_merge``'s tie-break fix (equal-max
    logits on different shards must resolve to the same global index on
    every rank).
    """
    av, ai = a
    bv, bi = b
    return select_topk(jnp.concatenate([av, bv], axis=-1),
                       jnp.concatenate([ai, bi], axis=-1),
                       av.shape[-1])
