"""Pure-jnp oracle for the FlashDecoding baseline kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_decode_attention_ref(q, k_cache, v_cache, cache_len, *,
                               scale: Optional[float] = None,
                               attn_softcap: float = 0.0, window: int = 0,
                               **_):
    B, q_loc, hd = q.shape
    S, kv_loc, _ = k_cache.shape
    qpk = q_loc // kv_loc
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.astype(jnp.float32).reshape(B, kv_loc, qpk, hd)
    s = jnp.einsum("bkqh,skh->bkqs", qg, k_cache.astype(jnp.float32)) * scale
    if attn_softcap > 0:
        s = jnp.tanh(s / attn_softcap) * attn_softcap
    pos = jnp.arange(S)
    valid = pos < cache_len
    if window > 0:
        valid &= pos > cache_len - window
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkqs,skh->bkqh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, q_loc, hd).astype(q.dtype)
