"""Plain FlashDecoding attention kernel (the paper's *baseline* dataflow:
attention alone, projections in separate kernels).

Same attention phase as ``fused_decode`` but takes q as input and returns
the normalized attention output — used for the fusion-ablation benchmark
(paper Fig. 9/18: ClusterFusion vs unfused) and as a standalone op.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(cache_len_ref, q_ref, k_blk_ref, v_blk_ref,
            o_ref, m_s, l_s, acc_s,
            *, blk_s: int, n_blocks: int, kv_loc: int, qpk: int,
            hd: int, scale: float, cap: float, window: int):
    j = pl.program_id(0)
    cache_len = cache_len_ref[0]
    B = q_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], -1e30)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    blk_start = j * blk_s
    lo = cache_len - window if window > 0 else -1
    live = (j < n_blocks) & (blk_start < cache_len) & \
        (blk_start + blk_s > lo)

    @pl.when(live)
    def _attend():
        q = q_ref[...].astype(jnp.float32).reshape(B, kv_loc, qpk, hd)
        kb = k_blk_ref[...].astype(jnp.float32)
        vb = v_blk_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, kb, (((3,), (2,)), ((1,), (1,))))
        s = jnp.moveaxis(s, 0, 1) * scale
        if cap > 0:
            s = jnp.tanh(s / cap) * cap
        pos = blk_start + lax.broadcasted_iota(jnp.int32, (1, 1, 1, blk_s), 3)
        valid = pos < cache_len
        if window > 0:
            valid &= pos > cache_len - window
        s = jnp.where(valid, s, -1e30)
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        m_s[...] = m_new
        l_s[...] = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.moveaxis(
            jax.lax.dot_general(p, vb, (((3,), (0,)), ((1,), (1,)))), 0, 1)
        acc_s[...] = acc_s[...] * corr[..., None] + pv

    @pl.when(j == n_blocks)
    def _finalize():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[...] = (acc_s[...] / l[..., None]).reshape(
            B, kv_loc * qpk, hd).astype(o_ref.dtype)


def flash_decode_attention(
    q: jax.Array,                 # [B, q_loc, hd]
    k_cache: jax.Array,           # [S, kv_loc, hd]
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    scale: Optional[float] = None,
    attn_softcap: float = 0.0,
    window: int = 0,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, q_loc, hd = q.shape
    S, kv_loc, _ = k_cache.shape
    qpk = q_loc // kv_loc
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    blk_s = min(block_s, S)
    assert S % blk_s == 0
    n_blocks = S // blk_s

    kernel = functools.partial(
        _kernel, blk_s=blk_s, n_blocks=n_blocks, kv_loc=kv_loc, qpk=qpk,
        hd=hd, scale=scale, cap=attn_softcap, window=window)

    def cache_map(j, *_):
        return (jnp.clip(j, 0, n_blocks - 1), 0, 0)

    (o,) = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks + 1,),
            in_specs=[
                pl.BlockSpec((B, q_loc, hd), lambda j, *_: (0, 0, 0)),
                pl.BlockSpec((blk_s, kv_loc, hd), cache_map),
                pl.BlockSpec((blk_s, kv_loc, hd), cache_map),
            ],
            out_specs=[pl.BlockSpec((B, q_loc, hd), lambda j, *_: (0, 0, 0))],
            scratch_shapes=[
                pltpu.VMEM((B, kv_loc, qpk), jnp.float32),
                pltpu.VMEM((B, kv_loc, qpk), jnp.float32),
                pltpu.VMEM((B, kv_loc, qpk, hd), jnp.float32),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, q_loc, hd), q.dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(jnp.asarray(cache_len, jnp.int32).reshape(1), q, k_cache, v_cache)
    return o
