"""Jitted public wrapper for the FlashDecoding baseline kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_decode.flash_decode import flash_decode_attention
from repro.kernels.flash_decode.ref import flash_decode_attention_ref


@partial(jax.jit, static_argnames=("scale", "attn_softcap", "window",
                                   "block_s", "interpret", "use_ref"))
def flash_decode(q, k_cache, v_cache, cache_len, *, scale=None,
                 attn_softcap=0.0, window=0, block_s=512, interpret=False,
                 use_ref=False):
    fn = flash_decode_attention_ref if use_ref else flash_decode_attention
    return fn(q, k_cache, v_cache, cache_len, scale=scale,
              attn_softcap=attn_softcap, window=window, block_s=block_s,
              interpret=interpret)
