"""Pure-jnp oracle for the fused MLA decode kernel."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def fused_mla_decode_attention_ref(
    x, wq, wdkv, wuk, wuv, wo, c_cache, cache_len, cos, sin, *,
    q_heads, nope, rope_d, l_rank, v_dim, fuse_out=True,
    pos: Optional[jax.Array] = None, include_new=None,
    norm_scale: Optional[jax.Array] = None, norm_eps: float = 1e-6, **_,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns ``(o, c_new, m, l)`` — same contract as the kernel:
    ``fuse_out=False`` gives the *unnormalized* latent accumulator."""
    B, D = x.shape
    S, lr = c_cache.shape
    scale = 1.0 / math.sqrt(nope + rope_d)
    xf = x.astype(jnp.float32)
    if norm_scale is not None:      # fused pre-attention RMSNorm
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        xf = xf * jax.lax.rsqrt(var + norm_eps) \
            * (1.0 + norm_scale.astype(jnp.float32))
        xf = xf.astype(x.dtype).astype(jnp.float32)
    q = (xf @ wq.astype(jnp.float32)).reshape(B, q_heads, nope + rope_d)
    c = xf @ wdkv.astype(jnp.float32)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    c_lat, c_rope = c[..., :l_rank], c[..., l_rank:]
    q_lat = jnp.einsum("bqn,qnl->bql", q_nope, wuk.astype(jnp.float32))
    half = rope_d // 2
    cc, ss = cos.astype(jnp.float32), sin.astype(jnp.float32)

    def rope(t):
        t1, t2 = t[..., :half], t[..., half:]
        return jnp.concatenate([t1 * cc - t2 * ss, t2 * cc + t1 * ss], -1)

    q_rope = rope(q_rope)
    c_rope = rope(c_rope)
    c_new = jnp.concatenate([c_lat, c_rope], axis=-1)

    cache = c_cache.astype(jnp.float32)
    s_cache = (jnp.einsum("bql,sl->bqs", q_lat, cache[:, :l_rank])
               + jnp.einsum("bqr,sr->bqs", q_rope, cache[:, l_rank:])) * scale
    s_self = (jnp.einsum("bql,bl->bq", q_lat, c_lat)
              + jnp.einsum("bqr,br->bq", q_rope, c_rope)) * scale
    if include_new is not None:
        # -1e30 (not -inf) keeps m finite when the cache is empty too
        s_self = jnp.where(include_new > 0, s_self, -1e30)
    if pos is None:
        pos = jnp.arange(S)
    valid = (pos >= 0) & (pos < cache_len)
    s_cache = jnp.where(valid[None, None, :], s_cache, -jnp.inf)
    s_all = jnp.concatenate([s_cache, s_self[..., None]], axis=-1)
    m = jnp.max(s_all, axis=-1)
    p_un = jnp.exp(s_all - m[..., None])
    p_un = jnp.where(jnp.isfinite(s_all), p_un, 0.0)
    l = jnp.sum(p_un, axis=-1)
    acc = jnp.einsum("bqs,sl->bql", p_un[..., :-1], cache[:, :l_rank]) \
        + p_un[..., -1][..., None] * c_lat[:, None, :]
    if fuse_out == "partial_o":
        # unnormalized projection through the prepacked W_UV·W_O tiles
        o = jnp.einsum("bql,qlv->bqv", acc, wuv.astype(jnp.float32))
    elif fuse_out:
        a_lat = acc / l[..., None]
        o_head = jnp.einsum("bql,qlv->bqv", a_lat, wuv.astype(jnp.float32))
        o = (o_head.reshape(B, q_heads * v_dim)
             @ wo.astype(jnp.float32)).astype(x.dtype)
    else:
        o = acc
    return o, c_new.astype(c_cache.dtype), m, l
