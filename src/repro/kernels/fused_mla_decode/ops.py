"""Jitted public wrapper for the fused MLA decode kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fused_mla_decode.fused_mla_decode import (
    fused_mla_decode_attention)
from repro.kernels.fused_mla_decode.ref import fused_mla_decode_attention_ref


@partial(jax.jit, static_argnames=("q_heads", "nope", "rope_d", "l_rank",
                                   "v_dim", "block_s", "fuse_out",
                                   "interpret", "use_ref"))
def fused_mla_decode(x, wq, wdkv, wuk, wuv, wo, c_cache, cache_len, cos, sin,
                     *, q_heads, nope, rope_d, l_rank, v_dim, block_s=512,
                     fuse_out=True, interpret=False, use_ref=False):
    fn = (fused_mla_decode_attention_ref if use_ref
          else fused_mla_decode_attention)
    return fn(x, wq, wdkv, wuk, wuv, wo, c_cache, cache_len, cos, sin,
              q_heads=q_heads, nope=nope, rope_d=rope_d, l_rank=l_rank,
              v_dim=v_dim, block_s=block_s, fuse_out=fuse_out,
              interpret=interpret)
