"""Jitted public wrapper for the fused MLA decode kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fused_mla_decode.fused_mla_decode import (
    fused_mla_decode_attention)
from repro.kernels.fused_mla_decode.ref import fused_mla_decode_attention_ref


@partial(jax.jit, static_argnames=("q_heads", "nope", "rope_d", "l_rank",
                                   "v_dim", "block_s", "fuse_out",
                                   "interpret", "use_ref", "norm_eps"))
def fused_mla_decode(x, wq, wdkv, wuk, wuv, wo, c_cache, cache_len, cos, sin,
                     *, q_heads, nope, rope_d, l_rank, v_dim, block_s=512,
                     fuse_out=True, interpret=False, use_ref=False,
                     pos=None, include_new=None, pos_base=None,
                     norm_scale=None, norm_eps=1e-6):
    kw = dict(q_heads=q_heads, nope=nope, rope_d=rope_d, l_rank=l_rank,
              v_dim=v_dim, fuse_out=fuse_out, pos=pos,
              include_new=include_new, norm_scale=norm_scale,
              norm_eps=norm_eps)
    if use_ref:
        return fused_mla_decode_attention_ref(
            x, wq, wdkv, wuk, wuv, wo, c_cache, cache_len, cos, sin, **kw)
    return fused_mla_decode_attention(
        x, wq, wdkv, wuk, wuv, wo, c_cache, cache_len, cos, sin,
        block_s=block_s, interpret=interpret, pos_base=pos_base, **kw)
