"""Fused weight-absorbed MLA decode kernel (paper Alg. 4, Level-1 TPU form).

Phases (one ``pallas_call``, grid sequential):
  0.  Q-Projection + Down-Projection + K-up absorption (q_lat = q_nope·W_UK)
      + RoPE, all resident in VMEM scratch; emits the new latent cache entry.
  1..n.  FlashDecoding in *latent space* over the compressed cache
      (this is MLA's whole point — the cache is [S, l+rope] shared by all
      heads, MQA-style).
  n+1.  New-entry contribution + online-softmax finalize + value
      Up-Projection (A·W_UV) + Output-Projection, one HBM write.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cache_len_ref,
            x_ref, wq_ref, wdkv_ref, wuk_ref, wuv_ref, wo_ref,
            cos_ref, sin_ref, c_blk_ref,
            o_ref, c_new_ref,
            q_s, m_s, l_s, acc_s,
            *, blk_s: int, n_blocks: int, q_loc: int, nope: int,
            rope_d: int, l_rank: int, v_dim: int, scale: float,
            fuse_out: bool):
    j = pl.program_id(0)
    cache_len = cache_len_ref[0]
    B = x_ref.shape[0]
    lr = l_rank + rope_d

    @pl.when(j == 0)
    def _proj():
        x = x_ref[...].astype(jnp.float32)                   # [B, D]
        q = jax.lax.dot(x, wq_ref[...].astype(jnp.float32))  # [B, q*(n+r)]
        q = q.reshape(B, q_loc, nope + rope_d)
        c = jax.lax.dot(x, wdkv_ref[...].astype(jnp.float32))  # [B, l+r]
        q_nope, q_rope = q[..., :nope], q[..., nope:]
        c_lat, c_rope = c[..., :l_rank], c[..., l_rank:]
        # absorb K-up into q:  q_lat [B, q, l]
        q_lat = jax.lax.dot_general(
            q_nope, wuk_ref[...].astype(jnp.float32),
            (((2,), (1,)), ((1,), (0,))))                     # [q, B, l]
        q_lat = jnp.moveaxis(q_lat, 0, 1)
        cos = cos_ref[...].astype(jnp.float32)
        sin = sin_ref[...].astype(jnp.float32)
        half = rope_d // 2

        def rope(t):
            t1, t2 = t[..., :half], t[..., half:]
            return jnp.concatenate([t1 * cos - t2 * sin,
                                    t2 * cos + t1 * sin], axis=-1)

        q_rope = rope(q_rope)
        c_rope = rope(c_rope.reshape(B, 1, rope_d)).reshape(B, rope_d)
        q_s[...] = jnp.concatenate([q_lat, q_rope], axis=-1)  # [B,q,l+r]
        c_new_ref[...] = jnp.concatenate([c_lat, c_rope],
                                         axis=-1).astype(c_new_ref.dtype)
        m_s[...] = jnp.full_like(m_s[...], -1e30)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    blk_start = (j - 1) * blk_s
    live = (j > 0) & (j <= n_blocks) & (blk_start < cache_len)

    @pl.when(live)
    def _attend():
        q = q_s[...]                                          # [B,q,l+r]
        cb = c_blk_ref[...].astype(jnp.float32)               # [blk, l+r]
        s = jax.lax.dot_general(q, cb, (((2,), (1,)), ((), ())))
        s = s * scale                                         # [B,q,blk]
        pos = blk_start + lax.broadcasted_iota(jnp.int32, (1, 1, blk_s), 2)
        valid = pos < cache_len
        s = jnp.where(valid, s, -1e30)
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        m_s[...] = m_new
        l_s[...] = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, cb[:, :l_rank],
                                 (((2,), (0,)), ((), ())))    # [B,q,l]
        acc_s[...] = acc_s[...] * corr[..., None] + pv

    @pl.when(j == n_blocks + 1)
    def _finalize():
        q = q_s[...]
        c_new = c_new_ref[...].astype(jnp.float32)            # [B, l+r]
        s = jnp.einsum("bql,bl->bq", q, c_new) * scale
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, s)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_fin = l_prev * corr + p
        acc = acc_s[...] * corr[..., None] \
            + p[..., None] * c_new[:, None, :l_rank]
        a_lat = acc / l_fin[..., None]                        # [B,q,l]
        # value Up-Projection (A · W_UV)  → [B, q, v]
        o_head = jax.lax.dot_general(
            a_lat, wuv_ref[...].astype(jnp.float32),
            (((2,), (1,)), ((1,), (0,))))                     # [q, B, v]
        o_head = jnp.moveaxis(o_head, 0, 1).reshape(B, q_loc * v_dim)
        if fuse_out:
            o_ref[...] = jax.lax.dot(
                o_head, wo_ref[...].astype(jnp.float32)).astype(o_ref.dtype)
        else:
            o_ref[...] = o_head.reshape(B, q_loc, v_dim).astype(o_ref.dtype)


def fused_mla_decode_attention(
    x: jax.Array,                 # [B, D]
    wq: jax.Array,                # [D, q_loc * (nope+rope)]
    wdkv: jax.Array,              # [D, l_rank + rope]
    wuk: jax.Array,               # [q_loc, nope, l_rank]
    wuv: jax.Array,               # [q_loc, l_rank, v_dim]
    wo: jax.Array,                # [q_loc * v_dim, D_out]
    c_cache: jax.Array,           # [S, l_rank + rope] latent cache
    cache_len: jax.Array,
    cos: jax.Array,               # [rope//2] at position cache_len
    sin: jax.Array,
    *,
    q_heads: int, nope: int, rope_d: int, l_rank: int, v_dim: int,
    block_s: int = 512, fuse_out: bool = True, interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (o, c_new).  o: [B, D_out] (fused) or [B, q, v] partials."""
    B, D = x.shape
    S, lr = c_cache.shape
    assert lr == l_rank + rope_d
    scale = 1.0 / math.sqrt(nope + rope_d)
    blk_s = min(block_s, S)
    assert S % blk_s == 0
    n_blocks = S // blk_s
    d_out = wo.shape[1]
    o_shape = (B, d_out) if fuse_out else (B, q_heads, v_dim)

    kernel = functools.partial(
        _kernel, blk_s=blk_s, n_blocks=n_blocks, q_loc=q_heads, nope=nope,
        rope_d=rope_d, l_rank=l_rank, v_dim=v_dim, scale=scale,
        fuse_out=fuse_out)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks + 2,),
            in_specs=[
                pl.BlockSpec((B, D), lambda j, *_: (0, 0)),
                pl.BlockSpec(wq.shape, lambda j, *_: (0, 0)),
                pl.BlockSpec(wdkv.shape, lambda j, *_: (0, 0)),
                pl.BlockSpec(wuk.shape, lambda j, *_: (0, 0, 0)),
                pl.BlockSpec(wuv.shape, lambda j, *_: (0, 0, 0)),
                pl.BlockSpec(wo.shape, lambda j, *_: (0, 0)),
                pl.BlockSpec((1, rope_d // 2), lambda j, *_: (0, 0)),
                pl.BlockSpec((1, rope_d // 2), lambda j, *_: (0, 0)),
                pl.BlockSpec((blk_s, lr),
                             lambda j, *_: (jnp.clip(j - 1, 0, n_blocks - 1),
                                            0)),
            ],
            out_specs=[
                pl.BlockSpec(o_shape, lambda j, *_: (0,) * len(o_shape)),
                pl.BlockSpec((B, lr), lambda j, *_: (0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((B, q_heads, lr), jnp.float32),
                pltpu.VMEM((B, q_heads), jnp.float32),
                pltpu.VMEM((B, q_heads), jnp.float32),
                pltpu.VMEM((B, q_heads, l_rank), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct(o_shape,
                                 x.dtype if fuse_out else jnp.float32),
            jax.ShapeDtypeStruct((B, lr), c_cache.dtype),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(jnp.asarray(cache_len, jnp.int32).reshape(1),
      x, wq, wdkv, wuk, wuv, wo, cos.reshape(1, -1), sin.reshape(1, -1),
      c_cache)
    return tuple(out)
