"""Fused weight-absorbed MLA decode kernel (paper Alg. 4, Level-1 TPU form).

Phases (one ``pallas_call``, grid sequential):
  0.  Q-Projection + Down-Projection + K-up absorption (q_lat = q_nope·W_UK)
      + RoPE, all resident in VMEM scratch; emits the new latent cache entry.
  1..n.  FlashDecoding in *latent space* over the compressed cache
      (this is MLA's whole point — the cache is [S, l+rope] shared by all
      heads, MQA-style).  The block index map is clamped with ``cache_len``
      (scalar prefetch), so grid steps beyond the live prefix re-address
      the resident block — HBM traffic is proportional to ``cache_len``,
      not the allocated ``S`` (DESIGN.md §3) — and interior fully-live
      blocks take a mask-free fast path.
  n+1.  New-entry contribution (gated by ``include_new`` — across a
      cluster only the append-slot owner counts it) + online-softmax
      finalize + value Up-Projection (A·W_UV) + Output-Projection, one
      HBM write.

Cache slots carry explicit positions (``pos``; −1 ⇒ empty) matching the
XLA dataflow's ``KVBlock.pos`` convention; without ``pos`` the linear
layout ``pos[i] = i`` is assumed.

Three modes:
* ``fuse_out=True``  — returns final ``o [B, D_out]``.
* ``fuse_out=False`` — returns the *unnormalized* latent flash partials
  ``acc [B, q, l_rank]`` plus ``(m, l)`` for the cross-chip
  ClusterReduce combine (paper Alg. 4 lines 8–10); the value
  Up-Projection and Output-Projection then run after the combine.
* ``fuse_out="partial_o"`` — value Up-Projection AND Output-Projection
  fused into the kernel: ``wuv`` carries the prepacked per-head product
  ``W_UV · W_O(cols)`` (``[q, l_rank, d_out]``, serving/prepack.py) and
  the kernel emits unnormalized projected tiles ``o [B, q, d_out]``.
  The projection is linear per head, so the flash merge on ``(m, l, o)``
  stays exact: ONE fused ClusterReduce, then a local normalize + head
  sum, completes the layer — and Alg. 4's value-up partial-sum
  ClusterReduce (lines 11–12) disappears entirely.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tracecount
from repro.kernels import tpu_compiler_params
from repro.kernels.fused_decode.fused_decode import _cache_block_index


def _kernel(scalars_ref,          # [cache_len, include_new, pos_base] (SMEM)
            x_ref, wq_ref, wdkv_ref, wuk_ref, wuv_ref, wo_ref,
            cos_ref, sin_ref, norm_ref, c_blk_ref, pos_blk_ref,
            o_ref, c_new_ref, m_out_ref, l_out_ref,
            q_s, m_s, l_s, acc_s,
            *, blk_s: int, n_blocks: int, q_loc: int, nope: int,
            rope_d: int, l_rank: int, v_dim: int, scale: float,
            fuse_out, fuse_norm: bool, norm_eps: float):
    j = pl.program_id(0)
    cache_len = scalars_ref[0]
    B = x_ref.shape[0]
    lr = l_rank + rope_d

    @pl.when(j == 0)
    def _proj():
        x = x_ref[...].astype(jnp.float32)                   # [B, D]
        if fuse_norm:
            # fused pre-attention RMSNorm (raw residual stream crossed
            # HBM; dtype round-trip matches the XLA oracle's rms_norm)
            g = norm_ref[...].astype(jnp.float32)            # [1, D]
            var = jnp.mean(x * x, axis=-1, keepdims=True)
            x = x * jax.lax.rsqrt(var + norm_eps) * (1.0 + g)
            x = x.astype(x_ref.dtype).astype(jnp.float32)
        q = jax.lax.dot(x, wq_ref[...].astype(jnp.float32))  # [B, q*(n+r)]
        q = q.reshape(B, q_loc, nope + rope_d)
        c = jax.lax.dot(x, wdkv_ref[...].astype(jnp.float32))  # [B, l+r]
        q_nope, q_rope = q[..., :nope], q[..., nope:]
        c_lat, c_rope = c[..., :l_rank], c[..., l_rank:]
        # absorb K-up into q:  q_lat [B, q, l]
        q_lat = jax.lax.dot_general(
            q_nope, wuk_ref[...].astype(jnp.float32),
            (((2,), (1,)), ((1,), (0,))))                     # [q, B, l]
        q_lat = jnp.moveaxis(q_lat, 0, 1)
        cos = cos_ref[...].astype(jnp.float32)
        sin = sin_ref[...].astype(jnp.float32)
        half = rope_d // 2

        def rope(t):
            t1, t2 = t[..., :half], t[..., half:]
            return jnp.concatenate([t1 * cos - t2 * sin,
                                    t2 * cos + t1 * sin], axis=-1)

        q_rope = rope(q_rope)
        c_rope = rope(c_rope.reshape(B, 1, rope_d)).reshape(B, rope_d)
        q_s[...] = jnp.concatenate([q_lat, q_rope], axis=-1)  # [B,q,l+r]
        c_new_ref[...] = jnp.concatenate([c_lat, c_rope],
                                         axis=-1).astype(c_new_ref.dtype)
        m_s[...] = jnp.full_like(m_s[...], -1e30)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    blk_start = (j - 1) * blk_s
    pos_base = scalars_ref[2]
    # rank-local live span (slot i holds position pos_base + i)
    eff_len = cache_len - jnp.maximum(pos_base, 0)
    live = (j > 0) & (j <= n_blocks) & (blk_start < eff_len)
    full = (live & (pos_base >= 0)
            & (pos_base + blk_start + blk_s <= cache_len))

    def _attend(masked: bool):
        q = q_s[...]                                          # [B,q,l+r]
        cb = c_blk_ref[...].astype(jnp.float32)               # [blk, l+r]
        s = jax.lax.dot_general(q, cb, (((2,), (1,)), ((), ())))
        s = s * scale                                         # [B,q,blk]
        valid = None
        if masked:
            pos = pos_blk_ref[...].reshape(1, 1, blk_s)
            valid = (pos >= 0) & (pos < cache_len)
            s = jnp.where(valid, s, -1e30)
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        if masked:
            p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        m_s[...] = m_new
        l_s[...] = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p, cb[:, :l_rank],
                                 (((2,), (0,)), ((), ())))    # [B,q,l]
        acc_s[...] = acc_s[...] * corr[..., None] + pv

    @pl.when(full)
    def _attend_full():
        _attend(masked=False)

    @pl.when(live & jnp.logical_not(full))
    def _attend_masked():
        _attend(masked=True)

    @pl.when(j == n_blocks + 1)
    def _finalize():
        include_new = scalars_ref[1] > 0
        q = q_s[...]
        c_new = c_new_ref[...].astype(jnp.float32)            # [B, l+r]
        s = jnp.einsum("bql,bl->bq", q, c_new) * scale
        s = jnp.where(include_new, s, -1e30)
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, s)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_fin = l_prev * corr + p
        acc = acc_s[...] * corr[..., None] \
            + p[..., None] * c_new[:, None, :l_rank]
        m_out_ref[...] = m_new
        l_out_ref[...] = l_fin
        if fuse_out == "partial_o":
            # fused value-up + Output-Projection of the UNNORMALIZED latent
            # accumulator through the prepacked per-head W_UV·W_O tiles;
            # normalization (÷ l_g) + head sum run after the ClusterReduce.
            po = jax.lax.dot_general(
                acc, wuv_ref[...].astype(jnp.float32),
                (((2,), (1,)), ((1,), (0,))))                 # [q, B, d_out]
            o_ref[...] = jnp.moveaxis(po, 0, 1).astype(o_ref.dtype)
        elif fuse_out:
            # max guard: an inactive slot (ragged decode) has l == 0
            a_lat = acc / jnp.maximum(l_fin[..., None], 1e-30)  # [B,q,l]
            # value Up-Projection (A · W_UV)  → [B, q, v]
            o_head = jax.lax.dot_general(
                a_lat, wuv_ref[...].astype(jnp.float32),
                (((2,), (1,)), ((1,), (0,))))                 # [q, B, v]
            o_head = jnp.moveaxis(o_head, 0, 1).reshape(B, q_loc * v_dim)
            o_ref[...] = jax.lax.dot(
                o_head, wo_ref[...].astype(jnp.float32)).astype(o_ref.dtype)
        else:
            o_ref[...] = acc.astype(o_ref.dtype)              # unnormalized


def fused_mla_decode_attention(
    x: jax.Array,                 # [B, D]
    wq: jax.Array,                # [D, q_loc * (nope+rope)]
    wdkv: jax.Array,              # [D, l_rank + rope]
    wuk: jax.Array,               # [q_loc, nope, l_rank]
    wuv: jax.Array,               # [q_loc, l_rank, v_dim]; the prepacked
                                  # W_UV·W_O tiles when fuse_out="partial_o"
    wo: jax.Array,                # [q_loc * v_dim, D_out] (unused for
                                  # fuse_out="partial_o")
    c_cache: jax.Array,           # [S, l_rank + rope] latent cache
    cache_len: jax.Array,
    cos: jax.Array,               # [rope//2] at position cache_len
    sin: jax.Array,
    *,
    q_heads: int, nope: int, rope_d: int, l_rank: int, v_dim: int,
    block_s: int = 512, fuse_out=True, interpret: bool = False,
    pos: Optional[jax.Array] = None,
    include_new: Optional[jax.Array] = None,
    pos_base: Optional[jax.Array] = None,
    norm_scale: Optional[jax.Array] = None,   # [D] fused pre-attention
                                              # RMSNorm scale (None = legacy)
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns ``(o, c_new, m, l)``.

    ``fuse_out=True``: o = [B, D_out] (final; m/l informational).
    ``fuse_out=False``: o = [B, q, l_rank] *unnormalized* latent
    accumulator — combine across chips with ``cluster_flash_combine``,
    then Up-Project and Output-Project.
    ``fuse_out="partial_o"``: o = [B, q, v_dim] *unnormalized* projected
    tiles through the prepacked per-head ``wuv`` (= W_UV·W_O columns);
    flash-merge across chips, normalize per head, sum over heads.
    """
    tracecount.bump("pallas_kernel")
    B, D = x.shape
    S, lr = c_cache.shape
    assert lr == l_rank + rope_d
    scale = 1.0 / math.sqrt(nope + rope_d)
    blk_s = min(block_s, S)
    assert S % blk_s == 0
    n_blocks = S // blk_s
    d_out = wo.shape[1]
    if fuse_out == "partial_o":
        assert wuv.shape == (q_heads, l_rank, v_dim), (wuv.shape,)
        o_shape = (B, q_heads, v_dim)
    elif fuse_out:
        o_shape = (B, d_out)
    else:
        o_shape = (B, q_heads, l_rank)
    if pos is None:
        pos = jnp.arange(S, dtype=jnp.int32)
        if pos_base is None:
            pos_base = jnp.int32(0)
    if pos_base is None:
        pos_base = jnp.int32(-1)
    if include_new is None:
        include_new = jnp.int32(1)
    scalars = jnp.stack([
        jnp.asarray(cache_len, jnp.int32).reshape(()),
        jnp.asarray(include_new, jnp.int32).reshape(()),
        jnp.asarray(pos_base, jnp.int32).reshape(()),
    ])

    fuse_norm = norm_scale is not None
    norm_op = (jnp.asarray(norm_scale, jnp.float32).reshape(1, D)
               if fuse_norm else jnp.zeros((1, 1), jnp.float32))
    kernel = functools.partial(
        _kernel, blk_s=blk_s, n_blocks=n_blocks, q_loc=q_heads, nope=nope,
        rope_d=rope_d, l_rank=l_rank, v_dim=v_dim, scale=scale,
        fuse_out=fuse_out, fuse_norm=fuse_norm, norm_eps=norm_eps)

    def cache_map(j, s_ref):
        b = _cache_block_index(j, s_ref[0], blk_s=blk_s, n_blocks=n_blocks,
                               window=0, pos_base=s_ref[2])
        return (b, 0)

    def pos_map(j, s_ref):
        b = _cache_block_index(j, s_ref[0], blk_s=blk_s, n_blocks=n_blocks,
                               window=0, pos_base=s_ref[2])
        return (0, b)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks + 2,),
            in_specs=[
                pl.BlockSpec((B, D), lambda j, *_: (0, 0)),
                pl.BlockSpec(wq.shape, lambda j, *_: (0, 0)),
                pl.BlockSpec(wdkv.shape, lambda j, *_: (0, 0)),
                pl.BlockSpec(wuk.shape, lambda j, *_: (0, 0, 0)),
                pl.BlockSpec(wuv.shape, lambda j, *_: (0, 0, 0)),
                pl.BlockSpec(wo.shape, lambda j, *_: (0, 0)),
                pl.BlockSpec((1, rope_d // 2), lambda j, *_: (0, 0)),
                pl.BlockSpec((1, rope_d // 2), lambda j, *_: (0, 0)),
                pl.BlockSpec(norm_op.shape, lambda j, *_: (0, 0)),  # ln1
                pl.BlockSpec((blk_s, lr), cache_map),
                pl.BlockSpec((1, blk_s), pos_map),
            ],
            out_specs=[
                pl.BlockSpec(o_shape, lambda j, *_: (0,) * len(o_shape)),
                pl.BlockSpec((B, lr), lambda j, *_: (0, 0)),
                pl.BlockSpec((B, q_heads), lambda j, *_: (0, 0)),
                pl.BlockSpec((B, q_heads), lambda j, *_: (0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((B, q_heads, lr), jnp.float32),
                pltpu.VMEM((B, q_heads), jnp.float32),
                pltpu.VMEM((B, q_heads), jnp.float32),
                pltpu.VMEM((B, q_heads, l_rank), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct(o_shape,
                                 x.dtype if fuse_out is True
                                 else jnp.float32),
            jax.ShapeDtypeStruct((B, lr), c_cache.dtype),
            jax.ShapeDtypeStruct((B, q_heads), jnp.float32),
            jax.ShapeDtypeStruct((B, q_heads), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(scalars,
      x, wq, wdkv, wuk, wuv, wo, cos.reshape(1, -1), sin.reshape(1, -1),
      norm_op, c_cache, jnp.asarray(pos, jnp.int32).reshape(1, S))
    return tuple(out)
