"""RWKV-6 WKV recurrence kernel with the matrix state resident in VMEM.

grid = (head_blocks, seq_blocks); heads parallel, sequence sequential with
the [B, hb, hd, hd] state carried in VMEM scratch (fp32).  Per timestep:

    o_t = r_t · (S + u ⊙ (k_tᵀ v_t))
    S  ← diag(w_t) S + k_tᵀ v_t

This is the fusion-scope philosophy applied to the attention-free arch
(DESIGN.md §4: the paper's head-cluster dataflow is inapplicable to
RWKV-6, so the recurrence gets its own fused kernel instead).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            o_ref, s_fin_ref, s_s,
            *, blk_t: int, n_tblocks: int, hb: int, hd: int):
    tj = pl.program_id(1)
    B = r_ref.shape[0]

    @pl.when(tj == 0)
    def _init():
        s_s[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)      # [B, blk_t, hb, hd]
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)      # [1, hb, hd]

    def step(t, s):
        kt = k[:, t]                         # [B, hb, hd]
        vt = v[:, t]
        kv = kt[..., :, None] * vt[..., None, :]      # [B,hb,hd,hd]
        o = jnp.einsum("bhi,bhij->bhj", r[:, t], s + u[..., :, None] * kv)
        o_ref[:, t] = o.astype(o_ref.dtype)
        return w[:, t][..., :, None] * s + kv

    s = lax.fori_loop(0, blk_t, step, s_s[...])
    s_s[...] = s

    @pl.when(tj == n_tblocks - 1)
    def _fin():
        s_fin_ref[...] = s.astype(s_fin_ref.dtype)


def rwkv6_scan_kernel(r, k, v, w, u, s0, *, block_t: int = 64,
                      block_h: int = 4, interpret: bool = False):
    """r/k/v/w: [B, S, H, hd]; u: [H, hd]; s0: [B, H, hd, hd].

    Returns (o [B, S, H, hd], s_final [B, H, hd, hd])."""
    B, S, H, hd = r.shape
    hb = min(block_h, H)
    blk_t = min(block_t, S)
    assert S % blk_t == 0 and H % hb == 0
    n_t, n_h = S // blk_t, H // hb

    kernel = functools.partial(_kernel, blk_t=blk_t, n_tblocks=n_t, hb=hb,
                               hd=hd)
    o, s_fin = pl.pallas_call(
        kernel,
        grid=(n_h, n_t),
        in_specs=[
            pl.BlockSpec((B, blk_t, hb, hd), lambda h, t: (0, t, h, 0)),
            pl.BlockSpec((B, blk_t, hb, hd), lambda h, t: (0, t, h, 0)),
            pl.BlockSpec((B, blk_t, hb, hd), lambda h, t: (0, t, h, 0)),
            pl.BlockSpec((B, blk_t, hb, hd), lambda h, t: (0, t, h, 0)),
            pl.BlockSpec((1, hb, hd), lambda h, t: (0, h, 0)),
            pl.BlockSpec((B, hb, hd, hd), lambda h, t: (0, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, blk_t, hb, hd), lambda h, t: (0, t, h, 0)),
            pl.BlockSpec((B, hb, hd, hd), lambda h, t: (0, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((B, hb, hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u.reshape(1, H, hd), s0)
    return o, s_fin
