"""Pure-jnp oracle for the RWKV-6 WKV scan kernel (same math as
models/rwkv6._wkv_scan)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.rwkv6 import _wkv_scan


def rwkv6_scan_ref(r, k, v, w, u, s0, **_):
    o, s_fin = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), w.astype(jnp.float32),
                         u.astype(jnp.float32), s0.astype(jnp.float32))
    return o.astype(r.dtype), s_fin
