"""Jitted public wrapper for the RWKV-6 WKV scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6_scan.rwkv6_scan import rwkv6_scan_kernel
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


@partial(jax.jit, static_argnames=("block_t", "block_h", "interpret",
                                   "use_ref"))
def rwkv6_scan(r, k, v, w, u, s0, *, block_t=64, block_h=4, interpret=False,
               use_ref=False):
    if use_ref:
        return rwkv6_scan_ref(r, k, v, w, u, s0)
    return rwkv6_scan_kernel(r, k, v, w, u, s0, block_t=block_t,
                             block_h=block_h, interpret=interpret)
