"""Pure-jnp oracle for the fused FFN block-tail kernel."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation


def fused_ffn_block_ref(
    x: jax.Array, a: jax.Array, w_in: jax.Array,
    w_gate: Optional[jax.Array], w_out: jax.Array, ln2: jax.Array,
    post_ln1: Optional[jax.Array], add_r, *,
    act: str, eps: float = 1e-6, **_,
) -> Tuple[jax.Array, jax.Array]:
    """Mirrors the kernel's math (f32 matmuls, dtype-rounded norms and
    residual) — ``(o, r)`` with ``o`` this rank's partial + ``add_r·r``."""
    def rms(v, scale):
        var = jnp.mean(v * v, axis=-1, keepdims=True)
        out = v * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
        return out.astype(x.dtype).astype(jnp.float32)

    def q(v):                       # model-dtype op-boundary rounding
        return v.astype(x.dtype).astype(jnp.float32)

    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    if post_ln1 is not None:
        af = rms(af, post_ln1)
    r = (xf + af).astype(x.dtype).astype(jnp.float32)
    h = rms(r, ln2)
    act_fn = activation(act)
    u = q(h @ w_in.astype(jnp.float32))
    if w_gate is not None:
        hm = q(act_fn(q(h @ w_gate.astype(jnp.float32))) * u)
    else:
        hm = q(act_fn(u))
    o = hm @ w_out.astype(jnp.float32) \
        + r * jnp.asarray(add_r, jnp.float32)
    return o.astype(x.dtype), r.astype(x.dtype)
