"""Fused transformer-block tail: post-attention RMSNorm + gated FFN +
both residual adds in ONE ``pallas_call`` (DESIGN.md §7).

After the fused attention kernel emits the full-width attention output
``a`` for a layer, the rest of the block is still ~6 loose XLA ops plus
a per-layer ``psum_model`` all-reduce on the FFN activations — repeated
HBM round-trips for the ``[B, D]`` activation.  This kernel runs the
whole tail per rank:

* grid = (F_loc / block_f,), sequential.  Step 0 additionally computes
  the *prologue* in VMEM scratch: optional post-attention norm of ``a``
  (Gemma-2 ``post_ln1``), the first residual add ``r = x + a``, and the
  pre-FFN RMSNorm ``h = rms(r, ln2)`` — the raw residual stream and the
  raw attention output are the only activations that cross HBM.
* every step streams one ``block_f`` column tile of the up (and gate)
  projection plus the matching ``block_f``-row tile of the down
  projection, accumulating ``act(h·Wg)·(h·Wi) @ Wo_tile`` into a
  ``[B, D]`` f32 scratch accumulator.
* the last step folds the second residual add and writes once.

**Full-width down rows.**  ``w_out`` tiles are FULL-width ``[bf, D]``
rows (the Megatron row-sharded layout — every rank's partial lives in
the same output basis), so one fused ClusterReduce over the model axis
sums the per-rank partials exactly — the same invariant that makes the
attention kernel's ``partial_o`` combinable (see
``PackedSplitTokenWeights.wo``).  The residual ``r`` is folded into
exactly ONE rank's partial (``add_r = 1.0`` there, ``0.0`` elsewhere —
an exact multiplicative gate), so the reduce completes the layer output
``x + a + f`` directly and the per-layer ``ctx.psum_model`` disappears.

Post-norm models (``post_ln2``) normalize the SUMMED FFN output — a
nonlinearity over the full reduction — so there ``add_r = 0``: the
kernel emits the raw partial plus ``r`` (second output), and the caller
applies ``r + rms(reduce(partial), post_ln2)`` after the combine.

Ragged decode needs no gating here: the FFN is position-independent and
slot-local, so free slots simply flow through (their output is ignored
by the scheduler), exactly as on the XLA path.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tracecount
from repro.kernels import tpu_compiler_params
from repro.models.layers import activation


def _kernel(x_ref, a_ref, wi_ref, wg_ref, wo_ref, ln2_ref, post1_ref,
            addr_ref,
            o_ref, r_ref,
            r_s, h_s, acc_s,
            *, n_f: int, act: str, eps: float, gated: bool,
            has_post1: bool):
    j = pl.program_id(0)

    def rms(v, scale):                  # v f32 [B, D]; dtype round-trip
        var = jnp.mean(v * v, axis=-1, keepdims=True)
        out = v * jax.lax.rsqrt(var + eps) * (1.0 + scale)
        return out.astype(x_ref.dtype).astype(jnp.float32)

    # ---------------- prologue: norms + first residual add -------------
    @pl.when(j == 0)
    def _prologue():
        x = x_ref[...].astype(jnp.float32)
        a = a_ref[...].astype(jnp.float32)
        if has_post1:
            a = rms(a, post1_ref[...].astype(jnp.float32))
        r = (x + a).astype(x_ref.dtype).astype(jnp.float32)
        r_s[...] = r
        h_s[...] = rms(r, ln2_ref[...].astype(jnp.float32))
        acc_s[...] = jnp.zeros_like(acc_s[...])

    # ---------------- one d_ff tile per grid step -----------------------
    # intermediates round to the model dtype at the same op boundaries the
    # XLA path rounds at, so fused-vs-unfused drift stays at reduce-
    # association level (keeps greedy decode token-stable)
    def q(v):
        return v.astype(x_ref.dtype).astype(jnp.float32)

    h = h_s[...]
    act_fn = activation(act)
    u = q(jax.lax.dot(h, wi_ref[...].astype(jnp.float32)))     # [B, bf]
    if gated:
        g = q(jax.lax.dot(h, wg_ref[...].astype(jnp.float32)))
        hm = q(act_fn(g) * u)
    else:
        hm = q(act_fn(u))
    acc_s[...] += jax.lax.dot(hm, wo_ref[...].astype(jnp.float32))

    # ---------------- epilogue: second residual add + one HBM write -----
    @pl.when(j == n_f - 1)
    def _epilogue():
        add_r = addr_ref[...].astype(jnp.float32)              # [1, 1]
        o_ref[...] = (acc_s[...] + r_s[...] * add_r).astype(o_ref.dtype)
        r_ref[...] = r_s[...].astype(r_ref.dtype)


def fused_ffn_block(
    x: jax.Array,                     # [B, D] raw residual stream
    a: jax.Array,                     # [B, D] attention output (pre-residual)
    w_in: jax.Array,                  # [D, F_loc] up-projection columns
    w_gate: Optional[jax.Array],      # [D, F_loc] gate columns, or None
    w_out: jax.Array,                 # [F_loc, D] FULL-width down rows
    ln2: jax.Array,                   # [D] pre-FFN RMSNorm scale
    post_ln1: Optional[jax.Array],    # [D] post-attention norm (Gemma-2)
    add_r: jax.Array,                 # [] 1.0 on the single rank folding the
                                      # residual into its partial, else 0.0
    *,
    act: str,
    eps: float = 1e-6,
    block_f: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns ``(o, r)``.

    ``o [B, D]``: this rank's down-projection partial (+ ``add_r · r``),
    in ``x.dtype`` — ClusterReduce over the model axis completes the
    layer.  ``r [B, D]``: the post-first-residual stream (needed only by
    ``post_ln2`` callers, which apply the second residual add outside).
    """
    tracecount.bump("pallas_kernel")
    tracecount.bump("ffn_pallas_kernel")
    B, D = x.shape
    F_loc = w_in.shape[1]
    bf = min(block_f, F_loc)
    assert F_loc % bf == 0, (F_loc, bf)
    n_f = F_loc // bf
    gated = w_gate is not None
    has_post1 = post_ln1 is not None
    wg_op = w_gate if gated else jnp.zeros((1, 1), w_in.dtype)
    post1_op = (jnp.asarray(post_ln1, jnp.float32).reshape(1, D)
                if has_post1 else jnp.zeros((1, 1), jnp.float32))
    ln2_op = jnp.asarray(ln2, jnp.float32).reshape(1, D)
    addr_op = jnp.asarray(add_r, jnp.float32).reshape(1, 1)

    kernel = functools.partial(
        _kernel, n_f=n_f, act=act, eps=eps, gated=gated,
        has_post1=has_post1)

    def col_tile(j):
        return (0, j)

    wg_spec = (pl.BlockSpec((D, bf), col_tile) if gated
               else pl.BlockSpec((1, 1), lambda j: (0, 0)))

    out = pl.pallas_call(
        kernel,
        grid=(n_f,),
        in_specs=[
            pl.BlockSpec((B, D), lambda j: (0, 0)),            # x
            pl.BlockSpec((B, D), lambda j: (0, 0)),            # a
            pl.BlockSpec((D, bf), col_tile),                   # w_in tile
            wg_spec,                                           # w_gate tile
            pl.BlockSpec((bf, D), lambda j: (j, 0)),           # w_out rows
            pl.BlockSpec(ln2_op.shape, lambda j: (0, 0)),      # ln2
            pl.BlockSpec(post1_op.shape, lambda j: (0, 0)),    # post_ln1
            pl.BlockSpec((1, 1), lambda j: (0, 0)),            # add_r
        ],
        out_specs=[
            pl.BlockSpec((B, D), lambda j: (0, 0)),
            pl.BlockSpec((B, D), lambda j: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, D), jnp.float32),                   # r
            pltpu.VMEM((B, D), jnp.float32),                   # h (normed)
            pltpu.VMEM((B, D), jnp.float32),                   # accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), x.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, a, w_in, wg_op, w_out, ln2_op, post1_op, addr_op)
    return tuple(out)
