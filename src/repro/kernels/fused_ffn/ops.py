"""Jitted public wrapper for the fused FFN block-tail kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.fused_ffn.fused_ffn import fused_ffn_block
from repro.kernels.fused_ffn.ref import fused_ffn_block_ref


@partial(jax.jit, static_argnames=("act", "eps", "block_f", "interpret",
                                   "use_ref"))
def fused_ffn(x, a, w_in, w_gate, w_out, ln2, post_ln1, add_r, *,
              act, eps=1e-6, block_f=512, interpret=False, use_ref=False):
    if use_ref:
        return fused_ffn_block_ref(x, a, w_in, w_gate, w_out, ln2,
                                   post_ln1, add_r, act=act, eps=eps)
    return fused_ffn_block(x, a, w_in, w_gate, w_out, ln2, post_ln1, add_r,
                           act=act, eps=eps, block_f=block_f,
                           interpret=interpret)
