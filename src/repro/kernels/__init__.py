"""Pallas TPU kernels (validated with interpret=True on CPU).

Each subpackage: ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit wrapper), ``ref.py`` (pure-jnp oracle).
"""
from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kwargs):
    """Version-compat constructor for Mosaic compiler params.

    Newer JAX renamed ``pltpu.TPUCompilerParams`` to
    ``pltpu.CompilerParams``; the pinned runtime only has the old name.
    All kernel files build their ``compiler_params`` through this shim.
    """
    cls = getattr(_pltpu, "CompilerParams", None) \
        or getattr(_pltpu, "TPUCompilerParams")
    return cls(**kwargs)


from repro.kernels.fused_decode.ops import fused_decode, rope_at  # noqa: F401,E402
from repro.kernels.flash_decode.ops import flash_decode  # noqa: F401,E402
from repro.kernels.fused_ffn.ops import fused_ffn  # noqa: F401,E402
from repro.kernels.fused_head.ops import fused_head  # noqa: F401,E402
from repro.kernels.fused_mla_decode.ops import fused_mla_decode  # noqa: F401,E402
from repro.kernels.rglru_scan.ops import rglru_scan  # noqa: F401,E402
from repro.kernels.rwkv6_scan.ops import rwkv6_scan  # noqa: F401,E402
