"""Pure-jnp oracle for the RG-LRU scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def rglru_scan_ref(log_a: jax.Array, b: jax.Array, h0: jax.Array, **_):
    la = log_a.astype(jnp.float32)
    bb = b.astype(jnp.float32)

    def step(h, inp):
        la_t, b_t = inp
        h = jnp.exp(la_t) * h + b_t
        return h, h

    h_fin, hs = lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(la, 1, 0), jnp.moveaxis(bb, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(log_a.dtype), h_fin
