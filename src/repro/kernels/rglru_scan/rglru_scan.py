"""RG-LRU sequence-scan kernel (Griffin recurrence) with VMEM-resident state.

grid = (channel_blocks, seq_blocks); channel blocks are independent
("parallel"), sequence blocks are sequential ("arbitrary") with the
recurrent state carried in VMEM scratch — the whole scan runs without
HBM round-trips for the state (beyond-paper fusion for the attention-free
architectures, same philosophy as the paper's decode fusion).

Gate math is precomputed outside (it is a dense matmul — MXU-friendly in
the main graph); the kernel consumes ``log_a`` and the gated input ``b``
and performs ``h_t = exp(log_a_t)·h_{t−1} + b_t`` sequentially.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _kernel(log_a_ref, b_ref, h0_ref, out_ref, h_fin_ref, h_s,
            *, blk_t: int, n_tblocks: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    log_a = log_a_ref[...].astype(jnp.float32)     # [B, blk_t, C]
    b = b_ref[...].astype(jnp.float32)

    def step(t, h):
        h = jnp.exp(log_a[:, t]) * h + b[:, t]
        out_ref[:, t] = h.astype(out_ref.dtype)
        return h

    h = lax.fori_loop(0, blk_t, step, h_s[...])
    h_s[...] = h

    @pl.when(tj == n_tblocks - 1)
    def _fin():
        h_fin_ref[...] = h.astype(h_fin_ref.dtype)


def rglru_scan_kernel(log_a: jax.Array, b: jax.Array, h0: jax.Array,
                      *, block_t: int = 128, block_c: int = 512,
                      interpret: bool = False):
    """log_a/b: [B, S, C]; h0: [B, C] → (h_seq [B, S, C], h_final [B, C])."""
    B, S, C = log_a.shape
    blk_t = min(block_t, S)
    blk_c = min(block_c, C)
    assert S % blk_t == 0 and C % blk_c == 0
    n_t, n_c = S // blk_t, C // blk_c

    kernel = functools.partial(_kernel, blk_t=blk_t, n_tblocks=n_t)
    out, h_fin = pl.pallas_call(
        kernel,
        grid=(n_c, n_t),
        in_specs=[
            pl.BlockSpec((B, blk_t, blk_c), lambda c, t: (0, t, c)),
            pl.BlockSpec((B, blk_t, blk_c), lambda c, t: (0, t, c)),
            pl.BlockSpec((B, blk_c), lambda c, t: (0, c)),
        ],
        out_specs=[
            pl.BlockSpec((B, blk_t, blk_c), lambda c, t: (0, t, c)),
            pl.BlockSpec((B, blk_c), lambda c, t: (0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, C), log_a.dtype),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((B, blk_c), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b, h0)
    return out, h_fin
