"""Jitted public wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rglru_scan.rglru_scan import rglru_scan_kernel
from repro.kernels.rglru_scan.ref import rglru_scan_ref


@partial(jax.jit, static_argnames=("block_t", "block_c", "interpret",
                                   "use_ref"))
def rglru_scan(log_a, b, h0, *, block_t=128, block_c=512, interpret=False,
               use_ref=False):
    if use_ref:
        return rglru_scan_ref(log_a, b, h0)
    return rglru_scan_kernel(log_a, b, h0, block_t=block_t, block_c=block_c,
                             interpret=interpret)
