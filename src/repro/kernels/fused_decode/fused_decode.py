"""Fused QKV-Projection + FlashDecoding-Attention + Output-Projection
decode kernel — the TPU realization of the paper's expanded fusion scope
(DESIGN.md §2, Level 1).

One ``pallas_call`` per decode layer:

* grid = (1 + S_blocks,) — sequential on the TensorCore; grid step 0 is the
  *projection phase* (q/k/v of the new token computed from the resident
  hidden states and weights, RoPE applied, kept in VMEM scratch — the
  analogue of the cluster's ClusterGather'd q/k/v in SMEM); steps 1..S are
  the *attention phase* (FlashDecoding partial over one KV-cache block per
  step, online-softmax accumulators carried in VMEM scratch — the
  sequential analogue of ClusterReduce over concurrent blocks); the last
  step is the *output phase* (rescale + Output-Projection, one HBM write).
* HBM traffic = weights + **live prefix of** the KV cache + x + o (+ the
  k/v append, which the paper also pays) — no intermediate
  materialization, exactly the SplitToken property.  The scalar-prefetched
  block index map is clamped with ``cache_len``: grid steps beyond the
  live prefix re-address the already-resident block, so the pipeline
  issues no new HBM copies for dead blocks, and the ``@pl.when`` guard
  skips their compute.  Decode cost is therefore proportional to
  ``cache_len``, not to the allocated ``S`` (DESIGN.md §3).  Ragged
  batches ``vmap`` the kernel per slot with the scalar-prefetch operand
  batched, so the clamp and the rank-local live-span cull are
  **per-slot**: a retired slot (``cache_len ≤ 0``) runs zero attend
  steps while its batch neighbors keep streaming (DESIGN.md §6).
* interior blocks that are provably fully live (linear slot layout,
  no sliding window) take a mask-free fast path — no compare/select on
  the hot loop.

Cache slots carry explicit positions (``pos``; −1 ⇒ empty), which makes
full, sliding-window and ring caches uniform with the XLA dataflow's
``KVBlock.pos`` convention.  When the caller does not pass ``pos`` the
kernel assumes the linear layout ``pos[i] = i``.

Three modes:
* ``fuse_out=True``  — returns ``o [B, D_out]`` (O-projection fused);
  for single-chip-per-head-group layouts (cluster == 1).
* ``fuse_out=False`` — returns unnormalized ``(acc, m, l)`` partials for
  the cross-chip ClusterReduce combine (DESIGN.md §2, Level 2); the
  O-projection then runs after the combine, as in paper Alg. 3 lines 5–8.
  ``include_new`` gates the new token's own attention contribution so
  that, across a cluster, exactly the rank owning the append slot counts
  it.
* ``fuse_out="partial_o"`` — the Output-Projection tile runs INSIDE the
  kernel on the *unnormalized* accumulator, per head: with ``wo`` passed
  as 3-D per-head tiles ``[q_loc, hd, d_out]`` the kernel emits
  ``o [B, q_loc, d_out]`` projected partials plus ``(m, l)``.  Because
  the projection is linear per head, the flash-merge operator remains
  exact on ``(m, l, o)`` triples, so across a cluster the layer
  completes with exactly ONE fused ClusterReduce followed by a local
  normalize-and-sum-over-heads — the full Alg. 3 fusion scope.  The
  serve layout passes FULL-width rows (d_out = D) so every cluster
  rank's partial lives in the same output basis (DESIGN.md §2,
  serving/prepack.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tracecount
from repro.kernels import tpu_compiler_params


def _kernel(scalars_ref,                         # scalar prefetch (SMEM):
                                                 # [cache_len, include_new,
                                                 #  pos_base]
            x_ref, wqkv_ref, bqkv_ref, wo_ref, cos_ref, sin_ref, norm_ref,
            k_blk_ref, v_blk_ref, pos_blk_ref,
            o_ref, k_new_ref, v_new_ref, m_out_ref, l_out_ref,
            q_s, k_s, v_s, m_s, l_s, acc_s,
            *, blk_s: int, n_blocks: int, q_loc: int, kv_loc: int,
            hd: int, scale: float, cap: float, window: int, ring: bool,
            fuse_out, fuse_norm: bool, norm_eps: float):
    j = pl.program_id(0)
    cache_len = scalars_ref[0]
    B = x_ref.shape[0]
    qpk = q_loc // kv_loc

    # ---------------- phase 0: fused QKV projection --------------------
    @pl.when(j == 0)
    def _proj():
        x = x_ref[...].astype(jnp.float32)               # [B, D]
        if fuse_norm:
            # Pre-attention RMSNorm fused into the projection phase: the
            # RAW residual stream crosses HBM; the normed copy exists only
            # in VMEM.  The dtype round-trip reproduces the XLA oracle's
            # rms_norm output exactly (it returns x.dtype).
            g = norm_ref[...].astype(jnp.float32)        # [1, D] scale
            var = jnp.mean(x * x, axis=-1, keepdims=True)
            x = x * jax.lax.rsqrt(var + norm_eps) * (1.0 + g)
            x = x.astype(x_ref.dtype).astype(jnp.float32)
        w = wqkv_ref[...].astype(jnp.float32)            # [D, P]
        qkv = jax.lax.dot(x, w, precision=lax.Precision.DEFAULT)
        qkv += bqkv_ref[...].astype(jnp.float32)         # [1, P] bias
        q = qkv[:, : q_loc * hd].reshape(B, q_loc, hd)
        k = qkv[:, q_loc * hd: (q_loc + kv_loc) * hd].reshape(B, kv_loc, hd)
        v = qkv[:, (q_loc + kv_loc) * hd:].reshape(B, kv_loc, hd)
        # RoPE at position cache_len (cos/sin precomputed outside)
        cos = cos_ref[...].astype(jnp.float32)           # [1, hd//2]
        sin = sin_ref[...].astype(jnp.float32)
        half = hd // 2

        def rope(t):
            t1, t2 = t[..., :half], t[..., half:]
            return jnp.concatenate([t1 * cos - t2 * sin,
                                    t2 * cos + t1 * sin], axis=-1)

        q_s[...] = rope(q)
        k_s[...] = rope(k)
        v_s[...] = v
        k_new_ref[...] = rope(k).astype(k_new_ref.dtype)
        v_new_ref[...] = v.astype(v_new_ref.dtype)
        m_s[...] = jnp.full_like(m_s[...], -1e30)
        l_s[...] = jnp.zeros_like(l_s[...])
        acc_s[...] = jnp.zeros_like(acc_s[...])

    # ---------------- phases 1..n: FlashDecoding over cache blocks -----
    blk_idx = j - 1
    blk_start = blk_idx * blk_s
    pos_base = scalars_ref[2]
    # Rank-local live span: linear slots hold position pos_base + index,
    # so this rank's live prefix ends at cache_len − pos_base (a non-owner
    # rank whose shard starts beyond cache_len has NO live slots and runs
    # no attend steps).  Ring slot i maps to a global ring slot ≥ i, first
    # written once cache_len exceeds it, so the same bound is a valid
    # (conservative) cull there, with pos_base = −1 ⇒ eff = cache_len.
    eff_len = cache_len - jnp.maximum(pos_base, 0)
    in_range = (j > 0) & (j <= n_blocks) & (blk_start < eff_len)
    if ring:
        # Ring cache: slot offsets are NOT positions once wrapped, so the
        # window bound cannot cull by offset — every resident block may
        # hold in-window entries; the stored-pos mask does the exact cut.
        live = in_range
    else:
        lo = cache_len - window - jnp.maximum(pos_base, 0) \
            if window > 0 else -1
        live = in_range & (blk_start + blk_s > lo)
    # Mask-free fast path: slots are position-linear (pos_base >= 0, i.e.
    # pos[i] = pos_base + i) and the whole block is inside the live prefix.
    full = (live & (pos_base >= 0)
            & (pos_base + blk_start + blk_s <= cache_len)
            & (window == 0))

    def _attend(masked: bool):
        q = q_s[...].reshape(B, kv_loc, qpk, hd)         # f32 scratch
        kb = k_blk_ref[...].astype(jnp.float32)          # [blk, kv_loc, hd]
        vb = v_blk_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, kb, (((3,), (2,)), ((1,), (1,))),         # contract hd, batch kv
        )                                                # [kv, B, qpk, blk]
        s = jnp.moveaxis(s, 0, 1) * scale                # [B, kv, qpk, blk]
        if cap > 0:
            s = jnp.tanh(s / cap) * cap
        valid = None
        if masked:
            pos = pos_blk_ref[...].reshape(1, 1, 1, blk_s)
            valid = (pos >= 0) & (pos < cache_len)
            if window > 0:
                valid &= pos > cache_len - window
            s = jnp.where(valid, s, -1e30)
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        if masked:
            p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        m_s[...] = m_new
        l_s[...] = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, vb, (((3,), (0,)), ((1,), (1,))),         # [B,kv,qpk,blk]x[blk,kv,hd]
        )                                                # -> [kv, B, qpk, hd]
        pv = jnp.moveaxis(pv, 0, 1)
        acc_s[...] = acc_s[...] * corr[..., None] + pv

    @pl.when(full)
    def _attend_full():
        _attend(masked=False)

    @pl.when(live & jnp.logical_not(full))
    def _attend_masked():
        _attend(masked=True)

    # ---------------- final phase: new-token KV + output ---------------
    @pl.when(j == n_blocks + 1)
    def _finalize():
        # append the new token's (k, v) contribution from scratch; across a
        # cluster only the slot-owning rank counts it (include_new).
        include_new = scalars_ref[1] > 0
        q = q_s[...].reshape(B, kv_loc, qpk, hd)
        k_new = k_s[...]                                  # [B, kv_loc, hd]
        v_new = v_s[...]
        s = jnp.einsum("bkqh,bkh->bkq", q, k_new) * scale
        if cap > 0:
            s = jnp.tanh(s / cap) * cap
        s = jnp.where(include_new, s, -1e30)
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, s)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_fin = l_prev * corr + p
        acc = acc_s[...] * corr[..., None] \
            + p[..., None] * v_new[:, :, None, :]
        m_s[...] = m_new
        l_s[...] = l_fin
        if fuse_out == "partial_o":
            # per-head Output-Projection of the UNNORMALIZED accumulator:
            # o[b, h, :] = Σ_d acc[b, h, d] · wo[h, d, :].  Linear per head,
            # so the cross-chip flash merge on (m, l, o) stays exact and the
            # normalization (÷ l_g) + head sum happen after ONE ClusterReduce.
            a2 = acc.reshape(B, q_loc, hd)
            wo3 = wo_ref[...].astype(jnp.float32)         # [q_loc, hd, d_out]
            po = jax.lax.dot_general(
                a2, wo3, (((2,), (1,)), ((1,), (0,))))    # [q_loc, B, d_out]
            o_ref[...] = jnp.moveaxis(po, 0, 1).astype(o_ref.dtype)
        elif fuse_out:
            # max guard: a fully inactive slot (empty cache, include_new
            # gated off — ragged scheduler free slots) has l == 0; emit 0,
            # not NaN (the partial modes defer the divide to the combine).
            att = (acc / jnp.maximum(l_fin[..., None], 1e-30)
                   ).reshape(B, q_loc * hd)
            wo = wo_ref[...].astype(jnp.float32)          # [q_loc*hd, D_out]
            o_ref[...] = jax.lax.dot(att, wo).astype(o_ref.dtype)
        else:
            o_ref[...] = acc.reshape(B, q_loc, hd).astype(o_ref.dtype)
        m_out_ref[...] = m_s[...].reshape(B, q_loc)
        l_out_ref[...] = l_fin.reshape(B, q_loc)


def _live_block_bounds(cache_len, blk_s: int, n_blocks: int, window: int,
                       ring: bool = False, pos_base=0):
    """[lo, hi] inclusive block-index range the pipeline may address.

    Blocks outside it are dead (wholly beyond the live prefix, or wholly
    below the sliding window); the index map clamps into this range so
    dead grid steps re-address a resident block instead of issuing a new
    HBM copy.  Exposed at module level so tests can assert the maps stop
    advancing past the live prefix.

    ``pos_base`` rank-localizes the bounds on a sharded linear cache
    (slot i holds position pos_base + i): a rank whose shard starts past
    ``cache_len`` addresses only block 0.  ``ring=True`` (wrapped slot
    layout, pos_base < 0): offsets are not positions, so only the
    fill-order upper bound applies — slot i is first written when
    ``cache_len`` exceeds its global ring slot (≥ i), hence blocks with
    ``blk_start >= cache_len`` are still provably unwritten.
    """
    cache_len = jnp.asarray(cache_len, jnp.int32)
    eff = cache_len - jnp.maximum(jnp.asarray(pos_base, jnp.int32), 0)
    hi = jnp.clip((eff + blk_s - 1) // blk_s - 1, 0, n_blocks - 1)
    if window > 0 and not ring:
        lo = jnp.clip((eff - window) // blk_s, 0, hi)
    else:
        lo = jnp.zeros_like(hi)
    return lo, hi


def _cache_block_index(j, cache_len, *, blk_s: int, n_blocks: int,
                       window: int, ring: bool = False, pos_base=0):
    """Block index fetched at grid step ``j`` (step 0 is the projection
    phase; steps 1..n_blocks are attention; the final step re-addresses
    the last live block)."""
    lo, hi = _live_block_bounds(cache_len, blk_s, n_blocks, window, ring,
                                pos_base)
    return jnp.clip(j - 1, lo, hi)


def fused_decode_attention(
    x: jax.Array,                 # [B, D]
    wqkv: jax.Array,              # [D, (q_loc + 2 kv_loc) * hd]
    bqkv: Optional[jax.Array],    # [(q_loc + 2 kv_loc) * hd] or None
    wo: jax.Array,                # [q_loc * hd, D_out]; [q_loc, hd, d_out]
                                  # per-head tiles when fuse_out="partial_o"
    k_cache: jax.Array,           # [S, kv_loc, hd]
    v_cache: jax.Array,           # [S, kv_loc, hd]
    cache_len: jax.Array,         # scalar int32: tokens already cached
    cos: jax.Array,               # [hd//2] RoPE at position cache_len
    sin: jax.Array,
    *,
    q_heads: int,
    kv_heads: int,
    scale: Optional[float] = None,
    attn_softcap: float = 0.0,
    window: int = 0,
    ring: bool = False,   # slots wrap (pos ≠ index): window culls by stored
                          # pos only, never by block offset
    block_s: int = 512,
    fuse_out=True,        # True | False | "partial_o"
    interpret: bool = False,
    pos: Optional[jax.Array] = None,          # [S] slot positions (−1 empty)
    include_new: Optional[jax.Array] = None,  # count the new token's own
                                              # attention (cluster: owner only)
    pos_base: Optional[jax.Array] = None,     # pos[i] = pos_base + i when the
                                              # layout is linear; −1 otherwise
    norm_scale: Optional[jax.Array] = None,   # [D] fused pre-attention
                                              # RMSNorm scale; None = caller
                                              # pre-normed x (legacy)
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns ``(o, k_new, v_new, m, l)``.

    ``fuse_out=True``: o = [B, D_out] (final).  ``fuse_out=False``:
    o = [B, q_loc, hd] *unnormalized* accumulator; combine across chips
    with ``cluster_flash_combine`` and project afterwards.
    ``fuse_out="partial_o"``: o = [B, q_loc, d_out] *unnormalized*
    per-head Output-Projection tiles (``wo`` must be ``[q_loc, hd,
    d_out]``); flash-merge the (m, l, o) triple across chips, then
    normalize per head and sum over heads — one ClusterReduce total.
    """
    tracecount.bump("pallas_kernel")
    B, D = x.shape
    S, kv_loc, hd = k_cache.shape
    q_loc = q_heads
    assert kv_loc == kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    blk_s = min(block_s, S)
    assert S % blk_s == 0, (S, blk_s)
    n_blocks = S // blk_s
    if fuse_out == "partial_o":
        assert wo.ndim == 3 and wo.shape[:2] == (q_loc, hd), \
            ("partial_o needs per-head wo tiles [q_loc, hd, d_out]",
             wo.shape, q_loc, hd)
    d_out = wo.shape[-1]
    if bqkv is None:
        bqkv = jnp.zeros((wqkv.shape[1],), wqkv.dtype)
    if pos is None:
        pos = jnp.arange(S, dtype=jnp.int32)
        if pos_base is None:
            pos_base = jnp.int32(0)
    if pos_base is None:
        pos_base = jnp.int32(-1)
    if include_new is None:
        include_new = jnp.int32(1)
    scalars = jnp.stack([
        jnp.asarray(cache_len, jnp.int32).reshape(()),
        jnp.asarray(include_new, jnp.int32).reshape(()),
        jnp.asarray(pos_base, jnp.int32).reshape(()),
    ])
    fuse_norm = norm_scale is not None
    norm_op = (jnp.asarray(norm_scale, jnp.float32).reshape(1, D)
               if fuse_norm else jnp.zeros((1, 1), jnp.float32))

    kernel = functools.partial(
        _kernel, blk_s=blk_s, n_blocks=n_blocks, q_loc=q_loc, kv_loc=kv_loc,
        hd=hd, scale=scale, cap=attn_softcap, window=window, ring=ring,
        fuse_out=fuse_out, fuse_norm=fuse_norm, norm_eps=norm_eps)

    grid = (n_blocks + 2,)
    if fuse_out == "partial_o":
        o_shape = (B, q_loc, d_out)
    elif fuse_out:
        o_shape = (B, d_out)
    else:
        o_shape = (B, q_loc, hd)

    def cache_map(j, s_ref):
        b = _cache_block_index(j, s_ref[0], blk_s=blk_s, n_blocks=n_blocks,
                               window=window, ring=ring, pos_base=s_ref[2])
        return (b, 0, 0)

    def pos_map(j, s_ref):
        b = _cache_block_index(j, s_ref[0], blk_s=blk_s, n_blocks=n_blocks,
                               window=window, ring=ring, pos_base=s_ref[2])
        return (0, b)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((B, D), lambda j, *_: (0, 0)),                 # x
                pl.BlockSpec(wqkv.shape, lambda j, *_: (0, 0)),             # wqkv
                pl.BlockSpec((1, bqkv.shape[0]), lambda j, *_: (0, 0)),     # bqkv
                pl.BlockSpec(wo.shape, lambda j, *_: (0,) * wo.ndim),       # wo
                pl.BlockSpec((1, hd // 2), lambda j, *_: (0, 0)),           # cos
                pl.BlockSpec((1, hd // 2), lambda j, *_: (0, 0)),           # sin
                pl.BlockSpec(norm_op.shape, lambda j, *_: (0, 0)),          # ln1
                pl.BlockSpec((blk_s, kv_loc, hd), cache_map),           # k
                pl.BlockSpec((blk_s, kv_loc, hd), cache_map),           # v
                pl.BlockSpec((1, blk_s), pos_map),                      # pos
            ],
            out_specs=[
                pl.BlockSpec(o_shape, lambda j, *_: (0,) * len(o_shape)),
                pl.BlockSpec((B, kv_loc, hd), lambda j, *_: (0, 0, 0)),
                pl.BlockSpec((B, kv_loc, hd), lambda j, *_: (0, 0, 0)),
                pl.BlockSpec((B, q_loc), lambda j, *_: (0, 0)),
                pl.BlockSpec((B, q_loc), lambda j, *_: (0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((B, q_loc, hd), jnp.float32),    # q
                pltpu.VMEM((B, kv_loc, hd), jnp.float32),   # k_new
                pltpu.VMEM((B, kv_loc, hd), jnp.float32),   # v_new
                pltpu.VMEM((B, kv_loc, q_loc // kv_loc), jnp.float32),  # m
                pltpu.VMEM((B, kv_loc, q_loc // kv_loc), jnp.float32),  # l
                pltpu.VMEM((B, kv_loc, q_loc // kv_loc, hd), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct(o_shape, x.dtype if fuse_out is True
                                 else jnp.float32),
            jax.ShapeDtypeStruct((B, kv_loc, hd), k_cache.dtype),
            jax.ShapeDtypeStruct((B, kv_loc, hd), v_cache.dtype),
            jax.ShapeDtypeStruct((B, q_loc), jnp.float32),
            jax.ShapeDtypeStruct((B, q_loc), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(scalars,
      x, wqkv, bqkv.reshape(1, -1), wo,
      cos.reshape(1, -1), sin.reshape(1, -1), norm_op, k_cache, v_cache,
      jnp.asarray(pos, jnp.int32).reshape(1, S))
    return tuple(out)
