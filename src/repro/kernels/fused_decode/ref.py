"""Pure-jnp oracle for the fused decode kernel."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def fused_decode_attention_ref(
    x: jax.Array, wqkv: jax.Array, bqkv: Optional[jax.Array],
    wo: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
    cache_len, cos: jax.Array, sin: jax.Array, *,
    q_heads: int, kv_heads: int, scale: Optional[float] = None,
    attn_softcap: float = 0.0, window: int = 0, fuse_out=True,
    pos: Optional[jax.Array] = None, include_new=None,
    norm_scale: Optional[jax.Array] = None, norm_eps: float = 1e-6,
    **_,
) -> Tuple[jax.Array, ...]:
    B, D = x.shape
    S, kv_loc, hd = k_cache.shape
    q_loc = q_heads
    qpk = q_loc // kv_loc
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    xf = x.astype(jnp.float32)
    if norm_scale is not None:      # fused pre-attention RMSNorm
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        xf = xf * jax.lax.rsqrt(var + norm_eps) \
            * (1.0 + norm_scale.astype(jnp.float32))
        xf = xf.astype(x.dtype).astype(jnp.float32)
    qkv = xf @ wqkv.astype(jnp.float32)
    if bqkv is not None:
        qkv = qkv + bqkv.astype(jnp.float32)
    q = qkv[:, : q_loc * hd].reshape(B, q_loc, hd)
    k_new = qkv[:, q_loc * hd: (q_loc + kv_loc) * hd].reshape(B, kv_loc, hd)
    v_new = qkv[:, (q_loc + kv_loc) * hd:].reshape(B, kv_loc, hd)

    half = hd // 2
    c, s_ = cos.astype(jnp.float32), sin.astype(jnp.float32)

    def rope(t):
        t1, t2 = t[..., :half], t[..., half:]
        return jnp.concatenate([t1 * c - t2 * s_, t2 * c + t1 * s_], -1)

    q, k_new = rope(q), rope(k_new)

    # full sequence = cache[:cache_len] ++ new token
    kc = k_cache.astype(jnp.float32)
    qg = q.reshape(B, kv_loc, qpk, hd)
    s_cache = jnp.einsum("bkqh,skh->bkqs", qg, kc) * scale
    s_self = jnp.einsum("bkqh,bkh->bkq", qg, k_new) * scale
    if attn_softcap > 0:
        s_cache = jnp.tanh(s_cache / attn_softcap) * attn_softcap
        s_self = jnp.tanh(s_self / attn_softcap) * attn_softcap
    if pos is None:
        pos = jnp.arange(S)
    valid = (pos >= 0) & (pos < cache_len)
    if window > 0:
        valid &= pos > cache_len - window
    if include_new is not None:
        # -1e30 (not -inf) keeps m finite when the cache is empty too
        s_self = jnp.where(include_new > 0, s_self, -1e30)
    s_cache = jnp.where(valid[None, None, None, :], s_cache, -jnp.inf)
    s_all = jnp.concatenate([s_cache, s_self[..., None]], axis=-1)
    m = jnp.max(s_all, axis=-1)
    p = jnp.exp(s_all - m[..., None])
    l = jnp.sum(p, axis=-1)
    v_all = v_cache.astype(jnp.float32)
    acc = jnp.einsum("bkqs,skh->bkqh", p[..., :-1], v_all) \
        + p[..., -1][..., None] * v_new.astype(jnp.float32)[:, :, None, :]
    if fuse_out == "partial_o":
        # unnormalized per-head Output-Projection tiles (wo [q_loc, hd, d])
        o = jnp.einsum("bqh,qhd->bqd", acc.reshape(B, q_loc, hd),
                       wo.astype(jnp.float32))
    elif fuse_out:
        att = (acc / l[..., None]).reshape(B, q_loc * hd)
        o = (att @ wo.astype(jnp.float32)).astype(x.dtype)
    else:
        o = acc.reshape(B, q_loc, hd)
    return (o, k_new.astype(k_cache.dtype), v_new.astype(v_cache.dtype),
            m.reshape(B, q_loc), l.reshape(B, q_loc))
