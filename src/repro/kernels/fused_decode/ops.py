"""Jitted public wrapper for the fused decode kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_decode.fused_decode import fused_decode_attention
from repro.kernels.fused_decode.ref import fused_decode_attention_ref


@partial(jax.jit, static_argnames=("q_heads", "kv_heads", "scale",
                                   "attn_softcap", "window", "ring",
                                   "block_s", "fuse_out", "interpret",
                                   "use_ref", "norm_eps"))
def fused_decode(x, wqkv, bqkv, wo, k_cache, v_cache, cache_len, cos, sin,
                 *, q_heads, kv_heads, scale=None, attn_softcap=0.0,
                 window=0, ring=False, block_s=512, fuse_out=True,
                 interpret=False, use_ref=False, pos=None, include_new=None,
                 pos_base=None, norm_scale=None, norm_eps=1e-6):
    kw = dict(q_heads=q_heads, kv_heads=kv_heads, scale=scale,
              attn_softcap=attn_softcap, window=window, block_s=block_s,
              fuse_out=fuse_out, pos=pos, include_new=include_new,
              norm_scale=norm_scale, norm_eps=norm_eps)
    if use_ref:
        return fused_decode_attention_ref(
            x, wqkv, bqkv, wo, k_cache, v_cache, cache_len, cos, sin, **kw)
    return fused_decode_attention(
        x, wqkv, bqkv, wo, k_cache, v_cache, cache_len, cos, sin,
        interpret=interpret, pos_base=pos_base, ring=ring, **kw)


def rope_at(position, head_dim: int, theta: float = 10000.0):
    """cos/sin vectors for a decode position — a scalar ([half] each) or
    a per-slot ``[B]`` vector of ragged positions ([B, half] each; vmap
    axis 0 into the kernels)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.asarray(position, jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)
