"""GPipe-style pipeline parallelism over the ``pod`` axis.

Cross-pod links (DCN) are an order of magnitude slower than intra-pod ICI,
so the pod axis is better used as a *pipeline* dimension than as plain DP:
each pod owns a contiguous span of layers and only one boundary activation
[µB, S, D] crosses the DCN per microbatch per direction.

Implementation: the classic shifted-microbatch loop inside ``shard_map``
— ``n_micro + n_stages − 1`` ticks; at each tick stage s processes the
microbatch that stage s−1 finished last tick, received via
``ppermute`` over the pod axis.  Backward runs by autodiff through the
loop (GPipe schedule: full forward, then full backward — activations for
the backward are rematerialized per microbatch by ``jax.checkpoint``).

This module provides the *forward* pipeline transform; the train step uses
it through ``pipeline_loss`` which composes it with the loss head on the
last stage and returns a scalar every rank agrees on.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax


def pipeline_forward(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     stage_params: Any,
                     x_micro: jax.Array,          # [n_micro, µB, S, D]
                     axis: str) -> jax.Array:
    """Run microbatches through ``n_stages`` = axis size pipeline stages.

    Every rank holds ITS stage's params (``stage_params``) and the full
    stack of microbatch inputs (only stage 0 actually consumes them).
    Returns the outputs for all microbatches, valid on the LAST stage
    (other ranks hold garbage of the right shape — callers mask).
    """
    n_stages = lax.axis_size(axis)
    stage = lax.axis_index(axis)
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(t, carry):
        inflight, outputs = carry
        # which microbatch does THIS stage work on at tick t?
        mb = t - stage
        live = (mb >= 0) & (mb < n_micro)
        # stage 0 reads a fresh microbatch; others use the received one
        fresh = x_micro[jnp.clip(mb, 0, n_micro - 1)]
        inp = jnp.where(stage == 0, fresh, inflight)
        out = stage_fn(stage_params, inp)
        out = jnp.where(live, out, inflight)
        # last stage records its finished microbatch
        outputs = lax.dynamic_update_index_in_dim(
            outputs,
            jnp.where(live & (stage == n_stages - 1), out,
                      outputs[jnp.clip(mb, 0, n_micro - 1)]),
            jnp.clip(mb, 0, n_micro - 1), axis=0)
        # ship to the next stage (ring; the wraparound edge is ignored)
        inflight = lax.ppermute(out, axis, fwd_perm)
        return inflight, outputs

    inflight0 = jnp.zeros_like(x_micro[0])
    outputs0 = jnp.zeros_like(x_micro)
    _, outputs = lax.fori_loop(0, ticks, tick, (inflight0, outputs0))
    return outputs


def split_stages(kinds_len: int, n_stages: int) -> list:
    """Contiguous layer spans per stage (balanced)."""
    base = kinds_len // n_stages
    rem = kinds_len % n_stages
    spans, start = [], 0
    for s in range(n_stages):
        n = base + (1 if s < rem else 0)
        spans.append((start, start + n))
        start += n
    return spans
