"""Compute/communication overlap: ring all-gather ⟂ matmul.

``overlap_ag_matmul`` computes ``all_gather(x, axis) @ w`` without ever
materializing the gathered operand: a ``lax.fori_loop`` circulates shards
around the ring with ``ppermute`` while multiplying the *previously
received* shard — on TPU the ppermute DMA of chunk k+1 overlaps the MXU
work on chunk k (the classic collective-matmul decomposition, Wang et al.
ASPLOS'23).  Used by the TP FFN when ``overlap=True`` (§Perf hillclimb).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import primitives as prim
from repro.core.primitives import Axis


def overlap_ag_matmul(x: jax.Array, w: jax.Array, axis: Axis) -> jax.Array:
    """x: [B, D_loc] (sharded on dim 1 over ``axis``); w: [D, F_any].

    Returns ``concat_gather(x) @ w`` = [B, F_any] computed as a ring of
    N partial matmuls overlapped with N−1 ppermutes.
    """
    n = prim._axis_size(axis)
    if n == 1:
        return x @ w
    phys = prim._axis_name(axis)
    my = prim.axis_index(axis)
    d_loc = x.shape[1]
    perm = prim._ring_perm(axis, 1)

    def body(i, carry):
        buf, acc = carry
        # shard currently held = the one originally owned by (my - i) mod n
        src = (my - i) % n
        w_slice = lax.dynamic_slice_in_dim(w, src * d_loc, d_loc, axis=0)
        acc = acc + buf @ w_slice
        buf = lax.ppermute(buf, phys, perm)     # send on, receive next
        return buf, acc

    acc0 = jnp.zeros((x.shape[0], w.shape[1]),
                     jnp.promote_types(x.dtype, w.dtype))
    _, acc = lax.fori_loop(0, n, body, (x, acc0))
    return acc.astype(x.dtype)
