"""Gradient compression for the data-parallel all-reduce.

int8 row-scaled quantization with **error feedback** (Seide et al. /
1-bit-Adam lineage): quantize(g + e), all-reduce the int8 payload (as the
tree ClusterReduce of quantized values re-materialized to f32 — TPU ICI
reduces in the element type, so we model compression as quantize →
psum(int32) → dequantize, an 4× wire-traffic reduction vs f32 and 2× vs
bf16), and carry the quantization error into the next step.

The error-feedback state makes the scheme *convergent*: the bias of each
step's rounding is re-injected, so long-run gradients are unbiased.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = jax.Array


class EFState(NamedTuple):
    error: PyTree            # same structure as grads (fp32 residuals)


def init_ef_state(grads: PyTree) -> EFState:
    return EFState(error=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads: PyTree, ef: EFState, axes,
                    n_ranks: int) -> Tuple[PyTree, EFState]:
    """All-reduce ``grads`` over ``axes`` with int8 compression + error
    feedback.  Returns (mean gradients f32, new EF state).

    The scale is itself psum-max'd so every rank dequantizes identically
    (required for the subsequent ZeRO-1 update to stay replicated).
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(gf))
        amax = lax.pmax(amax, axes)                   # shared scale
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        deq = q * scale
        new_e = gf - deq                              # local residual
        # wire payload is int8; the reduction accumulates in int32
        summed = lax.psum(q.astype(jnp.int32), axes)
        mean = summed.astype(jnp.float32) * scale / n_ranks
        return mean, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = treedef.unflatten([m for m, _ in out])
    errs = treedef.unflatten([e for _, e in out])
    return means, EFState(error=errs)


def plain_psum_mean(grads: PyTree, axes, n_ranks: int) -> PyTree:
    return jax.tree.map(
        lambda g: lax.psum(g.astype(jnp.float32), axes) / n_ranks, grads)
